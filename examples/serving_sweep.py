"""Walkthrough: latency/cost Pareto fronts for the serving fleet.

The serving twin of ``examples/pareto_sweep.py``, mirroring the
subsystem's layers (ISSUE 6 tentpole):

  1. seeded open-loop ``Workload`` (bundled LLM request trace) through
     the request-level event engine (``FleetSim``) — cold starts,
     continuous batching, autoscaling, per-arch billing;
  2. the vectorized M/G/c steady-state grid: thousands of
     arch x replicas x RAM x arrival-rate points, millions of
     simulated requests per second;
  3. Pareto extraction: which (replicas, RAM tier) combos are worth
     paying for at each traffic level, per architecture.

  PYTHONPATH=src python examples/serving_sweep.py
"""
import time

from repro.serverless import pareto_front
from repro.serverless.traces import lambda_default, request_default
from repro.serving import (FleetSim, ServingGrid, Workload,
                           serving_sweep_analytic)


def main():
    # ---- 1. one fleet, request by request -----------------------------
    workload = Workload(n_requests=400, trace=request_default())
    workload = workload.with_rate(3.0)          # bursty shape, 3 req/s
    sim = FleetSim(arch="spirt", replicas=1, batch_size=8,
                   autoscale=True, max_replicas=6,
                   trace=lambda_default())      # measured cold starts
    rep = sim.run_workload(workload, seed=0)
    print(f"event engine: {rep.n_requests} requests in "
          f"{rep.makespan_s:.0f}s, p50/p95/p99 latency "
          f"{rep.latency_p50_s:.1f}/{rep.latency_p95_s:.1f}/"
          f"{rep.latency_p99_s:.1f}s")
    print(f"  peak {rep.peak_replicas} replicas "
          f"({rep.n_cold_starts} cold starts), "
          f"${rep.usd_per_1k_requests:.4f}/1k requests")
    for round_idx, delta, why in rep.scale_decisions[:3]:
        print(f"  autoscaler tick {round_idx}: {delta:+d} ({why})")

    # ---- 2. the whole grid in closed form -----------------------------
    grid = ServingGrid(replicas=(1, 2, 4, 8),
                       ram_gb=(1.0, 2.0, 4.0),
                       rate_rps=(0.25, 0.5, 1.0, 2.0, 4.0, 8.0))
    t0 = time.perf_counter()  # repro: allow[no-wallclock] -- demo prints req/s throughput, never recorded
    sw = serving_sweep_analytic(grid)
    dt = time.perf_counter() - t0  # repro: allow[no-wallclock] -- demo prints req/s throughput, never recorded
    print(f"\nanalytic grid: {len(sw)} configs "
          f"({sw.requests_simulated:,} simulated requests) in "
          f"{dt*1e3:.1f} ms — {sw.requests_simulated/dt:,.0f} req/s")

    # ---- 3. Pareto: cost vs p95 latency per architecture --------------
    print("\nPareto fronts (stable points; cost up, p95 latency down):")
    seen = set()
    for arch in sw.grid.resolved_archs():
        idx = [j for j in range(len(sw))
               if sw.arch[j] == arch and sw.stable[j]]
        costs = [sw.usd_per_1k_requests[j] for j in idx]
        lats = [sw.latency_p95_s[j] for j in idx]
        front = [idx[k] for k in pareto_front(costs, lats)]
        key = tuple(round(float(sw.usd_per_1k_requests[j]), 9)
                    for j in front)
        if key in seen:                 # serverless archs bill alike —
            continue                    # their serving fronts coincide
        seen.add(key)
        print(f"\n  {arch} — {len(front)} of {len(idx)} stable configs:")
        for j in front:
            print(f"    ${sw.usd_per_1k_requests[j]:.4f}/1k  "
                  f"p95 {sw.latency_p95_s[j]:6.1f}s  "
                  f"R={int(sw.replicas[j])} "
                  f"ram={sw.ram_gb[j]:g}GB "
                  f"rate={sw.rate_rps[j]:g}rps "
                  f"(rho={sw.rho[j]:.2f})")
    print("\nReading the fronts: Lambda replicas buy latency with RAM "
          "tiers (vCPU\nscales with memory) and bill per-second even "
          "when idle-ish; the GPU\nbaseline decodes ~8x faster but "
          "bills the instance-hour — the paper's\ncost-performance "
          "crossover, restated for inference traffic.")


if __name__ == "__main__":
    main()
