"""Register a third-party architecture in ~20 lines — no edits to the
repo.

The whole serverless stack (analytic simulator, vectorized sweeps,
discrete-event runtime with faults/recovery/autoscaling, trace replay,
Pareto/knee benchmarks) resolves architectures through the
``repro.serverless.archs`` registry, so one ``ArchSpec`` is the entire
integration surface — and that includes the serving subsystem: a
third-party spec flows into ``repro.serving`` fleet runs and
latency/cost sweeps (``benchmarks/serving_sweep.py``) through its
``fleet_cost`` / ``ram_scales_compute`` fields, no serving-side edits.

The example arch, ``tree_allreduce``, replaces λML AllReduce's serial
master with a binary aggregation tree over the channel: each sync is
~log2(W) sequential levels of one gradient push + one fetch, so the
sync wall grows O(log W) instead of O(W).

  PYTHONPATH=src python examples/custom_arch.py
"""
import numpy as np

from repro.serverless import (ArchSpec, EventSweepPoint, FaultPlan,
                              FaultRates, ServerlessSetup, SweepGrid,
                              register_arch, run_event_epoch,
                              simulate_epoch, sweep_analytic, sweep_events)
from repro.serverless.archs import _transfer

# --- the ~20 lines -------------------------------------------------------


def tree_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
               significant_fraction, accumulation):
    levels = np.ceil(np.log2(np.maximum(W, 2)))   # elementwise in W
    per_sync = levels * (_transfer(G, sync_bw, sync_lat, ops=1) * 2)
    return dict(n_rounds=nb, batches_per_round=1.0,
                sync_s=per_sync,
                update_s=_transfer(G, sync_bw, sync_lat, ops=1),
                sync_bytes=levels * 2 * G, update_bytes=1.0 * G)


register_arch(ArchSpec(
    name="tree_allreduce", round_terms=tree_terms,
    description="binary aggregation tree over the channel: O(log W) "
                "sync instead of the serial master's O(W)",
    default_recovery="restore",
    jax_strategy="allreduce", anchor="allreduce"))

# --- and it flows through every layer ------------------------------------


def main():
    rep = simulate_epoch("tree_allreduce", n_params=4_200_000,
                         compute_s_per_batch=0.9)
    print(f"analytic: {rep.per_worker_s:.1f}s/epoch, "
          f"${rep.total_cost:.4f}")

    ev = run_event_epoch(
        "tree_allreduce", n_params=4_200_000, compute_s_per_batch=0.9,
        faults=FaultPlan.random(seed=0, n_workers=4, horizon_s=60.0,
                                crash_rate=0.5),
        recovery="auto")                 # the spec's default policy
    print(f"event engine under faults: {ev.makespan_s:.1f}s, "
          f"{len(ev.recoveries)} recoveries")

    grid = SweepGrid(n_params=4_200_000, compute_s_per_batch=0.9,
                     archs=("allreduce", "tree_allreduce"),
                     n_workers=(4, 8, 16, 32))
    vec = sweep_analytic(grid)
    for arch in grid.archs:
        m = vec.mask(arch)
        print(f"{arch:15s} sync vs W: "
              + "  ".join(f"{s:6.1f}" for s in vec.sync_s[m]))

    stats = sweep_events(
        [EventSweepPoint(arch="tree_allreduce", n_params=4_200_000,
                         compute_s_per_batch=0.9,
                         setup=ServerlessSetup(n_workers=8))],
        rates=FaultRates(crash_rate=0.3, straggler_rate=0.3),
        n_replicates=4, seed=1, processes=1)
    print(f"event sweep: p95 makespan {stats[0].makespan_p95_s:.1f}s, "
          f"cost overhead {stats[0].cost_overhead_mean:+.1%}")

    # ... and into the serving subsystem: the spec's billing and
    # RAM-scaling fields are all the fleet sim / M/G/c sweep need
    from repro.serving import ServingGrid, serving_sweep_analytic
    sv = serving_sweep_analytic(ServingGrid(archs=("tree_allreduce",),
                                            replicas=(2,),
                                            ram_gb=(2.0,),
                                            rate_rps=(1.0,)))
    print(f"serving sweep: p95 latency {sv.latency_p95_s[0]:.1f}s at "
          f"${sv.usd_per_1k_requests[0]:.4f}/1k requests")


if __name__ == "__main__":
    main()
