"""Reproduce the paper's cost-performance analysis (Table 2 + Discussion).

Recomputes the serverless-vs-GPU cost crossover: serverless wins for
MobileNet-class models, dedicated accelerators win as models grow —
then extends the analysis with TPU v5e pod pricing for the assigned
architectures (beyond-paper, DESIGN.md §5).

  PYTHONPATH=src python examples/paper_cost_analysis.py
"""
import numpy as np

from repro.configs.base import get_config
from repro.costmodel import flops as F, pricing
from repro.serverless import (PAPER_TABLE2, ServerlessSetup,
                              paper_cost_check, simulate_epoch)


def main():
    print("=== 1. Validate the paper's Table 2 cost arithmetic ===")
    print(f"{'model':10s} {'framework':14s} {'paper $':>8s} {'ours $':>8s}")
    for model in ("mobilenet", "resnet18"):
        for arch in ("spirt", "scatterreduce", "allreduce", "mlless",
                     "gpu"):
            r = paper_cost_check(model, arch)
            print(f"{model:10s} {arch:14s} {r['paper_total']:8.4f} "
                  f"{r['our_total']:8.4f}")

    print("\n=== 2. The crossover: cost vs model size (simulated) ===")
    print(f"{'params':>12s} {'serverless $':>13s} {'gpu $':>9s} {'winner':>10s}")
    for n_params in (1e6, 4.2e6, 11.7e6, 25e6, 60e6, 150e6):
        # compute time scales ~linearly with params on both platforms;
        # anchor on the paper's MobileNet measurements
        comp_sls = 14.3 * n_params / 4.2e6
        comp_gpu = (92.0 / 24) * n_params / 4.2e6
        sls = simulate_epoch("scatterreduce", n_params=int(n_params),
                             compute_s_per_batch=comp_sls,
                             setup=ServerlessSetup(ram_gb=2.0 + n_params / 2e7))
        gpu = simulate_epoch("gpu", n_params=int(n_params),
                             compute_s_per_batch=comp_gpu)
        winner = "serverless" if sls.total_cost < gpu.total_cost else "gpu"
        print(f"{n_params:12,.0f} {sls.total_cost:13.4f} "
              f"{gpu.total_cost:9.4f} {winner:>10s}")

    print("\n=== 3. Beyond paper: TPU v5e pod pricing, assigned archs ===")
    print(f"{'arch':20s} {'step flops':>12s} {'$/1M tokens @40%MFU':>20s}")
    for arch in ("smollm-135m", "qwen1.5-4b", "phi3-mini-3.8b",
                 "mixtral-8x7b", "mixtral-8x22b"):
        cfg = get_config(arch)
        f = F.train_step_flops(cfg, 256, 4096)
        tokens = 256 * 4096
        t = f / (256 * pricing.HW.peak_flops_bf16) / 0.4
        usd_per_mtok = pricing.tpu_cost(t, 256) / tokens * 1e6
        print(f"{arch:20s} {f:12.3e} {usd_per_mtok:20.4f}")


if __name__ == "__main__":
    main()
