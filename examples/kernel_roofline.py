"""Walkthrough: reading a kernel's roofline from BENCH_kernels.json.

Every public Pallas kernel in ``repro.kernels`` ships three
executables of the same math:

  1. the Mosaic kernel (``interpret=False``, TPU),
  2. its CPU production twin — a fused-jnp formulation for the
     robust-aggregation set, ``models.attention.chunked_attention``
     for sliding-window attention — selected automatically when
     ``interpret=None`` off-TPU,
  3. the pure-jnp oracle in ``kernels/ref.py`` that parity tests and
     the bench compare against.

``benchmarks/kernel_bench.py`` times (2) vs (3) and gates both floors;
this example re-derives the *analytic* side of those rows without any
timing: bytes each aggregation must touch, the compiler-confirmed IO
of the jitted computation (``hlo_analysis.entry_io_bytes``), and the
machine-independent minimum seconds at a given stream bandwidth —
then shows the ``use_pallas`` switch on a recovery aggregator.

Run::

    PYTHONPATH=src python examples/kernel_roofline.py
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.costmodel.hlo_analysis import entry_io_bytes
from repro.kernels import ref, robust_agg
from repro.serverless.recovery import krum

W, D = 8, 250_000                      # a mobilenet-sized [W, D] stack
FP32 = 4


def main():
    rng = np.random.default_rng(0)
    stacked = jnp.asarray(rng.standard_normal((W, D), dtype=np.float32))

    # --- analytic bytes: what any krum implementation must touch -----
    touched = W * D * FP32             # read every row once
    oracle_peak = W * W * D * FP32     # ref materializes [W, W, D]
    print(f"krum [W={W}, D={D:,}]")
    print(f"  bytes any implementation must read : {touched:>13,}")
    print(f"  ref.py broadcast peak              : {oracle_peak:>13,}"
          f"  ({oracle_peak / touched:.0f}x)")

    # --- compiler-confirmed IO of the Gram-form production path ------
    jitted = jax.jit(robust_agg.krum_pairwise)
    hlo = jitted.lower(stacked).compile().as_text()
    param_b, result_b = entry_io_bytes(hlo)
    print(f"  compiled ENTRY io (param, result)  : "
          f"{param_b:,} + {result_b:,}")

    # --- minimum achievable seconds at a given stream bandwidth ------
    # (kernel_bench measures the bandwidth with a triad probe and
    # records it in BENCH_kernels.json; 5 GB/s is this container's
    # ballpark, a v5p HBM stream is ~2 TB/s)
    for name, bw in (("container-cpu", 5e9), ("tpu-v5p-hbm", 2.7e12)):
        print(f"  roofline floor @ {name:<14}: "
              f"{(param_b + result_b) / bw * 1e3:9.3f} ms")

    # --- the floors the bench actually gated, if the payload exists --
    if os.path.exists("BENCH_kernels.json"):
        with open("BENCH_kernels.json") as f:
            payload = json.load(f)
        for row in payload["results"]:
            if row["kernel"] == "krum_pairwise":
                print(f"  BENCH row [{row['config']}]: "
                      f"speedup {row['speedup']:.1f}x vs oracle, "
                      f"roofline_frac {row['roofline_frac']:.2f}, "
                      f"passed={row['passed']}")

    # --- same numbers, same selection: use_pallas on the aggregator --
    jnp_pick = krum(stacked, f=1, use_pallas=False)
    kern_pick = krum(stacked, f=1, use_pallas=True)
    gap = float(jnp.max(jnp.abs(jnp_pick - kern_pick)))
    print(f"  krum(use_pallas=True) vs jnp path  : max |diff| = {gap:.2e}")

    # the oracle agrees too (rtol-sized: Gram form trades the exact
    # difference for cancellation-prone ||xi||^2 + ||xj||^2 - 2<xi,xj>)
    d_ref = ref.krum_pairwise(stacked)
    d_kern = robust_agg.krum_pairwise(stacked)
    rel = float(jnp.max(jnp.abs(d_ref - d_kern)) / jnp.max(d_ref))
    print(f"  pairwise matrix vs ref oracle      : max rel = {rel:.2e}")


if __name__ == "__main__":
    main()
