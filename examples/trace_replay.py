"""Walkthrough: trace-driven fault replay (ISSUE 3 tentpole).

Four steps:

  1. load the bundled Lambda-like trace (digitized from arXiv
     2105.07806) and look at its heavy cold-start/straggler tails;
  2. resample one replayable ``FaultPlan`` from it — per-worker
     cold-start extras + empirical straggler windows, a pure function
     of (trace, seed);
  3. run the event engine under that plan and compare against the
     fault-free analytic epoch;
  4. sweep one architecture under measured tails vs the synthetic
     Poisson defaults and watch the p95 makespan split where the means
     barely move — the reason trace replay exists.

  PYTHONPATH=src python examples/trace_replay.py
"""
from repro.serverless import (EventSweepPoint, FaultPlan, FaultRates,
                              ServerlessSetup, lambda_default,
                              run_event_epoch, simulate_epoch,
                              sweep_events)

N_PARAMS = 4_200_000            # MobileNet
COMP = 0.9                      # s per minibatch


def main():
    # ---- 1. the measured distributions --------------------------------
    tr = lambda_default()
    print(f"trace {tr.name!r}: {len(tr.cold_start_s)} cold-start samples, "
          f"straggler_prob={tr.straggler_prob}")
    for field in ("cold_start_s", "straggler_slowdown",
                  "straggler_duration_s"):
        lo, hi = tr.support(field)
        print(f"  {field:22s} p50={tr.quantile(field, 0.5):6.1f} "
              f"p95={tr.quantile(field, 0.95):6.1f} "
              f"support=[{lo:g}, {hi:g}]")

    # ---- 2. a replayable plan from (trace, seed) ----------------------
    setup = ServerlessSetup()
    base = simulate_epoch("allreduce", n_params=N_PARAMS,
                          compute_s_per_batch=COMP, setup=setup)
    plan = FaultPlan.from_trace(tr, seed=7, n_workers=setup.n_workers,
                                horizon_s=base.per_worker_s,
                                base_cold_start_s=setup.cold_start_s)
    print("\nFaultPlan.from_trace(seed=7): per-worker cold-start extras "
          f"= {[round(e, 1) for e in plan.cold_start_extra_s]} s")
    for s in plan.stragglers:
        print(f"  worker {s.worker} straggles x{s.slowdown:.1f} in "
              f"[{s.start_s:.0f}s, {s.end_s:.0f}s]")
    again = FaultPlan.from_trace(tr, seed=7, n_workers=setup.n_workers,
                                 horizon_s=base.per_worker_s,
                                 base_cold_start_s=setup.cold_start_s)
    print(f"  replayable: identical plan from the same seed -> "
          f"{plan == again}")

    # ---- 3. the event engine replays the measured tails ---------------
    rep = run_event_epoch("allreduce", n_params=N_PARAMS,
                          compute_s_per_batch=COMP, setup=setup,
                          faults=plan)
    print(f"\nevent epoch under the trace: makespan {rep.makespan_s:.1f}s "
          f"vs analytic {rep.analytic_s:.1f}s "
          f"(+{100 * rep.overhead_vs_analytic:.1f}%), "
          f"cost ${rep.total_cost:.4f}")

    # ---- 4. measured tails vs Poisson, replicated ---------------------
    point = [EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                             compute_s_per_batch=COMP)]
    reps = 12
    traced = sweep_events(point, rates=FaultRates(crash_rate=0.1),
                          trace=tr, n_replicates=reps, seed=42,
                          processes=1)[0]
    poisson = sweep_events(point, rates=FaultRates(
        crash_rate=0.1, straggler_rate=tr.straggler_prob, storm_prob=0.3),
        n_replicates=reps, seed=42, processes=1)[0]
    print(f"\nallreduce, {reps} replicates each:")
    print(f"  {'':10s}{'p50 s':>9s}{'p95 s':>9s}{'p95/p50':>9s}"
          f"{'cost $':>9s}")
    for name, s in (("measured", traced), ("poisson", poisson)):
        print(f"  {name:10s}{s.makespan_p50_s:9.1f}{s.makespan_p95_s:9.1f}"
              f"{s.makespan_p95_s / s.makespan_p50_s:9.2f}"
              f"{s.cost_mean:9.4f}")
    print("\nReading it: both arms crash at the same rate (shared crash "
          "sub-stream),\nbut the measured cold-start/straggler tails fatten "
          "the p95 — the synthetic\ndefaults understate exactly the risk a "
          "fleet operator provisions for.")


if __name__ == "__main__":
    main()
