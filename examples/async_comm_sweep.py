"""Tour of the async / compressed-communication architecture family.

PR 10 registers five beyond-paper variants next to the paper's five —
all through ``register_arch``, so the paper specs (and the goldens
pinned to them) are untouched:

  local_sgd        semi-sync: K local steps between barriers, chunked
                   scatter-reduce exchange, mild staleness tax
  async_spirt      barrier-free SPIRT: workers commit whenever their
                   accumulation window closes; staleness is priced as
                   (1 + penalty * min(W-1, bound)) extra batches
  async_spirt_q8   async_spirt over the int8 quantized wire
  scatterreduce_q8 λML ScatterReduce with the int8 payload
                   (0.25 * (1 + 4/chunk) bytes per gradient byte —
                   exactly what ``QuantizedScatterReduce`` ships)
  spirt_sf         SPIRT with MLLess significance filtering (wire bytes
                   scale with the significant fraction)

The same spec drives the analytic simulator, the vectorized sweeps, the
discrete-event engine (barrier-free commit path included), trace
replay, and — through ``jax_strategy`` — real JAX training.  Every
number below is a pure function of the seeds printed with it.

  PYTHONPATH=src python examples/async_comm_sweep.py
"""
from repro.serverless import (EventSweepPoint, FaultPlan, FaultRates,
                              ServerlessSetup, SweepGrid, get_arch,
                              lambda_default, run_event_epoch,
                              simulate_epoch, sweep_analytic,
                              sweep_events)
from repro.serverless.faults import Straggler

N_PARAMS = 4_200_000                       # MobileNet
COMPUTE_S = 0.9


def main():
    # -- staleness is priced, not free ------------------------------------
    spec = get_arch("async_spirt")
    print(f"async_spirt: barrier_sync={spec.barrier_sync}, "
          f"tax = 1 + {spec.staleness_penalty} * "
          f"min(W-1, {spec.staleness_bound:g}) extra batches")
    for arch in ("spirt", "async_spirt", "async_spirt_q8", "spirt_sf",
                 "scatterreduce_q8", "local_sgd"):
        rep = simulate_epoch(arch, n_params=N_PARAMS,
                             compute_s_per_batch=COMPUTE_S)
        print(f"  {arch:17s} {rep.per_worker_s:6.1f}s/epoch  "
              f"${rep.total_cost:.4f}  "
              f"{rep.comm_bytes_per_worker / 1e6:8.1f} MB on the wire")

    # -- where asynchrony pays: a straggler stalls barriers, not peers ----
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=COMPUTE_S,
              accumulation=2, setup=ServerlessSetup(n_workers=4))
    slow = FaultPlan(stragglers=(Straggler(worker=1, slowdown=4.0),))
    for arch in ("spirt", "async_spirt"):
        clean = run_event_epoch(arch, **kw).makespan_s
        hurt = run_event_epoch(arch, faults=slow, **kw).makespan_s
        print(f"straggler overhead {arch:12s} "
              f"{hurt / clean - 1:+.0%} (clean {clean:.0f}s)")

    # -- the whole family through the vectorized sweep --------------------
    grid = SweepGrid(n_params=N_PARAMS, compute_s_per_batch=COMPUTE_S,
                     archs=("spirt", "async_spirt", "scatterreduce",
                            "scatterreduce_q8"),
                     n_workers=(4, 16, 64))
    vec = sweep_analytic(grid)
    for arch in grid.archs:
        m = vec.mask(arch)
        print(f"{arch:17s} sync_s vs W: "
              + "  ".join(f"{s:6.2f}" for s in vec.sync_s[m]))

    # -- and under the measured Lambda cold-start/straggler tails ---------
    stats = sweep_events(
        [EventSweepPoint(arch=a, n_params=N_PARAMS,
                         compute_s_per_batch=COMPUTE_S, label=a)
         for a in ("spirt", "async_spirt_q8")],
        rates=FaultRates(crash_rate=0.2), trace=lambda_default(),
        n_replicates=4, seed=7, processes=1)
    for s in stats:
        print(f"traced {s.point.label:15s} p95 makespan "
              f"{s.makespan_p95_s:6.1f}s  cost ${s.cost_mean:.4f}")


if __name__ == "__main__":
    main()
