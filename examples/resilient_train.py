"""Walkthrough: chaos harness for real sharded training (ISSUE 7).

The event runtime (PRs 1–6) *prices* worker loss analytically: a
crashed Lambda either re-invokes and replays from a checkpoint
(λML / MLLess) or its peers adopt the in-DB state and continue
(SPIRT).  This walkthrough pays those prices for real — a sharded
transformer trained data-parallel on forced host devices, a worker
killed mid-step, both recovery policies applied through the exact
policy objects the simulator scores.  Four steps:

  1. derive a deterministic step-level ``FaultSchedule`` from the same
     wall-clock ``FaultPlan`` the event runtime consumes;
  2. run the chaos scenario in a 4-device subprocess — uninterrupted
     baseline, checkpoint-restore, peer-takeover — one process, one
     XLA compile cache;
  3. read the receipts: the restored run's loss trace is bit-identical
     to the baseline (roll back + replay), the takeover run kept going
     on 3 workers without replay, moving only the dead peer's in-DB
     partition (~1/W of the checkpoint the restore path reads back);
  4. ask the event runtime for its time-to-recover prediction of the
     same scenario and check the policy ordering agrees in sign.

The full grid (config x policy x kill step) with tracked artifacts
lives in ``benchmarks/recovery_replay.py`` (BENCH_recovery.json):

  PYTHONPATH=src python examples/resilient_train.py
"""
from repro.launch.resilient_train import run_in_subprocess
from repro.resilience import FaultSchedule
from repro.serverless.faults import FaultPlan, WorkerCrash

STEPS, KILL_STEP, WORKER = 8, 5, 1


def main():
    # ---- 1. wall-clock fault plan -> step-level schedule --------------
    plan = FaultPlan(crashes=(WorkerCrash(WORKER, 37.5),))
    sched = FaultSchedule.from_fault_plan(plan, total_steps=STEPS,
                                          horizon_s=60.0)
    print(f"fault plan: worker {WORKER} crashes at t=37.5s of a 60s "
          f"epoch -> {sched.kills} (kill before step "
          f"{sched.kills[0][0]} of {STEPS})")

    # ---- 2. the chaos scenario, three ways ----------------------------
    print("\nrunning baseline + restore + takeover in a 4-device "
          "subprocess (~1 min)...")
    out = run_in_subprocess(steps=STEPS, kill_step=sched.kills[0][0],
                            kill_worker=WORKER, checkpoint_every=2,
                            seq=8)
    runs = out["runs"]

    # ---- 3. the receipts ---------------------------------------------
    base, rest, take = (runs["baseline"], runs["restore"],
                        runs["takeover"])
    print("\nloss traces:")
    for name, r in (("baseline", base), ("restore", rest),
                    ("takeover", take)):
        trace = " ".join(f"{x:.4f}" for x in r["losses"])
        print(f"  {name:9s} [{trace}]  workers_end="
              f"{r['n_workers_end']}")
    rrec, trec = rest["recoveries"][0], take["recoveries"][0]
    print(f"\nrestore : bit-exact vs baseline = "
          f"{rest['bitexact_vs_baseline']}, rolled back to step "
          f"{rrec['ckpt_step']}, replayed {rrec['replayed_steps']} "
          f"step(s), moved {rrec['bytes_moved'] / 1e6:.1f} MB "
          f"(full checkpoint) in {rrec['wall_s']:.2f}s")
    print(f"takeover: no replay, survivors adopted the dead peer's "
          f"partition ({trec['bytes_moved'] / 1e6:.1f} MB) in "
          f"{trec['wall_s']:.2f}s; final-loss gap vs baseline = "
          f"{take['final_loss_gap']:.4f}")

    # ---- 4. the simulator's opinion of the same scenario --------------
    from repro.serverless.faults import FaultPlan as FP
    from repro.serverless.runtime import run_event_epoch
    from repro.serverless.simulator import ServerlessSetup

    setup = ServerlessSetup(n_workers=4, batches_per_worker=STEPS,
                            model_bytes=float(base["state_bytes"]))
    kw = dict(n_params=base["n_params"],
              compute_s_per_batch=base["step_s"], setup=setup)
    ttr = {}
    for mode in ("restore", "takeover"):
        quiet = run_event_epoch("spirt", faults=FP(), recovery=mode,
                                **kw)
        crash_t = quiet.makespan_s * KILL_STEP / STEPS
        rep = run_event_epoch(
            "spirt", faults=FP(crashes=(WorkerCrash(WORKER, crash_t),)),
            recovery=mode, **kw)
        ttr[mode] = rep.time_to_recover_s
    real_d = rrec["wall_s"] - trec["wall_s"]
    sim_d = ttr["restore"] - ttr["takeover"]
    print(f"\nevent-runtime TTR: restore={ttr['restore']:.2f}s "
          f"takeover={ttr['takeover']:.2f}s")
    print(f"policy ordering: real delta {real_d:+.2f}s, simulated "
          f"delta {sim_d:+.2f}s -> "
          f"{'consistent' if (real_d > 0) == (sim_d > 0) else 'DISAGREE'}")


if __name__ == "__main__":
    main()
