"""Serve a small model with continuously-batched requests
(deliverable b: batched-request serving driver).

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def main():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_size=4, cache_len=64)

    rs = np.random.RandomState(0)
    n_req = 10
    t0 = time.time()
    for i in range(n_req):
        engine.submit(rs.randint(0, cfg.vocab_size, 8 + i),
                      max_new_tokens=6 + (i % 5))
    out = engine.run()
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {total} tokens in {dt:.1f}s "
          f"with 4 slots")
    for rid in sorted(out):
        print(f"  req {rid}: {out[rid]}")


if __name__ == "__main__":
    main()
