"""Serve a small model with continuously-batched requests
(deliverable b: batched-request serving driver).

The request stream comes from the serving subsystem's seeded
``Workload`` generator — the same open-loop arrival/token process that
drives the fleet simulator (``examples/serving_sweep.py``), so the
toy engine run and the million-request cost sweeps share one traffic
model.  Same seed, same requests, bit-identical outputs.

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import build_model
from repro.serverless.traces import request_default
from repro.serving.engine import ServingEngine
from repro.serving.workload import Workload


def main():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_size=4, cache_len=64)

    # seeded, reproducible traffic from the bundled LLM request trace
    # (arXiv 2311.18677 marginals); the real trace's token counts are
    # folded into the toy model's tiny cache budget
    workload = Workload(n_requests=10, trace=request_default())
    plan = workload.generate(seed=0)
    rs = np.random.RandomState(plan.seed)       # prompt token VALUES only
    t0 = time.time()  # repro: allow[no-wallclock] -- demo prints real elapsed time, never recorded
    for p_tok, d_tok in zip(plan.prompt_tokens, plan.decode_tokens):
        prompt = rs.randint(0, cfg.vocab_size, 4 + p_tok % 12)
        engine.submit(prompt, max_new_tokens=1 + d_tok % 6)
    out = engine.run()
    dt = time.time() - t0  # repro: allow[no-wallclock] -- demo prints real elapsed time, never recorded
    total = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {total} tokens in {dt:.1f}s "
          f"with 4 slots (workload seed {plan.seed}, "
          f"trace {workload.trace.name})")
    for rid in sorted(out):
        print(f"  req {rid}: {out[rid]}")
    print("\nThe engine is clockless — the plan's arrival times "
          f"(first {plan.arrival_s[0]:.2f}s, last {plan.span_s:.2f}s) "
          "are what repro.serving.FleetSim schedules against; see "
          "examples/serving_sweep.py.")


if __name__ == "__main__":
    main()
