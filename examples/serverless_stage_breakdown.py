"""Reproduce the paper's Table 1 structure: the per-stage breakdown
(fetch / compute / sync / update) of each serverless training
architecture, plus cost, from the simulator.

  PYTHONPATH=src python examples/serverless_stage_breakdown.py

Iterates every *registered* architecture (``list_archs()``), so the
beyond-paper hybrids from ``repro.serverless.archs`` — and anything a
user registers — appear alongside the paper's five; hybrids anchor
their calibration on the paper row their spec names.
"""
from repro.serverless import ServerlessSetup, get_arch, list_archs, \
    simulate_epoch
from repro.serverless.simulator import PAPER_TABLE2, paper_compute_anchor


def main():
    print("MobileNet / CIFAR-10, 4 workers, 24 batches/worker "
          "(paper §4.1 setting)\n")
    print(f"{'framework':15s} {'fetch':>7s} {'compute':>8s} {'sync':>7s} "
          f"{'update':>7s} {'total s':>8s} {'$/epoch':>8s}")
    for arch in list_archs():
        spec = get_arch(arch)
        # anchorless third-party specs fail here with the registry's
        # actionable "set ArchSpec.anchor" error, not a bare KeyError
        comp = paper_compute_anchor(arch)
        _, ram, _, paper_total = \
            PAPER_TABLE2["mobilenet"][spec.anchor or arch]
        setup = ServerlessSetup(ram_gb=(ram or 2048) / 1024.0)
        rep = simulate_epoch(arch, n_params=4_200_000,
                             compute_s_per_batch=comp, setup=setup)
        s = rep.stages
        paper = f"(paper: {paper_total})" if spec.paper else "(hybrid)"
        print(f"{arch:15s} {s.fetch:7.2f} {s.compute:8.1f} {s.sync:7.2f} "
              f"{s.update:7.2f} {rep.per_worker_s:8.1f} "
              f"{rep.total_cost:8.4f}   {paper}")
    print("\nNote how statelessness shows up: MLLess/λML reload per batch"
          "\n(fetch), SPIRT amortizes via gradient accumulation, the GPU"
          "\nbaseline loads once.")


if __name__ == "__main__":
    main()
