"""End-to-end driver (paper Fig. 4 in miniature): train the MobileNet
CNN on the synthetic CIFAR-like set with two contrasting strategies —
SPIRT (gradient accumulation) and MLLess (significance filtering) — for
a few hundred steps and print accuracy trajectories.

  PYTHONPATH=src python examples/train_cnn_convergence.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy, losses
from repro.data import cifar_like
from repro.models import build_cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("mobilenet-cifar").reduced()
    imgs, labels = cifar_like(8192, seed=0)
    test_imgs, test_labels = cifar_like(1024, seed=99)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    for sname, kw in (("spirt", {"microbatches": 4}),
                      ("mlless", {"threshold": 0.7})):
        model = build_cnn(cfg)

        def loss_fn(params, b):
            logits, _ = model.apply(params, b)
            return losses.classification_loss(logits, b["labels"])

        ts = build_train_step(model, optim.sgd(0.05, momentum=0.9),
                              get_strategy(sname, **kw), mesh,
                              loss_fn=loss_fn)
        state = ts.init_state(jax.random.PRNGKey(0))
        rs = np.random.RandomState(0)
        t0 = time.time()  # repro: allow[no-wallclock] -- progress print of real training time
        print(f"\n--- {sname} ---")
        for step in range(args.steps):
            idx = rs.randint(0, len(imgs), args.batch)
            b = {"images": jnp.asarray(imgs[idx]),
                 "labels": jnp.asarray(labels[idx])}
            state, metrics = ts.step_fn(state, b)
            if (step + 1) % 50 == 0:
                logits, _ = jax.jit(model.apply)(
                    state["params"], {"images": jnp.asarray(test_imgs)})
                acc = float(losses.accuracy(logits,
                                            jnp.asarray(test_labels)))
                extra = "".join(f" {k}={float(v):.2f}"
                                for k, v in metrics.items()
                                if k not in ("loss", "step"))
                print(f"step {step + 1:4d} loss {float(metrics['loss']):.3f}"
                      f" test_acc {acc:.3f}{extra}"
                      f" ({time.time() - t0:.0f}s)")  # repro: allow[no-wallclock] -- progress print of real training time


if __name__ == "__main__":
    main()
