"""Walkthrough: adversarial-fraction curves (ISSUE 5 tentpole).

Four steps:

  1. tour the attack-model registry — each registered AttackSpec is one
     way a byzantine worker corrupts its gradient, realized both as
     batched numpy (for the simulated sweep) and as a shard_map
     corruption (for real training);
  2. sweep byzantine fraction 0 -> (W-1)/2W x attack x aggregator on
     the deterministic quadratic-loss path and read the degradation
     curves: plain averaging collapses, the robust family holds a
     bounded floor up to each statistic's breakdown budget;
  3. find each aggregator's observed breakdown fraction under the
     colluding little-is-enough attack (Krum's cliff past f=(W-3)/2 is
     the textbook picture);
  4. map architectures onto the curves through ArchSpec's
     ``default_aggregator`` — the paper's per-arch vulnerability story
     in one lookup.

Real training under the same registry (any attack x any aggregator,
4-way data-parallel MobileNet) runs via
``repro.launch.byzantine_train``; see
``benchmarks/adversarial_curves.py --only jax``.

  PYTHONPATH=src python examples/adversarial_curves.py
"""
from repro.serverless import (AdversarialGrid, adversarial_curve,
                              adversarial_sweep, get_arch, get_attack,
                              list_archs, list_attacks,
                              sim_aggregator_max_f)


def main():
    # ---- 1. the attack-model registry ---------------------------------
    print("registered attack models:")
    for name in list_attacks():
        spec = get_attack(name)
        tag = " (colluding)" if spec.colluding else ""
        print(f"  {name:18s} scale={spec.default_scale:<6g}{tag} "
              f"{spec.description.splitlines()[0]}")

    # ---- 2. the byzantine-fraction surface ----------------------------
    grid = AdversarialGrid(n_workers=12, steps=80)
    cells = adversarial_sweep(grid, seed=0)
    print(f"\n{len(cells)} cells: W={grid.n_workers}, fractions "
          f"0 -> {(grid.n_workers - 1) // 2}/{grid.n_workers}, "
          f"{len(list_attacks())} attacks x "
          f"{len(grid.resolved_aggregators())} aggregators")
    print("\nfinal |theta - theta*| under the scale (x-10) attack:")
    fr, _ = adversarial_curve(cells, "mean", "scale")
    print("  fraction:          " + " ".join(f"{f:8.3f}" for f in fr))
    for agg in grid.resolved_aggregators():
        _, dist = adversarial_curve(cells, agg, "scale")
        print(f"  {agg:18s} " + " ".join(f"{d:8.3g}" for d in dist))

    # ---- 3. observed breakdown fractions ------------------------------
    floor = 2 * grid.converge_tol
    print("\nobserved breakdown under the attacks that find each "
          "statistic's weakness\n(first fraction that never reaches "
          f"the {grid.converge_tol:g} convergence ball):")
    for attack in ("scale", "little_is_enough"):
        print(f"  {attack}:")
        for agg in grid.resolved_aggregators():
            fr, steps = adversarial_curve(cells, agg, attack,
                                          "converged_step")
            broke = next((f"{f:.3f}" for f, s in zip(fr, steps)
                          if s < 0), "never")
            cap = sim_aggregator_max_f(agg, grid.n_workers)
            print(f"    {agg:18s} breakdown={broke:6s} "
                  f"(theoretical budget f<={cap})")
    print("  -> Krum's cliff under the colluding attack is the "
        "textbook little-is-enough result:\n     identical byzantine "
        "rows form the tightest cluster, and Krum trusts tight "
        "clusters.")

    # ---- 4. per-architecture vulnerability ----------------------------
    print("\narchitectures map onto the curves via "
          "ArchSpec.default_aggregator:")
    for arch in list_archs():
        agg = get_arch(arch).default_aggregator
        _, dist = adversarial_curve(cells, agg, "scale")
        verdict = ("holds the floor" if dist[-1] <= floor
                   else f"diverges ({dist[-1]:.3g})")
        print(f"  {arch:14s} -> {agg:18s} at max fraction: {verdict}")


if __name__ == "__main__":
    main()
