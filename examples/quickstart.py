"""Quickstart: train a tiny LM with each of the paper's five
gradient-synchronization strategies and compare the resulting losses and
logical communication volumes.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy
from repro.data import lm_batches, token_stream
from repro.models import build_model


def main():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    stream = token_stream(200_000, cfg.vocab_size)
    batches = lm_batches(stream, batch=16, seq=64)
    fixed = [jax.tree.map(jnp.asarray, next(batches)) for _ in range(30)]

    print(f"{'strategy':18s} {'final loss':>10s} {'comm bytes/step':>16s}")
    for name in ("allreduce", "scatterreduce", "parameter_server", "spirt",
                 "mlless"):
        strategy = get_strategy(name)
        ts = build_train_step(model, optim.adamw(3e-3), strategy, mesh)
        state = ts.init_state(jax.random.PRNGKey(0))
        for b in fixed:
            state, metrics = ts.step_fn(state, b)
        grads_like = jax.tree.leaves(state["params"])
        comm = strategy.comm_bytes(grads_like, n_workers=4)
        print(f"{name:18s} {float(metrics['loss']):10.4f} {comm:16,d}")


if __name__ == "__main__":
    main()
