"""A complete third-party repro-lint rule in ~20 lines.

``hidden-seed-default`` flags constant ``seed=<literal>`` defaults in
function signatures: a baked-in seed silently couples every caller to
one RNG stream, while the repo's convention is that seeds flow
explicitly from configs (see the ``seeded-rng`` contract in
``--list-rules``).

Point the CLI at it — no packaging, no entry points, just a file::

    PYTHONPATH=src python -m repro.analysis \
        --plugin examples/custom_rule.py --rules hidden-seed-default src
"""
import ast

from repro.analysis import Finding, RuleSpec, register_rule


def _defaulted_args(a: ast.arguments):
    pos = a.posonlyargs + a.args
    yield from zip(pos[len(pos) - len(a.defaults):], a.defaults)
    yield from ((arg, d) for arg, d in zip(a.kwonlyargs, a.kw_defaults)
                if d is not None)


def check_hidden_seed_default(ctx):
    for mod in ctx.modules.values():
        for fi in mod.functions:
            for arg, default in _defaulted_args(fi.node.args):
                if arg.arg == "seed" and isinstance(default, ast.Constant) \
                        and default.value is not None:
                    yield Finding(
                        mod.rel, fi.node.lineno, "hidden-seed-default",
                        f"{fi.name}() bakes in seed={default.value!r}; "
                        "require the caller to pass one")


register_rule(RuleSpec(
    rule_id="hidden-seed-default",
    description="no constant seed= defaults in function signatures",
    contract="seeds flow from configs/SeedSequence sub-streams so "
             "replicates stay disjoint; a baked-in default couples "
             "every caller to one stream",
    check=check_hidden_seed_default))
