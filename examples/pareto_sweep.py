"""Walkthrough: cost-vs-makespan Pareto fronts from the sweep engine.

Three steps, mirroring the subsystem's layers (ISSUE 2 tentpole):

  1. vectorized analytic sweep over a full configuration grid
     (arch x workers x RAM tier x channel x accumulation x fraction);
  2. seeded multi-replicate event-engine sweep of the interesting
     configs under random faults (crash / straggler / storm);
  3. Pareto extraction: which (RAM tier, channel, autoscaler bound)
     combos are worth paying for, per architecture.

  PYTHONPATH=src python examples/pareto_sweep.py
"""
import time

from repro.serverless import (EventSweepPoint, FaultRates, ServerlessSetup,
                              SweepGrid, pareto_front, ram_scaled_compute,
                              sweep_analytic, sweep_events)
from repro.serverless.simulator import (ARCHS, REDIS, S3,
                                        paper_compute_anchor as anchor)


def main():
    # ---- 1. analytic grid: millions of configs per second -------------
    grid = SweepGrid(n_params=4_200_000,
                     compute_s_per_batch=ram_scaled_compute(0.9),
                     n_workers=(2, 4, 8, 16),
                     ram_gb=(1.0, 2.0, 3.0, 4.0),
                     channels=(REDIS, S3),
                     accumulation=(8, 24),
                     significant_fraction=(0.1, 0.3, 0.9))
    t0 = time.perf_counter()  # repro: allow[no-wallclock] -- demo prints sims/s throughput, never recorded
    sweep = sweep_analytic(grid)
    dt = time.perf_counter() - t0  # repro: allow[no-wallclock] -- demo prints sims/s throughput, never recorded
    print(f"analytic grid: {grid.n_points} configs in {dt*1e3:.1f} ms "
          f"({grid.n_points/dt:,.0f} sims/s)\n")

    # cheapest config per architecture, from the closed form
    print(f"{'arch':14s} {'cheapest $':>10s} {'makespan s':>10s}  config")
    for arch in ARCHS:
        m = sweep.mask(arch)
        i = int(sweep.total_cost[m].argmin())
        idx = m.nonzero()[0][i]
        p = sweep.point(idx)
        print(f"{arch:14s} {p['total_cost']:10.4f} "
              f"{p['per_worker_s']:10.1f}  W={p['n_workers']} "
              f"ram={p['ram_gb']:g}GB {p['channel'].name}")

    # ---- 2. + 3. fault-injected event sweep -> Pareto fronts ----------
    rates = FaultRates(crash_rate=0.2, straggler_rate=0.3, storm_prob=0.2)
    print("\nPareto fronts under faults "
          f"(crash={rates.crash_rate} straggler={rates.straggler_rate} "
          f"storm={rates.storm_prob}, 4 replicates):")
    for arch in ARCHS:
        model = ram_scaled_compute(anchor(arch))
        points = [EventSweepPoint(
            arch=arch, n_params=4_200_000,
            compute_s_per_batch=model(arch, ram),
            setup=ServerlessSetup(ram_gb=ram, channel=ch),
            autoscale_max=hi,
            label=f"ram{ram:g}GB/{ch.name}"
                  + (f"/scale<= {hi}" if hi else "/fixed"))
            for ram in (1.0, 2.0, 3.0)
            for ch in (REDIS, S3)
            for hi in (0, 8)]
        stats = sweep_events(points, rates=rates, n_replicates=4, seed=42)
        costs = [s.cost_mean for s in stats]
        times = [s.makespan_mean_s for s in stats]
        front = pareto_front(costs, times)
        print(f"\n  {arch} — {len(front)} of {len(points)} configs "
              "on the front (cost up, makespan down):")
        for i in front:
            s = stats[i]
            print(f"    ${s.cost_mean:.4f}  {s.makespan_mean_s:7.1f}s "
                  f"(p95 {s.makespan_p95_s:7.1f}s, "
                  f"ttr p95 {s.ttr_p95_s:5.1f}s)  {s.point.label}")
    print("\nReading the fronts: SPIRT/ScatterReduce buy makespan with "
          "RAM tiers\n(Lambda vCPU scales with memory); the GPU baseline "
          "is fast but its\nhourly billing cannot scale to zero between "
          "rounds — the paper's\ncost-performance crossover, now as a "
          "surface instead of a point.")


if __name__ == "__main__":
    main()
