"""Monte-Carlo sweep engine: vectorized-vs-scalar exactness, Pareto
extraction, seeded multi-replicate event sweeps, and (slow-marked)
throughput floors.

The exactness contract is the subsystem's foundation: the vectorized
analytic path evaluates the *same* elementwise formulas as the scalar
``simulate_epoch`` (``simulator._round_terms`` / ``_epoch_terms``), so
every field must agree bit-for-bit — no tolerance."""
import numpy as np
import pytest

from repro.serverless.simulator import REDIS, S3, Channel
from repro.serverless.sweep import (EventSweepPoint, FaultRates, SweepGrid,
                                    iter_grid, pareto_front, point_setup,
                                    ram_scaled_compute, scalar_sweep,
                                    sweep_analytic, sweep_events)

N_PARAMS = int(4.2e6)


def _default_grid(**kw) -> SweepGrid:
    base = dict(n_params=N_PARAMS,
                compute_s_per_batch=ram_scaled_compute(0.9),
                n_workers=(2, 4, 8), ram_gb=(1.0, 2.0, 3.0),
                channels=(REDIS, S3), accumulation=(8, 24),
                significant_fraction=(0.1, 0.3, 0.9))
    base.update(kw)
    return SweepGrid(**base)


def _assert_exact(grid: SweepGrid):
    vec = sweep_analytic(grid)
    sca = scalar_sweep(grid)
    assert len(vec) == len(sca) == grid.n_points
    for i, rep in enumerate(sca):
        point = vec.point(i)
        assert point["arch"] == rep.arch, i
        # bit-exact, every field — shared formulas, no tolerance
        assert vec.per_worker_s[i] == rep.per_worker_s, (i, point)
        assert vec.per_batch_s[i] == rep.per_batch_s, (i, point)
        assert vec.fetch_s[i] == rep.stages.fetch, (i, point)
        assert vec.compute_s[i] == rep.stages.compute, (i, point)
        assert vec.sync_s[i] == rep.stages.sync, (i, point)
        assert vec.update_s[i] == rep.stages.update, (i, point)
        assert vec.comm_bytes_per_worker[i] == rep.comm_bytes_per_worker, \
            (i, point)
        assert vec.cost_per_worker[i] == rep.cost_per_worker, (i, point)
        assert vec.total_cost[i] == rep.total_cost, (i, point)


def test_vectorized_matches_scalar_exactly_on_default_grid():
    _assert_exact(_default_grid())          # 540 points, all archs


def test_vectorized_point_order_matches_iter_grid():
    grid = _default_grid(n_workers=(4,), ram_gb=(1.0, 2.0),
                         accumulation=(24,))
    vec = sweep_analytic(grid)
    for i, p in enumerate(iter_grid(grid)):
        assert vec.point(i)["arch"] == p["arch"]
        assert vec.point(i)["n_workers"] == p["n_workers"]
        assert vec.point(i)["ram_gb"] == p["ram_gb"]
        assert vec.point(i)["channel"] is p["channel"]
        assert vec.point(i)["significant_fraction"] == \
            p["significant_fraction"]
        setup = point_setup(grid, p)
        assert setup.ram_gb == p["ram_gb"]


def test_vectorized_matches_scalar_on_randomized_grids():
    """Hypothesis property: exact agreement on arbitrary axes."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hyp.given, hyp.settings

    pos = dict(allow_nan=False, allow_infinity=False)
    axis_f = lambda lo, hi, n=2: st.lists(        # noqa: E731
        st.floats(lo, hi, **pos), min_size=1, max_size=n, unique=True)

    @settings(max_examples=25, deadline=None)
    @given(
        n_params=st.integers(int(1e3), int(1e8)),
        comp=st.floats(1e-3, 50.0, **pos),
        workers=st.lists(st.integers(1, 32), min_size=1, max_size=3,
                         unique=True),
        rams=axis_f(0.25, 10.0, 3),
        accs=st.lists(st.integers(1, 48), min_size=1, max_size=2,
                      unique=True),
        sigs=axis_f(0.0, 1.0, 3),
        bw=st.floats(1e6, 1e10, **pos),
        lat=st.floats(0.0, 0.1, **pos),
        nb=st.integers(1, 96),
        cold=st.floats(0.0, 30.0, **pos),
    )
    def prop(n_params, comp, workers, rams, accs, sigs, bw, lat, nb, cold):
        grid = SweepGrid(
            n_params=n_params, compute_s_per_batch=comp,
            n_workers=tuple(workers), ram_gb=tuple(rams),
            channels=(Channel("x", bandwidth_Bps=bw, latency_s=lat),),
            accumulation=tuple(accs),
            significant_fraction=tuple(sigs),
            batches_per_worker=nb, cold_start_s=cold)
        _assert_exact(grid)

    prop()


def test_ram_scaled_compute_model():
    m = ram_scaled_compute(0.9, ref_ram_gb=2.0)
    assert m("allreduce", 2.0) == 0.9
    assert m("allreduce", 4.0) == pytest.approx(0.45)   # 2x vCPU
    assert m("allreduce", 1.0) == pytest.approx(1.8)
    assert m("gpu", 4.0) == 0.9                         # tier-independent


def test_pareto_front_drops_dominated_points():
    costs = [1.0, 2.0, 3.0, 2.5, 0.5]
    times = [5.0, 1.0, 0.5, 2.0, 9.0]
    front = pareto_front(costs, times).tolist()
    # index 3 is dominated by index 1 (cheaper AND faster); the rest
    # form the front in increasing-cost order
    assert front == [4, 0, 1, 2]


def test_pareto_front_equal_cost_keeps_only_fastest():
    front = pareto_front([1.0, 1.0, 2.0], [5.0, 3.0, 1.0]).tolist()
    assert front == [1, 2]                  # index 0 dominated by 1


def _points():
    return [EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                            compute_s_per_batch=0.9),
            EventSweepPoint(arch="spirt", n_params=N_PARAMS,
                            compute_s_per_batch=0.9),
            EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                            compute_s_per_batch=0.9, autoscale_max=8)]


_RATES = FaultRates(crash_rate=0.4, straggler_rate=0.4, storm_prob=0.3)


def test_event_sweep_is_deterministic_and_seeded():
    a = sweep_events(_points(), rates=_RATES, n_replicates=3, seed=7,
                     processes=1)
    b = sweep_events(_points(), rates=_RATES, n_replicates=3, seed=7,
                     processes=1)
    c = sweep_events(_points(), rates=_RATES, n_replicates=3, seed=8,
                     processes=1)
    for x, y in zip(a, b):
        assert x.makespan_mean_s == y.makespan_mean_s
        assert x.ttr_p95_s == y.ttr_p95_s
        assert x.cost_overhead_mean == y.cost_overhead_mean
    assert any(x.makespan_mean_s != z.makespan_mean_s
               for x, z in zip(a, c))


def test_event_sweep_processes_match_inline():
    inline = sweep_events(_points()[:2], rates=_RATES, n_replicates=2,
                          seed=3, processes=1)
    fanned = sweep_events(_points()[:2], rates=_RATES, n_replicates=2,
                          seed=3, processes=2)
    for x, y in zip(inline, fanned):
        assert x.makespan_mean_s == y.makespan_mean_s
        assert x.cost_mean == y.cost_mean
        assert x.ttr_mean_s == y.ttr_mean_s


def test_event_sweep_faults_cost_more_than_analytic():
    stats = sweep_events(_points()[:1], rates=_RATES, n_replicates=4,
                         seed=11, processes=1)[0]
    assert stats.makespan_mean_s > stats.analytic_makespan_s
    assert stats.cost_overhead_mean > 0
    assert stats.makespan_p95_s >= stats.makespan_p50_s
    assert stats.ttr_p95_s >= stats.ttr_p50_s


@pytest.mark.slow
def test_vectorized_sweep_50x_faster_than_scalar_loop():
    """Acceptance floor: >=1,000-point grid, >=50x over the scalar loop
    (run explicitly with `pytest -m slow`; timing-sensitive)."""
    import time
    grid = _default_grid(n_workers=(2, 4, 8, 16),
                         ram_gb=(1.0, 2.0, 3.0, 4.0, 6.0),
                         significant_fraction=(0.05, 0.1, 0.3, 0.5, 0.9))
    assert grid.n_points >= 1000
    sweep_analytic(grid)                    # warm
    t_vec = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()  # repro: allow[no-wallclock] -- slow-marked perf floor measures real speedup
        sweep_analytic(grid)
        t_vec = min(t_vec, time.perf_counter() - t0)  # repro: allow[no-wallclock] -- slow-marked perf floor measures real speedup
    t0 = time.perf_counter()  # repro: allow[no-wallclock] -- slow-marked perf floor measures real speedup
    scalar_sweep(grid)
    t_sca = time.perf_counter() - t0  # repro: allow[no-wallclock] -- slow-marked perf floor measures real speedup
    assert t_sca / t_vec >= 50, (t_sca, t_vec)


@pytest.mark.slow
def test_event_runtime_5x_faster_than_reference():
    """Acceptance floor: fault-injected epoch >=5x over the PR 1 engine
    (run explicitly with `pytest -m slow`; timing-sensitive)."""
    import time

    from repro.serverless import (CheckpointRestore, FaultPlan,
                                  ServerlessSetup, Straggler, WorkerCrash)
    from repro.serverless import runtime as opt
    from repro.serverless import runtime_ref as ref
    base = ref.run_event_epoch("allreduce", n_params=N_PARAMS,
                               compute_s_per_batch=0.9,
                               setup=ServerlessSetup())
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=0.9,
              setup=ServerlessSetup(),
              faults=FaultPlan(
                  crashes=(WorkerCrash(1, 0.4 * base.makespan_s),),
                  stragglers=(Straggler(2, slowdown=4.0),)),
              recovery=CheckpointRestore(checkpoint_every=4))

    def best(mod, n=200):
        t = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()  # repro: allow[no-wallclock] -- slow-marked perf floor measures real speedup
            for _ in range(n):
                mod.run_event_epoch("allreduce", **kw)
            t = min(t, (time.perf_counter() - t0) / n)  # repro: allow[no-wallclock] -- slow-marked perf floor measures real speedup
        return t

    t_ref, t_opt = best(ref), best(opt)
    assert t_ref / t_opt >= 5, (t_ref, t_opt)
