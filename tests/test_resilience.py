"""Resilience harness tests: fault schedules, the in-DB store,
survivor re-meshing, checkpoint regressions and the end-to-end chaos
runs (bit-exact restore / no-replay takeover) in 4-device subprocesses.
"""
import numpy as np
import pytest

from repro.resilience import FaultSchedule, InMemoryStore
from repro.serverless.faults import FaultPlan, WorkerCrash

# NOTE: the chaos subprocess tests use a (W, 1) mesh — the auto 'model'
# axis is width 1, so the partial-manual SPMD crash that gates
# test_multidevice's wide-model-axis tests does not apply (same reason
# test_adversarial's byzantine_train subprocesses run ungated).


# ---------------------------------------------------------------------------
# FaultSchedule
# ---------------------------------------------------------------------------
def test_schedule_sorts_and_queries():
    s = FaultSchedule(kills=((7, 2), (3, 0)))
    assert s.kills == ((3, 0), (7, 2))
    assert s.kill_at(3) == 0 and s.kill_at(7) == 2
    assert s.kill_at(5) is None
    assert s.n_kills == 2
    assert FaultSchedule.single(4, worker=1).kills == ((4, 1),)


def test_schedule_rejects_bad_entries():
    with pytest.raises(ValueError, match="step must be >= 1"):
        FaultSchedule(kills=((0, 1),))
    with pytest.raises(ValueError, match="worker must be >= 0"):
        FaultSchedule(kills=((2, -1),))
    with pytest.raises(ValueError, match="one kill per step"):
        FaultSchedule(kills=((2, 0), (2, 1)))


def test_schedule_from_fault_plan_maps_and_clamps():
    plan = FaultPlan(crashes=(
        WorkerCrash(0, 0.0),      # clamps up to step 1
        WorkerCrash(1, 50.0),     # -> round(50/100 * 10) = 5
        WorkerCrash(2, 999.0),    # clamps down to step 9
        WorkerCrash(3, 51.0),     # also -> 5: dropped (occupied)
    ))
    s = FaultSchedule.from_fault_plan(plan, total_steps=10,
                                      horizon_s=100.0)
    assert s.kills == ((1, 0), (5, 1), (9, 2))


def test_schedule_from_fault_plan_validates():
    with pytest.raises(ValueError, match="total_steps"):
        FaultSchedule.from_fault_plan(FaultPlan(), total_steps=1,
                                      horizon_s=10.0)
    with pytest.raises(ValueError, match="horizon_s"):
        FaultSchedule.from_fault_plan(FaultPlan(), total_steps=4,
                                      horizon_s=0.0)


def test_schedule_from_fault_plan_properties():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=50, deadline=None)
    @hyp.given(
        times=st.lists(st.floats(min_value=0.0, max_value=200.0,
                                 allow_nan=False), max_size=8),
        total_steps=st.integers(min_value=2, max_value=40),
        horizon=st.floats(min_value=1.0, max_value=150.0))
    def check(times, total_steps, horizon):
        plan = FaultPlan(crashes=tuple(
            WorkerCrash(i % 4, t) for i, t in enumerate(times)))
        s = FaultSchedule.from_fault_plan(plan, total_steps=total_steps,
                                          horizon_s=horizon)
        steps = [k for k, _ in s.kills]
        # every kill lands strictly inside the run, sorted and unique
        assert all(1 <= k <= total_steps - 1 for k in steps)
        assert steps == sorted(steps)
        assert len(set(steps)) == len(steps)
        assert s.n_kills <= len(times)
        # pure function of its inputs
        again = FaultSchedule.from_fault_plan(
            plan, total_steps=total_steps, horizon_s=horizon)
        assert again == s

    check()


# ---------------------------------------------------------------------------
# InMemoryStore
# ---------------------------------------------------------------------------
def test_store_accounting_and_missing_key():
    st = InMemoryStore()
    st.put("a", b"xyz")
    assert st.get("a") == b"xyz"
    assert (st.bytes_written, st.bytes_read) == (3, 3)
    assert (st.puts, st.gets) == (1, 1)
    assert "a" in st and "b" not in st
    with pytest.raises(KeyError, match="no key 'b'"):
        st.get("b")
    st.reset()
    assert st.keys() == [] and st.bytes_written == 0


def test_store_partition_roundtrip():
    st = InMemoryStore()
    blob = bytes(range(256)) * 5 + b"tail"   # not divisible by 4
    st.push_partitions(blob, 4)
    assert len(st.keys()) == 4
    rebuilt, dead_bytes = st.fetch_state(4, dead=2)
    assert rebuilt == blob
    assert dead_bytes == len(st.get("shard/2"))
    with pytest.raises(ValueError, match="out of range"):
        st.fetch_state(4, dead=4)
    with pytest.raises(ValueError, match="n_workers"):
        st.push_partitions(blob, 0)


# ---------------------------------------------------------------------------
# survivor_mesh (validation paths run on the default 1-device backend)
# ---------------------------------------------------------------------------
def test_survivor_mesh_validation():
    import jax
    from repro.core.sharding import survivor_mesh
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="no axis 'pod'"):
        survivor_mesh(mesh, 0, data_axis="pod")
    with pytest.raises(ValueError, match="out of range"):
        survivor_mesh(mesh, 3)
    with pytest.raises(ValueError, match="no survivors"):
        survivor_mesh(mesh, 0)


# ---------------------------------------------------------------------------
# checkpoint regressions (PR 7 satellites)
# ---------------------------------------------------------------------------
def test_checkpoint_treedef_mismatch_names_both(tmp_path):
    from repro import checkpoint
    p = str(tmp_path / "s.msgpack")
    checkpoint.save(p, {"a": np.zeros(2), "b": np.ones(3)})
    # same leaf count/shapes, different structure -> treedef error
    # must name both structures so the mismatch is debuggable
    with pytest.raises(ValueError) as ei:
        checkpoint.restore(p, like=[np.zeros(2), np.ones(3)])
    msg = str(ei.value)
    assert "stored" in msg and "like" in msg


def test_checkpoint_restored_leaves_are_writable(tmp_path):
    """np.frombuffer regression: restored numpy leaves must own
    writable memory (in-place optimizer updates, donation)."""
    from repro import checkpoint
    p = str(tmp_path / "s.msgpack")
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.zeros(4, dtype=np.int32)}
    checkpoint.save(p, tree)
    out = checkpoint.restore(
        p, like={"w": np.zeros((2, 3), np.float32),
                 "b": np.zeros(4, np.int32)})
    for leaf in (out["w"], out["b"]):
        assert isinstance(leaf, np.ndarray)
        assert leaf.flags.writeable
        leaf += 1                      # must not raise
    np.testing.assert_array_equal(out["w"], tree["w"] + 1)


def test_checkpoint_restore_to_jax_template_is_donatable(tmp_path):
    from repro import checkpoint
    import jax
    import jax.numpy as jnp
    p = str(tmp_path / "s.msgpack")
    checkpoint.save(p, {"w": np.full((4,), 2.0, np.float32)})
    out = checkpoint.restore(p, like={"w": jnp.zeros(4)})
    assert isinstance(out["w"], jax.Array)

    @jax.jit
    def bump(x):
        return x + 1

    donated = jax.jit(lambda x: x * 2, donate_argnums=0)(out["w"])
    np.testing.assert_array_equal(np.asarray(donated), 4.0)
    # the original buffer was donated -> restored arrays are owned,
    # not views of the serialized payload
    assert out["w"].is_deleted()
    del bump


# ---------------------------------------------------------------------------
# launch._subprocess helpers
# ---------------------------------------------------------------------------
def test_subprocess_env_and_result_parsing():
    from repro.launch import _subprocess
    env = _subprocess.child_env(6)
    assert env["XLA_FLAGS"].endswith("device_count=6")
    assert env["PYTHONPATH"].startswith(_subprocess.src_root())
    with pytest.raises(ValueError, match="devices"):
        _subprocess.child_env(0)

    parsed = _subprocess.parse_result_line(
        "noise\nRESULT,inner=krum,acc=0.5,loss=1.25\n",
        numeric_except=("inner",))
    assert parsed == {"inner": "krum", "acc": 0.5, "loss": 1.25}
    with pytest.raises(RuntimeError, match="no RESULT line"):
        _subprocess.parse_result_line("it crashed\n")


# ---------------------------------------------------------------------------
# end-to-end chaos runs (4-device subprocesses)
# ---------------------------------------------------------------------------
_SMALL = dict(steps=5, kill_step=3, checkpoint_every=2, seq=8,
              n_workers=4, global_batch=12)


def _check_chaos_scenario(seed: int) -> None:
    """One killed-at-step-k scenario: restore must replay the
    uninterrupted same-seed loss trace bit-exactly; takeover must
    resume without replay on the survivor fleet within tolerance."""
    from repro.launch.resilient_train import run_in_subprocess
    out = run_in_subprocess(seed=seed, **_SMALL)
    runs = out["runs"]
    rest, take = runs["restore"], runs["takeover"]
    # restore: bit-exact vs the uninterrupted baseline, and the
    # replayed steps reproduced their pre-kill losses exactly
    assert rest["bitexact_vs_baseline"]
    assert rest["replay_exact"]
    assert rest["recoveries"][0]["replayed_steps"] == 1
    assert rest["n_workers_end"] == 4
    # takeover: no replay, shrunk fleet, converges within tolerance
    trec = take["recoveries"][0]
    assert trec["replayed_steps"] == 0
    assert trec["n_workers_after"] == 3
    assert take["n_workers_end"] == 3
    assert take["final_loss_gap"] < 0.5
    # takeover moves only the dead peer's partition (~1/W of the
    # full checkpoint the restore path reads back)
    assert trec["bytes_moved"] < rest["recoveries"][0]["bytes_moved"]


def test_killed_then_restored_replays_bitexact():
    """Acceptance: the canonical seed, always run (no hypothesis
    dependency — this is the criterion the PR stands on)."""
    _check_chaos_scenario(seed=0)


@pytest.mark.slow
def test_killed_then_restored_replays_bitexact_seeded():
    """Hypothesis-drawn seeds: bit-exactness is a property of the
    harness, not of one lucky seed.  (slow: one ~1min subprocess per
    example.)"""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=2, deadline=None)
    @hyp.given(seed=st.integers(min_value=1, max_value=7))
    def check(seed):
        _check_chaos_scenario(seed)

    check()


@pytest.mark.slow
def test_restore_onto_shrunk_survivor_mesh():
    """restore_reinvoke=False: the checkpoint written from the W-way
    mesh restores onto the (W-1)-way survivor mesh and training
    continues (sharded restore onto a different mesh)."""
    from repro.launch.resilient_train import run_in_subprocess
    out = run_in_subprocess(restore_reinvoke=False,
                            modes="baseline,restore", **_SMALL)
    runs = out["runs"]
    rest, base = runs["restore"], runs["baseline"]
    rec = rest["recoveries"][0]
    assert rec["n_workers_after"] == 3
    assert rest["n_workers_end"] == 3
    assert rec["replayed_steps"] == 1
    # pre-checkpoint prefix is untouched history; post-rollback losses
    # come from 3-way arithmetic, so no bit-claim -- but the run must
    # converge to the neighbourhood of the unfaulted baseline
    k = rec["ckpt_step"]
    assert rest["losses"][:k] == base["losses"][:k]
    assert abs(rest["final_loss"] - base["final_loss"]) < 0.5
