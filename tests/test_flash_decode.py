"""Context-parallel flash-decode == single-device decode attention."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.flash_decode import flash_decode_attention
from repro.models.attention import decode_attention


@pytest.mark.parametrize("window", [None, 48])
@pytest.mark.parametrize("pos_past_wrap", [False, True])
def test_flash_decode_matches_reference(window, pos_past_wrap):
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 host devices")
    n_dev = min(4, len(jax.devices()))
    mesh = jax.make_mesh((n_dev,), ("data",))
    B, L, KV, G, hd = 2, 64, 2, 3, 32
    H = KV * G
    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.randn(B, 1, H, hd), jnp.float32)
    k = jnp.asarray(rs.randn(B, L, KV, hd), jnp.float32)
    v = jnp.asarray(rs.randn(B, L, KV, hd), jnp.float32)
    # ring semantics: if pos wrapped, all slots hold recent positions
    pos = jnp.asarray(L + 7 if pos_past_wrap else L - 1, jnp.int32)

    expect = decode_attention(q, k, v, pos, window=window)

    from repro.compat import shard_map
    fn = shard_map(
        lambda q_, k_, v_: flash_decode_attention(
            q_, k_, v_, pos, axis_name="data", total_len=L, window=window),
        mesh=mesh, in_specs=(P(), P(None, "data"), P(None, "data")),
        out_specs=P(), check_vma=False, axis_names={"data"})
    got = jax.jit(fn)(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               atol=2e-5)
