"""Property-based tests (hypothesis) on system invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.costmodel import flops, pricing
from repro.kernels import ops, ref
from repro.serverless import simulate_epoch


@given(n=st.integers(1, 400), b=st.sampled_from([64, 128, 256]),
       thr=st.floats(0.0, 3.0), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_error_feedback_conservation(n, b, thr, seed):
    """kept + residual == gradient, for any threshold/block size."""
    x = jnp.asarray(np.random.RandomState(seed).randn(n, b), jnp.float32)
    kept, resid, mask = ops.significance_filter(x, thr)
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(x),
                               atol=1e-5)
    # mask semantics: kept rows equal input; dropped rows zero
    m = np.asarray(mask)
    np.testing.assert_allclose(np.asarray(kept)[~m], 0.0, atol=0)
    np.testing.assert_allclose(np.asarray(kept)[m], np.asarray(x)[m],
                               atol=1e-6)


@given(t=st.floats(0.1, 1000), ram=st.floats(0.25, 10.0),
       k=st.integers(1, 100))
@settings(max_examples=50, deadline=None)
def test_lambda_cost_linear(t, ram, k):
    """Cost = time × RAM × rate is linear in each factor."""
    c1 = pricing.lambda_cost(t, ram)
    assert abs(pricing.lambda_cost(k * t, ram) - k * c1) < 1e-9 * max(k, 1)
    assert abs(pricing.lambda_cost(t, k * ram) - k * c1) < 1e-9 * max(k, 1)
    assert c1 >= 0


@given(nw=st.integers(2, 32), npar=st.integers(10**4, 10**8),
       comp=st.floats(0.01, 30.0))
@settings(max_examples=25, deadline=None)
def test_simulator_monotonicity(nw, npar, comp):
    """More params => more comm time; PS-style allreduce sync grows
    at least as fast as scatterreduce with workers."""
    from repro.serverless.simulator import ServerlessSetup
    setup = ServerlessSetup(n_workers=nw)
    r1 = simulate_epoch("allreduce", n_params=npar,
                        compute_s_per_batch=comp, setup=setup)
    r2 = simulate_epoch("allreduce", n_params=npar * 2,
                        compute_s_per_batch=comp, setup=setup)
    assert r2.stages.sync >= r1.stages.sync
    assert r1.total_cost > 0


@given(seq=st.sampled_from([512, 4096, 32768]),
       batch=st.sampled_from([1, 8, 256]))
@settings(max_examples=20, deadline=None)
def test_flops_scaling(seq, batch):
    """Forward FLOPs scale linearly in batch and superlinearly in seq for
    full attention archs."""
    from repro.configs.base import get_config
    cfg = get_config("phi3-mini-3.8b")
    f1 = flops.forward_flops(cfg, batch, seq)
    f2 = flops.forward_flops(cfg, 2 * batch, seq)
    np.testing.assert_allclose(f2, 2 * f1, rtol=1e-9)
    g1 = flops.forward_flops(cfg, batch, seq)
    g2 = flops.forward_flops(cfg, batch, 2 * seq)
    assert g2 > 2 * g1  # attention quadratic term


@given(s=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_synthetic_data_learnable_structure(s):
    """Class templates must be distinguishable from noise: same-class
    images correlate more than cross-class on average."""
    from repro.data import cifar_like
    imgs, labels = cifar_like(64, seed=s)
    flat = imgs.reshape(64, -1)
    flat = (flat - flat.mean(1, keepdims=True))
    flat /= np.linalg.norm(flat, axis=1, keepdims=True) + 1e-9
    sim = flat @ flat.T
    same = sim[labels[:, None] == labels[None, :]]
    diff = sim[labels[:, None] != labels[None, :]]
    assert same.mean() > diff.mean()
