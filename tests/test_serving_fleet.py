"""FleetSim event engine + M/G/c steady-state fast path: validation,
exact small-case timing, ArchSpec billing, autoscaling, the
analytic-vs-event agreement tolerance, and the slow-marked >= 1M
simulated requests/s throughput floor (ISSUE 6 acceptance)."""
import dataclasses

import numpy as np
import pytest

from repro.costmodel import pricing
from repro.serverless.traces import Trace, request_default
from repro.serving.fleet import FleetSim
from repro.serving.steady_state import (ServingGrid, analytic_point,
                                        serving_sweep_analytic)
from repro.serving.workload import RequestPlan, Workload


def _plan(arrivals, prompts, decodes):
    return RequestPlan(arrival_s=tuple(arrivals),
                       prompt_tokens=tuple(prompts),
                       decode_tokens=tuple(decodes))


# ------------------------------------------------------------ validation
@pytest.mark.parametrize("kw", [
    dict(arch="no_such_arch"),
    dict(batch_size=0),
    dict(replicas=0),
    dict(min_replicas=0),
    dict(min_replicas=3, replicas=2),
    dict(replicas=4, max_replicas=2),
    dict(decode_step_s=0.0),
    dict(prefill_s_per_token=-1e-4),
    dict(ram_gb=0.0),
    dict(cold_start_s=-1.0),
    dict(control_interval_s=0.0),
])
def test_fleet_sim_rejects_bad_inputs(kw):
    with pytest.raises(ValueError):
        FleetSim(**kw)


@pytest.mark.parametrize("kw", [
    dict(batch_size=0),
    dict(n_requests=0),
    dict(replicas=()),
    dict(replicas=(0,)),
    dict(ram_gb=(2.0, 0.0)),
    dict(rate_rps=(1.0, -1.0)),
])
def test_serving_grid_rejects_bad_inputs(kw):
    with pytest.raises(ValueError):
        ServingGrid(**kw)


# ------------------------------------------------- exact engine semantics
def test_single_request_timing_is_exact():
    """One request, one replica: latency = cold start + own prefill
    + (d-1) decode steps, to the float."""
    sim = FleetSim(arch="spirt", replicas=1, batch_size=4,
                   cold_start_s=2.0, prefill_s_per_token=1e-3,
                   decode_step_s=0.1)
    rep = sim.run(_plan([0.5], [100], [5]))
    # arrives 0.5s in, replica ready at 2.0: wait 1.5, prefill 0.1,
    # 4 decode steps of 0.1
    assert rep.latency_p50_s == pytest.approx(1.5 + 0.1 + 0.4)
    assert rep.ttft_p50_s == pytest.approx(1.5 + 0.1)
    assert rep.makespan_s == pytest.approx(2.0 + 0.1 + 0.4)


def test_one_token_request_completes_at_prefill():
    """d=1 finishes at admission without a decode step — the
    ServingEngine._admit semantics the engine tests pin."""
    sim = FleetSim(replicas=1, batch_size=2, cold_start_s=0.0,
                   prefill_s_per_token=1e-3, decode_step_s=0.1)
    rep = sim.run(_plan([0.0], [50], [1]))
    assert rep.latency_p50_s == pytest.approx(0.05)
    assert rep.ttft_p50_s == rep.latency_p50_s


def test_batch_shares_decode_steps():
    """B simultaneous residents decode together: each pays every
    resident's serial prefill once, then shared 0.1s token steps."""
    sim = FleetSim(replicas=1, batch_size=2, cold_start_s=0.0,
                   prefill_s_per_token=1e-2, decode_step_s=0.1)
    rep = sim.run(_plan([0.0, 0.0], [10, 10], [3, 3]))
    # both admitted at t=0 (serial prefills 0.1 + 0.1), then 2 shared
    # decode steps finish both at 0.2 + 0.2
    assert rep.makespan_s == pytest.approx(0.4)
    assert rep.latency_p95_s == pytest.approx(0.4)
    # queued third request on a full batch waits for a free slot
    rep2 = sim.run(_plan([0.0, 0.0, 0.0], [10, 10, 10], [3, 3, 2]))
    assert rep2.makespan_s > 0.4


def test_run_is_deterministic_and_seeded():
    w = Workload(n_requests=300, trace=request_default()).with_rate(2.0)
    sim = FleetSim(arch="spirt", replicas=2, batch_size=4,
                   trace=Trace(cold_start_s=(2.0, 9.0, 30.0)), seed=5)
    a, b = sim.run(w.generate(3)), sim.run(w.generate(3))
    assert a == b
    c = dataclasses.replace(sim, seed=6).run(w.generate(3))
    assert c != a                        # cold-start draws are seeded


def test_cold_start_trace_tail_gates_first_requests():
    slow = Trace(cold_start_s=(60.0,))
    base = FleetSim(replicas=1, batch_size=4, cold_start_s=1.0)
    cold = dataclasses.replace(base, trace=slow)
    plan = _plan([0.0], [10], [2])
    assert cold.run(plan).latency_p50_s \
        == pytest.approx(base.run(plan).latency_p50_s + 59.0)


# ---------------------------------------------------------------- billing
def test_arch_spec_billing_lambda_vs_instance():
    """Lambda replicas bill GB-seconds of up-time; the gpu arch bills
    instance-hours on the makespan — straight through ArchSpec."""
    plan = _plan([0.0, 0.1], [10, 10], [4, 4])
    lam = FleetSim(arch="spirt", replicas=2, batch_size=2, ram_gb=3.0,
                   cold_start_s=0.5).run(plan)
    # both replicas up from 0 to makespan
    assert lam.total_cost == pytest.approx(
        2 * pricing.lambda_cost(lam.makespan_s, 3.0))
    gpu = FleetSim(arch="gpu", replicas=2, batch_size=2,
                   cold_start_s=0.5).run(plan)
    assert gpu.total_cost == pytest.approx(
        pricing.gpu_cost(gpu.makespan_s, n_instances=2))
    assert gpu.usd_per_1k_requests == pytest.approx(
        gpu.total_cost / 2 * 1000)


def test_ram_scales_compute_for_lambda_not_gpu():
    """The serving twin of ram_scaled_compute: doubling RAM halves
    Lambda step times; the gpu arch has fixed accelerator steps."""
    lam2 = FleetSim(arch="spirt", ram_gb=2.0)
    lam4 = dataclasses.replace(lam2, ram_gb=4.0)
    assert lam4.step_times()[1] == pytest.approx(
        lam2.step_times()[1] / 2)
    g2 = FleetSim(arch="gpu", ram_gb=2.0, gpu_speedup=8.0)
    g4 = dataclasses.replace(g2, ram_gb=4.0)
    assert g2.step_times() == g4.step_times()
    assert g2.step_times()[1] == pytest.approx(
        lam2.step_times()[1] / 8.0)


# ------------------------------------------------------------ autoscaling
def test_autoscaler_scales_out_under_overload_and_respects_bounds():
    w = Workload(n_requests=400, rate_rps=4.0, prompt_tokens=256,
                 decode_tokens=64)
    fixed = FleetSim(arch="spirt", replicas=1, batch_size=4,
                     cold_start_s=1.0)
    scaled = dataclasses.replace(fixed, autoscale=True, max_replicas=6,
                                 control_interval_s=5.0)
    a, b = fixed.run(w.generate(1)), scaled.run(w.generate(1))
    assert b.peak_replicas > 1 and b.peak_replicas <= 6
    assert b.n_cold_starts > 1
    assert any(d > 0 for _, d, _ in b.scale_decisions)
    assert b.latency_p95_s < a.latency_p95_s       # scaling helped
    assert b.makespan_s < a.makespan_s


# ----------------------------------------------- analytic vs event engine
def _agreement_cases():
    """(sim, workload, mean tol, p95 tol) — Poisson arrivals match the
    M/G/c form tightly; the bundled trace's BURSTY arrivals push the
    event engine above it (M/G/c assumes Poisson), so the traced case
    carries a looser, still-pinned tolerance."""
    n = 3000
    wl = Workload(n_requests=n, rate_rps=1.0, prompt_tokens=256,
                  decode_tokens=64)
    return [
        (FleetSim(arch="spirt", replicas=2, batch_size=8,
                  cold_start_s=0.0), wl.with_rate(2.0), 0.15, 0.30),
        (FleetSim(arch="spirt", replicas=1, batch_size=8, ram_gb=4.0,
                  cold_start_s=0.0), wl.with_rate(2.0), 0.15, 0.30),
        (FleetSim(arch="gpu", replicas=2, batch_size=8,
                  cold_start_s=0.0), wl.with_rate(4.0), 0.15, 0.30),
        (FleetSim(arch="gpu", replicas=1, batch_size=8,
                  cold_start_s=0.0),
         Workload(n_requests=n, trace=request_default()).with_rate(2.0),
         0.25, 0.30),
    ]


def test_analytic_agrees_with_event_engine_on_overlap():
    """Acceptance: the closed form within a tested tolerance of the
    request-level engine on overlapping (stable) grid points."""
    for sim, wl, tol_mean, tol_p95 in _agreement_cases():
        rep = sim.run(wl.generate(42))
        ana = analytic_point(sim, wl)
        assert 0 < ana["rho"] < 1
        assert ana["mean_latency_s"] == pytest.approx(
            rep.mean_latency_s, rel=tol_mean), (sim.arch, sim.replicas)
        assert ana["latency_p95_s"] == pytest.approx(
            rep.latency_p95_s, rel=tol_p95), (sim.arch, sim.replicas)


def test_analytic_marks_overloaded_points_unstable():
    grid = ServingGrid(archs=("spirt",), replicas=(1,), ram_gb=(2.0,),
                       rate_rps=(0.1, 50.0),
                       workload=Workload(n_requests=10, rate_rps=1.0,
                                         prompt_tokens=256,
                                         decode_tokens=64))
    sw = serving_sweep_analytic(grid)
    assert bool(sw.stable[0]) and not bool(sw.stable[1])
    assert np.isinf(sw.latency_p95_s[1])
    assert np.isfinite(sw.latency_p95_s[0])
    # percentiles are ordered where finite
    assert sw.latency_p50_s[0] <= sw.latency_p95_s[0] \
        <= sw.latency_p99_s[0]


def test_analytic_sweep_covers_all_registered_archs():
    from repro.serverless.archs import list_archs
    sw = serving_sweep_analytic(ServingGrid(replicas=(1, 2),
                                            ram_gb=(2.0,),
                                            rate_rps=(0.5, 1.0)))
    assert set(sw.arch) == set(list_archs())
    assert len(sw) == len(list_archs()) * 2 * 2


def test_bench_payload_reproducible_and_only_guard(tmp_path,
                                                   monkeypatch):
    """BENCH_serving.json is bit-reproducible from (grid, seed), and a
    --only partial run never overwrites the tracked default (PR 4
    rule)."""
    from benchmarks import serving_sweep as bench
    monkeypatch.chdir(tmp_path)
    chart = str(tmp_path / "c.png")
    bench.run([], quick=True, json_path="BENCH_serving.json",
              chart=chart)
    first = (tmp_path / "BENCH_serving.json").read_text()
    bench.run([], quick=True, json_path="BENCH_serving.json",
              chart=chart)
    second = (tmp_path / "BENCH_serving.json").read_text()
    import json
    a, b = json.loads(first), json.loads(second)
    a.pop("throughput"), b.pop("throughput")       # wall-clock timings
    assert a == b
    (tmp_path / "BENCH_serving.json").write_text("sentinel")
    bench.run([], quick=True, json_path="BENCH_serving.json",
              only="pareto", chart=chart)
    assert (tmp_path / "BENCH_serving.json").read_text() == "sentinel"
    # an explicit non-default path IS honoured for partial runs
    bench.run([], quick=True, json_path=str(tmp_path / "part.json"),
              only="pareto", chart=chart)
    assert (tmp_path / "part.json").exists()


@pytest.mark.slow
def test_analytic_grid_throughput_floor():
    """Acceptance floor: >= 1M simulated requests per wall-clock second
    on the analytic grid (run explicitly with `pytest -m slow`;
    timing-sensitive)."""
    import time
    grid = ServingGrid(replicas=(1, 2, 4, 8),
                       ram_gb=(1.0, 2.0, 3.0, 4.0),
                       rate_rps=(0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0,
                                 16.0))
    serving_sweep_analytic(grid)                   # warm
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()  # repro: allow[no-wallclock] -- slow-marked perf floor measures real throughput
        sw = serving_sweep_analytic(grid)
        best = min(best, time.perf_counter() - t0)  # repro: allow[no-wallclock] -- slow-marked perf floor measures real throughput
    rate = sw.requests_simulated / best
    assert rate >= 1e6, (rate, len(sw), best)
