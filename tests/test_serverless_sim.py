"""Analytic-simulator invariants + event-runtime agreement.

The closed-form ``simulate_epoch`` is the paper's Table 2 engine; these
tests pin its orderings (ISSUE 1 satellite): SPIRT's amortized sync
beats AllReduce's master bottleneck, AllReduce total sync grows
superlinearly with fleet size, the cost arithmetic matches the paper's
reported Table 2 numbers, and the discrete-event runtime reduces to the
analytic numbers when no faults are injected.
"""
import pytest

from repro.serverless import (PAPER_TABLE2, ServerlessSetup, run_event_epoch,
                              simulate_epoch)
from repro.serverless.simulator import ARCHS, paper_cost_check

N_PARAMS = int(4.2e6)
COMP = 0.9


def _epoch(arch, n_workers=4):
    return simulate_epoch(arch, n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup(n_workers=n_workers))


def test_spirt_sync_beats_allreduce():
    """Amortized P2P sync < master-bottleneck sync at equal params/W."""
    assert _epoch("spirt").stages.sync < _epoch("allreduce").stages.sync


def test_allreduce_sync_superlinear_in_workers():
    """Total (fleet-wide) AllReduce sync grows faster than linearly in W
    — the serial master path is the paper's §4.2 scalability wall."""
    total = {W: W * _epoch("allreduce", n_workers=W).stages.sync
             for W in (4, 8, 16)}
    assert total[8] > 2.0 * total[4]
    assert total[16] > 2.0 * total[8]


def test_spirt_comm_cheaper_than_mlless_per_epoch():
    """Single sync per accumulation round < per-minibatch supervised
    sync (Table 2's MLLess blow-up)."""
    assert _epoch("spirt").per_worker_s < _epoch("mlless").per_worker_s


@pytest.mark.parametrize("model", ["mobilenet", "resnet18"])
@pytest.mark.parametrize("arch", list(ARCHS))
def test_paper_cost_check_within_15pct(model, arch):
    r = paper_cost_check(model, arch)
    rel = abs(r["our_total"] - r["paper_total"]) / r["paper_total"]
    assert rel < 0.15, (model, arch, r)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_event_runtime_matches_analytic_fault_free(arch):
    """The event engine's fault-free makespan/cost ARE the analytic
    numbers (simulate_epoch is its validated fast path)."""
    ana = _epoch(arch)
    rep = run_event_epoch(arch, n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup())
    assert rep.makespan_s == pytest.approx(ana.per_worker_s, rel=1e-9)
    assert rep.total_cost == pytest.approx(ana.total_cost, rel=1e-9)
    assert rep.recoveries == []
    assert rep.work_done_batches == pytest.approx(
        ServerlessSetup().n_workers * ServerlessSetup().batches_per_worker)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_event_runtime_stage_totals_match_analytic(arch):
    """Per-stage busy time (summed over W workers) = W x analytic."""
    W = 4
    ana = _epoch(arch)
    rep = run_event_epoch(arch, n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup())
    for stage in ("fetch", "compute", "sync", "update"):
        assert rep.stage_totals[stage] == pytest.approx(
            W * getattr(ana.stages, stage), rel=1e-9, abs=1e-12), stage
    assert rep.stage_totals["wait"] == pytest.approx(0.0, abs=1e-9)
