"""Analytic-simulator invariants + event-runtime agreement.

The closed-form ``simulate_epoch`` is the paper's Table 2 engine; these
tests pin its orderings (ISSUE 1 satellite): SPIRT's amortized sync
beats AllReduce's master bottleneck, AllReduce total sync grows
superlinearly with fleet size, the cost arithmetic matches the paper's
reported Table 2 numbers, and the discrete-event runtime reduces to the
analytic numbers when no faults are injected.
"""
import pytest

from repro.serverless import (PAPER_TABLE2, ServerlessSetup, run_event_epoch,
                              simulate_epoch)
from repro.serverless.simulator import ARCHS, paper_cost_check

N_PARAMS = int(4.2e6)
COMP = 0.9


def _epoch(arch, n_workers=4):
    return simulate_epoch(arch, n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup(n_workers=n_workers))


def test_spirt_sync_beats_allreduce():
    """Amortized P2P sync < master-bottleneck sync at equal params/W."""
    assert _epoch("spirt").stages.sync < _epoch("allreduce").stages.sync


def test_allreduce_sync_superlinear_in_workers():
    """Total (fleet-wide) AllReduce sync grows faster than linearly in W
    — the serial master path is the paper's §4.2 scalability wall."""
    total = {W: W * _epoch("allreduce", n_workers=W).stages.sync
             for W in (4, 8, 16)}
    assert total[8] > 2.0 * total[4]
    assert total[16] > 2.0 * total[8]


def test_spirt_comm_cheaper_than_mlless_per_epoch():
    """Single sync per accumulation round < per-minibatch supervised
    sync (Table 2's MLLess blow-up)."""
    assert _epoch("spirt").per_worker_s < _epoch("mlless").per_worker_s


@pytest.mark.parametrize("model", ["mobilenet", "resnet18"])
@pytest.mark.parametrize("arch", list(ARCHS))
def test_paper_cost_check_within_15pct(model, arch):
    r = paper_cost_check(model, arch)
    rel = abs(r["our_total"] - r["paper_total"]) / r["paper_total"]
    assert rel < 0.15, (model, arch, r)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_event_runtime_matches_analytic_fault_free(arch):
    """The event engine's fault-free makespan/cost ARE the analytic
    numbers (simulate_epoch is its validated fast path)."""
    ana = _epoch(arch)
    rep = run_event_epoch(arch, n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup())
    assert rep.makespan_s == pytest.approx(ana.per_worker_s, rel=1e-9)
    assert rep.total_cost == pytest.approx(ana.total_cost, rel=1e-9)
    assert rep.recoveries == []
    assert rep.work_done_batches == pytest.approx(
        ServerlessSetup().n_workers * ServerlessSetup().batches_per_worker)


def test_comm_bytes_counts_wire_bytes_not_latency():
    """ISSUE 2 satellite: comm_bytes_per_worker must derive from the
    RoundPlan's exact wire-byte terms — per-op latencies add seconds,
    never bytes.  Two channels with identical bandwidth but different
    latency therefore move identical bytes (the old
    ``sync_s * bandwidth`` formula inflated with latency)."""
    from repro.serverless.simulator import Channel, round_plan
    fast = Channel("fast", bandwidth_Bps=1e9, latency_s=0.0)
    slow = Channel("slow", bandwidth_Bps=1e9, latency_s=0.5)
    for arch in ARCHS:
        a = simulate_epoch(arch, n_params=N_PARAMS, compute_s_per_batch=COMP,
                           setup=ServerlessSetup(channel=fast))
        b = simulate_epoch(arch, n_params=N_PARAMS, compute_s_per_batch=COMP,
                           setup=ServerlessSetup(channel=slow))
        assert a.comm_bytes_per_worker == b.comm_bytes_per_worker, arch
        if arch != "gpu":               # gpu syncs via S3 regardless
            assert b.stages.sync > a.stages.sync, arch
        # and the report total is exactly rounds x per-round wire bytes
        plan = round_plan(arch, n_params=N_PARAMS, compute_s_per_batch=COMP,
                          setup=ServerlessSetup(channel=fast))
        assert a.comm_bytes_per_worker == \
            plan.n_rounds * plan.comm_bytes_per_round, arch


def test_comm_bytes_consistent_with_strategy_comm_bytes():
    """Where the serverless channel model and the SPMD Strategy model
    describe the same exchange, the byte counts must line up: the GPU
    baseline's push-one/fetch-all is exactly ParameterServer's W x G,
    and every architecture's external-channel traffic is bounded below
    by its strategy's logical collective volume."""
    np_ = pytest.importorskip("numpy")
    from repro.core import get_strategy
    from repro.serverless.simulator import _grad_bytes, round_plan
    W = 4
    setup = ServerlessSetup(n_workers=W)
    grads_like = [np_.zeros(N_PARAMS, np_.float32)]
    G = _grad_bytes(N_PARAMS)
    assert G == 4 * N_PARAMS

    def plan(arch, **kw):
        return round_plan(arch, n_params=N_PARAMS, compute_s_per_batch=COMP,
                          setup=setup, **kw)

    # exact: gpu push-1 + fetch-(W-1) == ParameterServer all-see-all
    ps = get_strategy("parameter_server")
    assert plan("gpu").comm_bytes_per_round == ps.comm_bytes(grads_like, W)

    # lower bound: external channels move at least the logical volume
    strategies = {
        "spirt": get_strategy("spirt"),
        "mlless": get_strategy("mlless"),
        "scatterreduce": get_strategy("scatterreduce"),
        "allreduce": ps,                # λML master == parameter server
        "gpu": ps,
    }
    for arch, strat in strategies.items():
        p = plan(arch, significant_fraction=0.3)
        if arch == "mlless":
            logical = strat.comm_bytes(grads_like, W,
                                       significant_fraction=0.3)
        else:
            logical = strat.comm_bytes(grads_like, W)
        assert p.comm_bytes_per_round >= logical, arch


@pytest.mark.parametrize("arch", list(ARCHS))
def test_event_runtime_stage_totals_match_analytic(arch):
    """Per-stage busy time (summed over W workers) = W x analytic."""
    W = 4
    ana = _epoch(arch)
    rep = run_event_epoch(arch, n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup())
    for stage in ("fetch", "compute", "sync", "update"):
        assert rep.stage_totals[stage] == pytest.approx(
            W * getattr(ana.stages, stage), rel=1e-9, abs=1e-12), stage
    assert rep.stage_totals["wait"] == pytest.approx(0.0, abs=1e-9)
