"""Multi-device tests run in subprocesses (the main pytest process must
keep the default 1-device backend — see conftest)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

from repro.compat import HAS_PARTIAL_MANUAL_SHARD_MAP  # noqa: E402

requires_partial_manual = pytest.mark.skipif(
    not HAS_PARTIAL_MANUAL_SHARD_MAP,
    reason="XLA SPMD partitioner crashes on partial-manual multi-device "
           "meshes with jax<0.5 (IsManualSubgroup check failure)")


def _run(code, devices=8, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_flash_decode_sharded():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.flash_decode import flash_decode_attention
    from repro.models.attention import decode_attention
    mesh = jax.make_mesh((4,), ("data",))
    B, L, KV, G, hd = 2, 64, 2, 3, 32
    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.randn(B, 1, KV*G, hd), jnp.float32)
    k = jnp.asarray(rs.randn(B, L, KV, hd), jnp.float32)
    v = jnp.asarray(rs.randn(B, L, KV, hd), jnp.float32)
    for window, pos in ((None, L-1), (48, L+7)):
        expect = decode_attention(q, k, v, jnp.asarray(pos), window=window)
        fn = shard_map(
            lambda q_, k_, v_: flash_decode_attention(
                q_, k_, v_, jnp.asarray(pos), axis_name="data",
                total_len=L, window=window),
            mesh=mesh, in_specs=(P(), P(None, "data"), P(None, "data")),
            out_specs=P(), check_vma=False, axis_names={"data"})
        got = jax.jit(fn)(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   atol=2e-5)
    print("OK")
    """)


@requires_partial_manual
def test_strategies_agree_across_real_data_shards():
    """4-way data parallel: allreduce == scatterreduce == PS, and dp
    sharding equals single-device training."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.core import build_train_step, get_strategy
    from repro import optim
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    r = np.random.RandomState(0)
    batch = {"tokens": r.randint(0, cfg.vocab_size, (8, 32)).astype(np.int32)}
    batch["labels"] = batch["tokens"]
    sums = {}
    for name in ("allreduce", "scatterreduce", "parameter_server",
                 "quantized_scatterreduce"):
        ts = build_train_step(model, optim.sgd(0.1), get_strategy(name),
                              mesh, data_axes=("data",))
        state = ts.init_state(jax.random.PRNGKey(0))
        b = {k: jax.device_put(v, ts.batch_shardings[k])
             for k, v in batch.items()}
        for _ in range(2):
            state, m = ts.step_fn(state, b)
        sums[name] = sum(float(jnp.sum(l.astype(jnp.float32)))
                         for l in jax.tree.leaves(state["params"]))
    assert abs(sums["allreduce"] - sums["scatterreduce"]) < 1e-4
    assert abs(sums["allreduce"] - sums["parameter_server"]) < 1e-4
    assert abs(sums["allreduce"] - sums["quantized_scatterreduce"]) < 0.5
    print("OK", sums)
    """)


def test_quantized_scatterreduce_tuple_axis_parity():
    """QuantizedScatterReduce on a REAL 4-device fleet, string axis vs
    tuple-of-axes (2x2 mesh): both must agree with the fp32 ring mean
    to quantization tolerance, and with each other bitwise — W (the
    scatter row count) and the collectives' device ordering come from
    the same normalized axes, so a 2-axis data mesh cannot reassemble
    chunks permuted."""
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.compression import QuantizedScatterReduce

    g = jnp.asarray(np.random.RandomState(0).randn(4, 1030),
                    jnp.float32)
    qsr = QuantizedScatterReduce(chunk=64)

    def run(mesh, axes, spec):
        def body(x):
            out, resid, _ = qsr.sync([x[0]], [jnp.zeros_like(x[0])],
                                     axes)
            return out[0]
        f = shard_map(body, mesh=mesh, in_specs=P(spec), out_specs=P(),
                      check_vma=False)
        return np.asarray(f(g))

    flat = run(Mesh(np.array(jax.devices()), ("data",)), "data", "data")
    grid = run(Mesh(np.array(jax.devices()).reshape(2, 2), ("a", "b")),
               ("a", "b"), ("a", "b"))
    want = np.asarray(jnp.mean(g, axis=0))
    # fp32 ring baseline within two quantization steps
    step = float(np.abs(np.asarray(g)).max()) / 127.0
    np.testing.assert_allclose(flat, want, atol=2 * step)
    np.testing.assert_allclose(grid, want, atol=2 * step)
    # same normalized layout -> bitwise identical across mesh shapes
    np.testing.assert_array_equal(flat, grid)
    print("OK")
    """, devices=4)


def test_quantized_scatterreduce_rejects_empty_axes():
    _run("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.compression import QuantizedScatterReduce
    try:
        QuantizedScatterReduce().sync([jnp.ones(8)], [jnp.zeros(8)], ())
    except ValueError as e:
        assert "at least one mesh axis" in str(e)
        print("OK")
    else:
        raise SystemExit("expected ValueError")
    """, devices=1)


@pytest.mark.slow
@requires_partial_manual
def test_dryrun_one_combo_small():
    """End-to-end dry-run driver on the real 512-device production mesh
    for the cheapest (arch, shape) pair."""
    out = _run("""
    from repro.launch import dryrun
    r = dryrun.dryrun_one("smollm-135m", "long_500k", save=False)
    assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert r["memory"]["peak_estimate_gb"] < 16.0
    print("OK", r["roofline"]["dominant"])
    """, devices=512)
    assert "OK" in out
