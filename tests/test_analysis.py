"""repro-lint: fixture-corpus pins (exact rule_id + line per rule),
engine determinism/suppression properties, registry contracts, CLI
exit codes, plugin loading, and the tracer-field runtime backstop."""
import dataclasses
import functools
import json
import os
import runpy
import subprocess
import sys
from pathlib import Path

import pytest

try:                                   # property tests ride along when
    import hypothesis.strategies as st  # hypothesis is available; the
    from hypothesis import given, settings  # deterministic twins below
    HAVE_HYPOTHESIS = True             # always run
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.analysis import (Finding, RuleSpec, analyze_paths,
                            analyze_sources, registry)
from repro.analysis.report import render_json

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "analysis_fixtures"

# the pinned contract: every built-in rule fires on its fixture at
# exactly these (path, line, rule_id) triples — nothing more, nothing
# less.  A rule edit that shifts any of these is a behaviour change.
EXPECTED = frozenset({
    ("archs/unbounded_async.py", 11, "staleness-spec"),
    ("archs/unbounded_async.py", 19, "staleness-spec"),
    ("archs/unbounded_async.py", 31, "staleness-spec"),
    ("archs/unbounded_async.py", 41, "staleness-spec"),
    ("kernels/fancy.py", 8, "kernel-ref-parity"),
    ("kernels/fancy.py", 12, "kernel-ref-parity"),
    ("kernels/interp_default.py", 10, "kernel-interpret-default"),
    ("kernels/interp_default.py", 16, "kernel-interpret-default"),
    ("reporting/wallclock.py", 7, "no-wallclock"),
    ("reporting/wallclock.py", 8, "no-wallclock"),
    ("serverless/global_rng.py", 6, "seeded-rng"),
    ("serverless/global_rng.py", 8, "seeded-rng"),
    ("serverless/global_rng.py", 12, "seeded-rng"),
    ("serverless/global_rng.py", 16, "seeded-rng"),
    ("src/tuning/mutate_spec.py", 9, "frozen-spec-mutation"),
    ("src/tuning/mutate_spec.py", 14, "frozen-spec-mutation"),
    ("src/tuning/mutate_spec.py", 18, "frozen-spec-mutation"),
    ("src/tuning/mutate_spec.py", 19, "frozen-spec-mutation"),
    ("traced/jit_sync.py", 8, "trace-safety"),
    ("traced/jit_sync.py", 9, "trace-safety"),
    ("traced/jit_sync.py", 11, "trace-safety"),
    ("traced/jit_sync.py", 12, "trace-safety"),
})
EXPECTED_LIST = sorted(EXPECTED)
BUILTIN_RULES = ("seeded-rng", "no-wallclock", "frozen-spec-mutation",
                 "trace-safety", "kernel-ref-parity",
                 "kernel-interpret-default", "staleness-spec")


@functools.lru_cache(maxsize=1)
def _sources():
    return {p.relative_to(FIXTURES).as_posix(): p.read_text()
            for p in sorted(FIXTURES.rglob("*.py"))}


@functools.lru_cache(maxsize=1)
def _result():
    return analyze_sources(_sources())


# ---------------------------------------------------------------------------
# fixture corpus: each rule fires exactly where pinned
# ---------------------------------------------------------------------------
def test_every_rule_fires_at_pinned_lines():
    got = {(f.path, f.line, f.rule_id) for f in _result().findings}
    assert got == EXPECTED


@pytest.mark.parametrize("rule_id", BUILTIN_RULES)
def test_each_rule_represented(rule_id):
    assert any(r == rule_id for _, _, r in EXPECTED)
    only = analyze_sources(_sources(), rules=[rule_id])
    got = {(f.path, f.line, f.rule_id) for f in only.findings}
    assert got == {t for t in EXPECTED if t[2] == rule_id}


def test_fixture_run_from_disk_matches_in_memory():
    disk = analyze_paths(["."], root=str(FIXTURES))
    assert disk.findings == _result().findings
    assert disk.suppressed == _result().suppressed


def test_reasoned_suppression_is_honoured():
    sup = {(f.path, f.line, f.rule_id) for f in _result().suppressed}
    assert sup == {("reporting/wallclock.py", 13, "no-wallclock")}
    assert _result().exit_code == 1


def test_trace_safety_names_the_jitted_entry():
    msgs = [f.message for f in _result().findings
            if f.rule_id == "trace-safety"]
    assert msgs and all(
        "reachable from jitted entry 'step'" in m for m in msgs)


# ---------------------------------------------------------------------------
# engine properties: suppression totality + purity.  Deterministic
# versions always run; hypothesis widens the input space when present.
# ---------------------------------------------------------------------------
def _check_suppression_moves_finding(idx, reason):
    """Appending a reasoned allow[] to any violating line moves that
    finding (and only that finding) to the suppressed list."""
    path, line, rule_id = EXPECTED_LIST[idx]
    sources = dict(_sources())
    lines = sources[path].splitlines()
    lines[line - 1] += f"  {_ALLOW}[{rule_id}] -- {reason}"
    sources[path] = "\n".join(lines) + "\n"
    res = analyze_sources(sources)
    got = {(f.path, f.line, f.rule_id) for f in res.findings}
    assert (path, line, rule_id) not in got
    assert got == EXPECTED - {(path, line, rule_id)}
    assert (path, line, rule_id) in {
        (f.path, f.line, f.rule_id) for f in res.suppressed}


def _check_order_independence(order):
    """Same contents in any insertion order → byte-identical report
    (the lint-level twin of the BENCH content-hash rule)."""
    src = _sources()
    res = analyze_sources({k: src[k] for k in order})
    assert res.findings == _result().findings
    assert render_json(res) == render_json(_result())


@pytest.mark.parametrize("idx", range(len(EXPECTED_LIST)))
def test_suppressed_lines_never_report(idx):
    _check_suppression_moves_finding(idx, "pinned fixture reason")


def test_findings_pure_function_of_contents():
    _check_order_independence(sorted(_sources(), reverse=True))


if HAVE_HYPOTHESIS:
    @given(idx=st.integers(0, len(EXPECTED_LIST) - 1),
           reason=st.text(
               st.characters(min_codepoint=33, max_codepoint=126),
               min_size=1, max_size=16))
    @settings(max_examples=30, deadline=None)
    def test_suppressed_lines_never_report_fuzz(idx, reason):
        _check_suppression_moves_finding(idx, reason)

    @given(order=st.permutations(sorted(_sources())))
    @settings(max_examples=20, deadline=None)
    def test_findings_pure_function_of_contents_fuzz(order):
        _check_order_independence(order)


def test_json_report_has_no_environment():
    payload = json.loads(render_json(_result()))
    assert set(payload) == {"version", "rules", "n_files", "findings",
                            "suppressed"}
    assert payload["version"] == 1
    assert [r["id"] for r in payload["rules"]] == list(BUILTIN_RULES)
    assert all(r["contract"] for r in payload["rules"])


# ---------------------------------------------------------------------------
# engine-owned findings: bad suppressions and unparseable files
# ---------------------------------------------------------------------------
# built by concatenation so this test file's own lines never look like
# suppression markers to the line-based parser
_ALLOW = "# repro" + ": allow"


def test_suppression_without_reason_is_a_finding():
    res = analyze_sources(
        {"a.py": f"import time\nx = 1  {_ALLOW}[no-wallclock]\n"})
    assert [(f.line, f.rule_id) for f in res.findings] == \
        [(2, "bad-suppression")]


def test_suppression_without_rules_is_a_finding():
    res = analyze_sources({"a.py": f"x = 1  {_ALLOW}[] -- because\n"})
    assert [(f.line, f.rule_id) for f in res.findings] == \
        [(1, "bad-suppression")]


def test_bad_suppression_cannot_be_registered_or_suppressed():
    with pytest.raises(ValueError, match="reserved"):
        RuleSpec(rule_id="bad-suppression", description="x",
                 check=lambda ctx: [])


def test_syntax_error_is_a_finding():
    res = analyze_sources({"broken.py": "def (:\n"})
    assert [(f.path, f.rule_id) for f in res.findings] == \
        [("broken.py", "syntax-error")]
    assert res.exit_code == 1


# ---------------------------------------------------------------------------
# registry contracts (mirrors serverless.archs semantics)
# ---------------------------------------------------------------------------
def test_builtin_rules_registered_in_order():
    assert registry.list_rules()[:len(BUILTIN_RULES)] == BUILTIN_RULES


def test_duplicate_registration_is_an_error():
    spec = RuleSpec(rule_id="seeded-rng", description="imposter",
                    check=lambda ctx: [])
    with pytest.raises(ValueError, match="already registered"):
        registry.register_rule(spec)


def test_unknown_rule_error_names_registered():
    with pytest.raises(ValueError, match="unknown rule .*seeded-rng"):
        registry.get_rule("no-such-rule")


def test_rule_id_must_be_kebab_case():
    for bad in ("CamelCase", "snake_case", "-leading", "trailing-", ""):
        with pytest.raises(ValueError, match="kebab-case"):
            RuleSpec(rule_id=bad, description="x", check=lambda ctx: [])


def test_rulespec_is_frozen():
    spec = registry.get_rule("seeded-rng")
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.rule_id = "other"


# ---------------------------------------------------------------------------
# third-party rules: examples/custom_rule.py
# ---------------------------------------------------------------------------
def test_custom_rule_registers_and_fires():
    registry.unregister_rule("hidden-seed-default")
    runpy.run_path(str(REPO / "examples" / "custom_rule.py"))
    try:
        res = analyze_sources(
            {"m.py": "def gen(seed=0):\n    return seed\n"},
            rules=["hidden-seed-default"])
        assert [(f.rule_id, f.line) for f in res.findings] == \
            [("hidden-seed-default", 1)]
        clean = analyze_sources(
            {"m.py": "def gen(seed):\n    return seed\n"},
            rules=["hidden-seed-default"])
        assert not clean.findings
    finally:
        registry.unregister_rule("hidden-seed-default")


# ---------------------------------------------------------------------------
# CLI: stable exit codes, json mode, plugins
# ---------------------------------------------------------------------------
def _cli(*args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd, env=env)


def test_cli_fixture_corpus_exits_1():
    p = _cli(".", "--root", str(FIXTURES))
    assert p.returncode == 1, p.stderr
    assert "[seeded-rng]" in p.stdout


def test_cli_self_run_is_clean_json():
    p = _cli("src", "tests", "benchmarks", "examples", "--format", "json")
    assert p.returncode == 0, p.stdout + p.stderr
    payload = json.loads(p.stdout)
    assert payload["findings"] == []
    # every suppression in the tree carries a reasoned allow[]
    assert payload["suppressed"], "expected reasoned suppressions"


def test_cli_list_rules():
    p = _cli("--list-rules")
    assert p.returncode == 0
    for rid in BUILTIN_RULES:
        assert rid in p.stdout


def test_cli_plugin_pickup(tmp_path):
    (tmp_path / "mod.py").write_text("def gen(seed=42):\n    return 1\n")
    p = _cli("--plugin", "examples/custom_rule.py",
             "--rules", "hidden-seed-default",
             ".", "--root", str(tmp_path))
    assert p.returncode == 1, p.stdout + p.stderr
    assert "hidden-seed-default" in p.stdout
    assert "seed=42" in p.stdout


def test_cli_unknown_rule_fails_loudly():
    p = _cli("--rules", "nope", ".", "--root", str(FIXTURES))
    assert p.returncode not in (0, 1)


# ---------------------------------------------------------------------------
# runtime backstop: report dataclasses reject tracer fields
# ---------------------------------------------------------------------------
def _fleet_report(**over):
    from repro.serving.fleet import FleetReport
    kw = dict(arch="cpu_serverless", n_requests=1, makespan_s=1.0,
              latency_p50_s=0.1, latency_p95_s=0.2, latency_p99_s=0.3,
              ttft_p50_s=0.05, ttft_p95_s=0.06, mean_latency_s=0.1,
              throughput_rps=1.0, tokens_generated=10, total_cost=0.01,
              usd_per_1k_requests=1.0, peak_replicas=1,
              replica_seconds=1.0, n_cold_starts=0)
    kw.update(over)
    return FleetReport(**kw)


def _runtime_report(**over):
    from repro.serverless.runtime import RuntimeReport
    kw = dict(arch="allreduce", makespan_s=1.0, analytic_s=1.0, rounds=1,
              work_done_batches=1.0, n_workers_start=1, n_workers_peak=1,
              n_workers_end=1, total_cost=0.1, stage_totals={},
              recoveries=[], poisoned_updates=0, masked_updates=0,
              scale_events=[], timeline=[])
    kw.update(over)
    return RuntimeReport(**kw)


def test_reports_accept_concrete_values():
    assert _fleet_report().makespan_s == 1.0
    assert _runtime_report().time_to_recover_s == 0.0


def test_fleet_report_rejects_tracer_field():
    import jax
    import jax.numpy as jnp

    def build(x):
        _fleet_report(makespan_s=x)
        return x

    with pytest.raises(TypeError, match="tracer"):
        jax.jit(build)(jnp.float32(1.0))


def test_runtime_report_rejects_tracer_in_container():
    import jax
    import jax.numpy as jnp

    def build(x):
        _runtime_report(stage_totals={"compute": x})
        return x

    with pytest.raises(TypeError, match="tracer"):
        jax.jit(build)(jnp.float32(1.0))
