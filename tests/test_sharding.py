"""Property tests for the divisibility-aware sharder."""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import numpy as np
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.core.sharding import cache_pspecs, leaf_pspec


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Shape-only stand-in (leaf_pspec reads only mesh.shape)."""
    def __init__(self, **shape):
        self.shape = shape


@given(dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       msize=st.sampled_from([2, 4, 16]),
       dsize=st.sampled_from([2, 16, 32]))
@settings(max_examples=100, deadline=None)
def test_leaf_pspec_always_legal(dims, msize, dsize):
    """Every assigned axis divides its dim; no axis appears twice."""
    mesh = _FakeMesh(model=msize, data=dsize)
    spec = leaf_pspec(tuple(dims), mesh, model_axis="model",
                      data_axes=("data",), fsdp=True)
    seen = []
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        for e in entries:
            assert e not in seen
            seen.append(e)
        size = np.prod([mesh.shape[e] for e in entries])
        assert dim % size == 0


@given(dims=st.lists(st.integers(1, 512), min_size=2, max_size=4))
@settings(max_examples=50, deadline=None)
def test_leaf_pspec_no_model_axis_profile(dims):
    mesh = _FakeMesh(model=16, data=16)
    spec = leaf_pspec(tuple(dims), mesh, model_axis=None)
    assert all(e is None for e in spec)


def test_skip_leading_never_shards_stack_dim():
    mesh = _FakeMesh(model=4, data=4)
    spec = leaf_pspec((4, 64, 64), mesh, skip_leading=True,
                      data_axes=("data",), fsdp=True)
    assert spec[0] is None


def test_quant_cache_payload_and_scale_align():
    """int8 payload and its (.., KV, 1) scales must pick the same
    model-axis dim (KV) so no resharding separates them."""
    mesh = _FakeMesh(model=16, data=16)
    import jax.numpy as jnp
    cache = {"blocks": [{"k": {
        "q": jax.ShapeDtypeStruct((32, 2, 512, 32, 96), jnp.int8),
        "scale": jax.ShapeDtypeStruct((32, 2, 512, 32, 1), jnp.float16),
    }}]}
    specs = cache_pspecs(cache, mesh, batch_axes=("data",))
    k = specs["blocks"][0]["k"]
    assert k["q"][3] == "model" and k["scale"][3] == "model"
