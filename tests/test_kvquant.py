"""int8 KV-cache quantization: accuracy vs full-precision decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import build_model
from repro.models.kvquant import dequantize_kv, quantize_kv


def test_quant_roundtrip_bound():
    x = jnp.asarray(np.random.RandomState(0).randn(4, 16, 2, 32),
                    jnp.float32)
    q, s = quantize_kv(x)
    err = jnp.abs(dequantize_kv(q, s) - x)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    assert bool(jnp.all(err <= bound + 1e-6))


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma3-4b"])
def test_quant_decode_close_to_exact(arch):
    cfg = get_config(arch).reduced()
    model_fp = build_model(cfg, remat=False)
    model_q = build_model(cfg, remat=False, kv_quant=True)
    params = model_fp.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    logits_fp, cache_fp = jax.jit(
        lambda p, b: model_fp.prefill(p, b, cache_len=S + 1))(
        params, {"tokens": toks[:, :S]})
    logits_q, cache_q = jax.jit(
        lambda p, b: model_q.prefill(p, b, cache_len=S + 1))(
        params, {"tokens": toks[:, :S]})
    # prefill logits identical (cache only affects decode)
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_fp),
                               atol=1e-4)
    d_fp, _ = jax.jit(lambda p, t, c: model_fp.decode_step(p, t, c, S))(
        params, toks[:, S:], cache_fp)
    d_q, _ = jax.jit(lambda p, t, c: model_q.decode_step(p, t, c, S))(
        params, toks[:, S:], cache_q)
    # int8 cache error stays small in logit space and preserves argmax
    err = np.abs(np.asarray(d_q - d_fp)).max()
    scale = np.abs(np.asarray(d_fp)).max()
    assert err / scale < 0.05, (err, scale)
    agree = (np.asarray(jnp.argmax(d_q, -1)) ==
             np.asarray(jnp.argmax(d_fp, -1))).mean()
    assert agree == 1.0


def test_quant_cache_half_the_bytes():
    cfg = get_config("phi3-mini-3.8b").reduced()
    m_fp = build_model(cfg, remat=False)
    m_q = build_model(cfg, remat=False, kv_quant=True)
    def nbytes(c):
        return sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(c))
    b_fp = nbytes(jax.eval_shape(lambda: m_fp.init_cache(2, 512)))
    b_q = nbytes(jax.eval_shape(lambda: m_q.init_cache(2, 512)))
    assert b_q < 0.6 * b_fp    # int8 payload + fp16 scales vs fp32
