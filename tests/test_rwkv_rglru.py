"""Recurrence equivalence: the chunked/parallel training forms must match
exact step-by-step recurrences (the decode path) token for token."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import rglru, rwkv6


def _cfg(name):
    return get_config(name).reduced(d_model=128)


def test_rwkv_chunked_equals_stepwise():
    cfg = _cfg("rwkv6-7b")
    p = rwkv6.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 37                       # deliberately not a chunk multiple
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))

    y_par, state_par = rwkv6.rwkv_apply(p, x, cfg, chunk=16)

    state = rwkv6.rwkv_init_state(cfg, B, x.dtype)
    ys = []
    for t in range(T):
        y_t, state = rwkv6.rwkv_decode_step(p, x[:, t:t + 1], cfg, state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_par["S"]),
                               np.asarray(state["S"]), atol=2e-4)


def test_rwkv_state_carry_across_segments():
    """apply(x) == apply(x[:, :k]) then apply(x[:, k:], state)."""
    cfg = _cfg("rwkv6-7b")
    p = rwkv6.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T, k = 1, 48, 19
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model))
    y_full, _ = rwkv6.rwkv_apply(p, x, cfg, chunk=16)
    y1, st = rwkv6.rwkv_apply(p, x[:, :k], cfg, chunk=16)
    y2, _ = rwkv6.rwkv_apply(p, x[:, k:], cfg, state=st, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4)


def test_rglru_scan_equals_stepwise():
    cfg = _cfg("recurrentgemma-2b")
    p = rglru.rglru_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 29
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (B, T, cfg.d_model))

    y_par, state_par = rglru.rglru_apply(p, x, cfg)

    state = rglru.rglru_init_state(cfg, B, x.dtype)
    ys = []
    for t in range(T):
        y_t, state = rglru.rglru_decode_step(p, x[:, t:t + 1], cfg, state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_par["h"]),
                               np.asarray(state["h"]), atol=2e-4)


def test_rwkv_pallas_kernel_path_matches_jnp():
    """use_pallas=True routes WKV through the Pallas kernel with a
    custom-VJP backward — forward and grads must match the jnp path."""
    from repro.models import build_model
    cfg = _cfg("rwkv6-7b")
    m1 = build_model(cfg, remat=False)
    m2 = build_model(cfg, remat=False, use_pallas=True)
    params = m1.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, cfg.vocab_size)}
    y1, _ = jax.jit(m1.apply)(params, batch)
    y2, _ = jax.jit(m2.apply)(params, batch)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=1e-4)

    def loss(m):
        def f(p):
            logits, _ = m.apply(p, batch)
            return jnp.mean(logits.astype(jnp.float32) ** 2)
        return f
    g1 = jax.grad(loss(m1))(params)
    g2 = jax.grad(loss(m2))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
