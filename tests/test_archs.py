"""Registry contract + the satellites that ride ISSUE 4's refactor:
the gpu channel-pin fix, setup/rate validation, knee detection, and the
two beyond-paper hybrids flowing through every layer from a single
``archs.py`` registration.
"""
import dataclasses

import numpy as np
import pytest

from repro.serverless import (ARCHS, ArchSpec, CheckpointRestore,
                              EventSweepPoint, FaultPlan, FaultRates,
                              PeerTakeover, ServerlessSetup, SweepGrid,
                              default_recovery, get_arch, knee_point,
                              list_archs, register_arch, run_event_epoch,
                              simulate_epoch, sweep_analytic, sweep_events,
                              unregister_arch)
from repro.serverless.archs import _transfer
from repro.serverless.simulator import REDIS, S3, round_plan
from repro.serverless.sweep import _resolve_recovery, iter_grid, \
    scalar_sweep
from repro.serverless.traces import lambda_default

N_PARAMS = int(4.2e6)
HYBRIDS = ("hier_spirt", "spirt_s3")


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------
def test_paper_archs_unchanged():
    assert ARCHS == ("spirt", "mlless", "scatterreduce", "allreduce",
                     "gpu")
    assert all(get_arch(a).paper for a in ARCHS)


def test_list_archs_includes_hybrids_after_paper_five():
    names = list_archs()
    assert names[:5] == ARCHS
    for h in HYBRIDS:
        assert h in names and not get_arch(h).paper


def test_unknown_arch_raises():
    with pytest.raises(ValueError, match="unknown architecture"):
        get_arch("does_not_exist")
    with pytest.raises(ValueError, match="unknown architecture"):
        simulate_epoch("does_not_exist", n_params=N_PARAMS,
                       compute_s_per_batch=0.9)


def test_duplicate_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_arch(get_arch("spirt"))
    # overwrite=True is the explicit escape hatch
    register_arch(get_arch("spirt"), overwrite=True)


def test_register_unregister_roundtrip():
    spec = dataclasses.replace(get_arch("allreduce"),
                               name="_test_arch", paper=False)
    register_arch(spec)
    try:
        assert "_test_arch" in list_archs()
        a = simulate_epoch("_test_arch", n_params=N_PARAMS,
                           compute_s_per_batch=0.9)
        b = simulate_epoch("allreduce", n_params=N_PARAMS,
                           compute_s_per_batch=0.9)
        assert a.per_worker_s == b.per_worker_s
        assert a.total_cost == b.total_cost
    finally:
        unregister_arch("_test_arch")
    assert "_test_arch" not in list_archs()


@pytest.mark.parametrize("arch", list_archs())
def test_every_spec_roundtrips_plan_to_event_runtime(arch):
    """round_plan -> EventRuntime must reduce to the analytic epoch for
    EVERY registered spec (the simulate_epoch fast-path contract)."""
    ana = simulate_epoch(arch, n_params=N_PARAMS, compute_s_per_batch=0.9)
    rep = run_event_epoch(arch, n_params=N_PARAMS,
                          compute_s_per_batch=0.9)
    assert rep.makespan_s == pytest.approx(ana.per_worker_s, rel=1e-9)
    assert rep.total_cost == pytest.approx(ana.total_cost, rel=1e-9)
    plan = round_plan(arch, n_params=N_PARAMS, compute_s_per_batch=0.9)
    assert plan.n_rounds >= 1
    if get_arch(arch).staleness_penalty:
        # staleness-taxed archs converge with strictly MORE work than
        # the nominal epoch — that is the convergence penalty
        assert plan.total_batches > 24
    else:
        assert plan.total_batches == 24


@pytest.mark.parametrize("arch", list_archs())
def test_default_recovery_follows_spec(arch):
    spec = get_arch(arch)
    pol = default_recovery(arch)
    want = PeerTakeover if spec.default_recovery == "takeover" \
        else CheckpointRestore
    assert isinstance(pol, want)
    point = EventSweepPoint(arch=arch, n_params=N_PARAMS,
                            compute_s_per_batch=0.9)
    assert isinstance(_resolve_recovery(point), want)


def test_spirt_family_defaults_to_takeover():
    for arch in ("spirt", "hier_spirt", "spirt_s3"):
        assert get_arch(arch).default_recovery == "takeover"
    assert get_arch("allreduce").default_recovery == "restore"


def test_sweep_rejects_unknown_recovery_string():
    point = EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                            compute_s_per_batch=0.9, recovery="takeovr")
    with pytest.raises(ValueError, match="unknown recovery"):
        _resolve_recovery(point)


def test_arch_spec_validates_default_recovery():
    with pytest.raises(ValueError, match="default_recovery"):
        dataclasses.replace(get_arch("spirt"), name="_bad",
                            default_recovery="peer_takeover")


def test_custom_arch_survives_spawned_sweep_workers():
    """Caller-registered specs must reach spawn-based sweep workers
    (the job carries the spec and the child re-registers it) — the
    extension point's multiprocessing contract."""
    spec = dataclasses.replace(get_arch("allreduce"),
                               name="_spawned_arch", paper=False)
    register_arch(spec)
    try:
        points = [EventSweepPoint(arch="_spawned_arch",
                                  n_params=N_PARAMS,
                                  compute_s_per_batch=0.9, label=str(i))
                  for i in range(2)]
        multi = sweep_events(points, rates=FaultRates(crash_rate=0.5),
                             n_replicates=2, seed=3, processes=2)
        inline = sweep_events(points, rates=FaultRates(crash_rate=0.5),
                              n_replicates=2, seed=3, processes=1)
        for a, b in zip(multi, inline):
            assert a.makespan_mean_s == b.makespan_mean_s
            assert a.cost_mean == b.cost_mean
    finally:
        unregister_arch("_spawned_arch")


def test_overwritten_builtin_spec_reaches_spawn_workers():
    """A parent-side overwrite=True replacement of a built-in spec must
    win over the child's fresh-import registration too."""
    from repro.serverless.archs import instance_fleet_cost
    original = get_arch("allreduce")
    register_arch(dataclasses.replace(original,
                                      fleet_cost=instance_fleet_cost),
                  overwrite=True)
    try:
        points = [EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                                  compute_s_per_batch=0.9, label=str(i))
                  for i in range(2)]
        multi = sweep_events(points, n_replicates=2, seed=3, processes=2)
        inline = sweep_events(points, n_replicates=2, seed=3,
                              processes=1)
        for a, b in zip(multi, inline):
            assert a.cost_mean == b.cost_mean     # both use the override
    finally:
        register_arch(original, overwrite=True)


def test_anchorless_spec_gets_clear_calibration_error():
    """A third-party spec without a Table-2 anchor must fail the
    anchored benchmarks with an actionable error, not a bare
    KeyError."""
    from repro.serverless.simulator import paper_compute_anchor
    spec = dataclasses.replace(get_arch("allreduce"),
                               name="_no_anchor", paper=False)
    register_arch(spec)
    try:
        with pytest.raises(ValueError, match="ArchSpec.anchor"):
            paper_compute_anchor("_no_anchor")
    finally:
        unregister_arch("_no_anchor")


def test_self_referential_jax_strategy_rejected():
    """jax_strategy naming the spec itself would make get_strategy
    recurse forever; make_strategy must fail fast instead."""
    spec = dataclasses.replace(get_arch("allreduce"), name="_selfref",
                               paper=False, jax_strategy="_selfref")
    register_arch(spec)
    try:
        with pytest.raises(ValueError, match="names itself"):
            spec.make_strategy()
    finally:
        unregister_arch("_selfref")


def test_run_event_epoch_accepts_recovery_strings():
    from repro.serverless import WorkerCrash
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=0.9,
              faults=FaultPlan(crashes=(WorkerCrash(1, 10.0),)))
    by_str = run_event_epoch("allreduce", recovery="takeover", **kw)
    by_obj = run_event_epoch("allreduce", recovery=PeerTakeover(), **kw)
    assert by_str.makespan_s == by_obj.makespan_s
    assert [r.mode for r in by_str.recoveries] == ["takeover"]
    assert [r.mode
            for r in run_event_epoch("allreduce", recovery="restore",
                                     **kw).recoveries] == ["restore"]
    with pytest.raises(ValueError, match="unknown recovery"):
        run_event_epoch("allreduce", recovery="bogus", **kw)


def test_spec_names_jax_strategy():
    """Sim arch and real-training arch are one object: get_strategy
    resolves arch names through the registry."""
    pytest.importorskip("jax")
    from repro.core import get_strategy
    assert get_strategy("gpu").name == "allreduce"
    assert get_strategy("hier_spirt").name == "spirt"
    assert get_strategy("hier_spirt").microbatches == 4
    with pytest.raises(KeyError):
        get_strategy("no_such_strategy")


@pytest.mark.parametrize("arch,want", [("spirt", "spirt"),
                                       ("mlless", "mlless"),
                                       ("scatterreduce", "scatterreduce"),
                                       ("allreduce", "parameter_server"),
                                       ("gpu", "allreduce"),
                                       ("hier_spirt", "spirt"),
                                       ("spirt_s3", "spirt"),
                                       ("local_sgd", "spirt"),
                                       ("async_spirt", "spirt"),
                                       ("async_spirt_q8",
                                        "quantized_scatterreduce"),
                                       ("scatterreduce_q8",
                                        "quantized_scatterreduce"),
                                       ("spirt_sf", "mlless")])
def test_make_strategy_works_for_every_shipped_spec(arch, want):
    """Specs whose jax_strategy shares the arch name (spirt, mlless,
    scatterreduce back concrete STRATEGIES entries) must build fine —
    the self-reference guard only rejects names that would re-enter
    the registry."""
    pytest.importorskip("jax")
    assert get_arch(arch).make_strategy().name == want


# ---------------------------------------------------------------------------
# satellite: gpu channel pin (silent no-op channel axis fix)
# ---------------------------------------------------------------------------
def test_pinned_channel_marks_bogus_grid_points():
    """gpu x redis sweeps used to report Redis labels with S3 sync
    numbers; the spec's pin now marks them."""
    grid = SweepGrid(n_params=N_PARAMS, compute_s_per_batch=0.9,
                     archs=("allreduce", "gpu", "spirt_s3"),
                     channels=(REDIS, S3))
    vec = sweep_analytic(grid)
    for i in range(len(vec)):
        p = vec.point(i)
        spec = get_arch(p["arch"])
        assert p["channel_pinned"] == spec.pins_channel(p["channel"]), p
    # allreduce genuinely varies by channel -> never marked
    assert not vec.channel_pinned[vec.mask("allreduce")].any()
    # gpu/spirt_s3: exactly the redis-labelled half is marked...
    for arch in ("gpu", "spirt_s3"):
        m = vec.mask(arch)
        assert vec.channel_pinned[m].sum() == m.sum() // 2
        # ...and its sync numbers equal the honestly-labelled S3 row's
        redis_rows = m & vec.channel_pinned
        s3_rows = m & ~vec.channel_pinned
        np.testing.assert_array_equal(vec.sync_s[redis_rows],
                                      vec.sync_s[s3_rows])
        # drop_pinned removes exactly the marked rows
        assert (vec.mask(arch, drop_pinned=True) == s3_rows).all()
    # iter_grid carries the same flag, in the same layout
    flags = [p["channel_pinned"] for p in iter_grid(grid)]
    np.testing.assert_array_equal(flags, vec.channel_pinned)


def test_pinned_sync_identical_across_channels_end_to_end():
    for arch in ("gpu", "spirt_s3"):
        a = simulate_epoch(arch, n_params=N_PARAMS,
                           compute_s_per_batch=0.9,
                           setup=ServerlessSetup(channel=REDIS))
        b = simulate_epoch(arch, n_params=N_PARAMS,
                           compute_s_per_batch=0.9,
                           setup=ServerlessSetup(channel=S3))
        assert a.stages.sync == b.stages.sync, arch


# ---------------------------------------------------------------------------
# satellite: setup / rate validation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [dict(n_workers=0), dict(n_workers=-3),
                                dict(batches_per_worker=0),
                                dict(ram_gb=0.0), dict(ram_gb=-1.0),
                                dict(cold_start_s=-0.1),
                                dict(model_bytes=-1.0),
                                dict(minibatch_bytes=-8.0)])
def test_serverless_setup_rejects_invalid(kw):
    with pytest.raises(ValueError):
        ServerlessSetup(**kw)


@pytest.mark.parametrize("kw", [dict(crash_rate=-0.1),
                                dict(straggler_rate=-1.0),
                                dict(byzantine_fraction=-0.5),
                                dict(storm_prob=-0.01)])
def test_fault_rates_reject_negative(kw):
    with pytest.raises(ValueError):
        FaultRates(**kw)


def test_valid_boundaries_accepted():
    ServerlessSetup(n_workers=1, batches_per_worker=1, ram_gb=0.125,
                    cold_start_s=0.0)
    FaultRates()                    # all-zero is the fault-free default
    FaultRates(crash_rate=1.0, byzantine_fraction=2.0)  # clamped later


# ---------------------------------------------------------------------------
# satellite: knee detection
# ---------------------------------------------------------------------------
def test_knee_point_finds_the_bend():
    x = np.linspace(0.0, 1.0, 11)
    # flat until 0.6, then a sharp linear take-off: the knee is the bend
    y = np.where(x <= 0.6, 0.01 * x, 0.01 * x + 8.0 * (x - 0.6))
    k = knee_point(x, y)
    assert x[k] == pytest.approx(0.6, abs=0.101)
    # order-invariant: indexes back into the ORIGINAL array
    perm = np.random.RandomState(0).permutation(len(x))
    k2 = knee_point(x[perm], y[perm])
    assert x[perm][k2] == x[k]


def test_knee_point_rejects_degenerate_inputs():
    with pytest.raises(ValueError):
        knee_point([0.0, 1.0], [0.0, 1.0])           # too few points
    with pytest.raises(ValueError):
        knee_point([0, 1, 2], [1.0, 1.0, 1.0])       # flat y
    with pytest.raises(ValueError):
        knee_point([1, 1, 1], [0.0, 0.5, 1.0])       # no x spread


# ---------------------------------------------------------------------------
# hybrids: defined solely in archs.py, present at every layer
# ---------------------------------------------------------------------------
def test_hybrids_flow_through_analytic_sweep():
    grid = SweepGrid(n_params=N_PARAMS, compute_s_per_batch=0.9,
                     archs=list_archs(), n_workers=(4, 16))
    vec = sweep_analytic(grid)
    sca = scalar_sweep(grid)
    for i, rep in enumerate(sca):        # vectorized == scalar, 7 archs
        assert vec.per_worker_s[i] == rep.per_worker_s, i
        assert vec.total_cost[i] == rep.total_cost, i
    for h in HYBRIDS:
        assert vec.mask(h).sum() == 2


def test_hier_spirt_flattens_sync_wall_at_scale():
    """The hierarchy's point: cross-group chunk exchange beats flat
    SPIRT's (W-1) full-gradient fan-in once the fleet is large."""
    def sync(arch, W):
        return simulate_epoch(
            arch, n_params=N_PARAMS, compute_s_per_batch=0.9,
            setup=ServerlessSetup(n_workers=W)).stages.sync
    assert sync("hier_spirt", 16) < sync("spirt", 16)
    assert sync("hier_spirt", 64) < 0.5 * sync("spirt", 64)


def test_spirt_s3_isolates_redis_premium():
    """Same semantics as spirt, gradient path pinned to S3: slower sync
    at equal fetch/compute — the Redis premium, isolated."""
    a = simulate_epoch("spirt", n_params=N_PARAMS,
                       compute_s_per_batch=0.9)
    b = simulate_epoch("spirt_s3", n_params=N_PARAMS,
                       compute_s_per_batch=0.9)
    assert b.stages.sync > a.stages.sync
    assert b.stages.fetch == a.stages.fetch
    assert b.stages.compute == a.stages.compute


@pytest.mark.parametrize("arch", HYBRIDS)
def test_hybrids_flow_through_event_sweep_with_trace(arch):
    stats = sweep_events(
        [EventSweepPoint(arch=arch, n_params=N_PARAMS,
                         compute_s_per_batch=0.9)],
        rates=FaultRates(crash_rate=0.5), trace=lambda_default(),
        n_replicates=3, seed=11, processes=1)
    s = stats[0]
    assert s.makespan_mean_s >= s.analytic_makespan_s
    # deterministic from (points, trace, seed)
    again = sweep_events(
        [EventSweepPoint(arch=arch, n_params=N_PARAMS,
                         compute_s_per_batch=0.9)],
        rates=FaultRates(crash_rate=0.5), trace=lambda_default(),
        n_replicates=3, seed=11, processes=1)[0]
    assert again.makespan_mean_s == s.makespan_mean_s
    assert again.cost_mean == s.cost_mean


def test_hybrid_crash_recovers_via_takeover():
    from repro.serverless import WorkerCrash
    rep = run_event_epoch(
        "hier_spirt", n_params=N_PARAMS, compute_s_per_batch=0.9,
        faults=FaultPlan(crashes=(WorkerCrash(1, 10.0),)),
        recovery="auto")
    assert [r.mode for r in rep.recoveries] == ["takeover"]
    assert rep.n_workers_end == 3


def test_elementwise_term_contract():
    """A spec's round_terms must accept arrays (the vectorized sweep's
    calling convention) — probe the hybrids directly."""
    W = np.array([2, 4, 8, 16])
    for arch in HYBRIDS:
        t = get_arch(arch).round_terms(
            G=_transfer(0, 1, 0) + 16.8e6, W=W, bw=1.25e9, lat=0.002,
            sync_bw=1.25e9, sync_lat=0.002, nb=24,
            significant_fraction=0.3, accumulation=24)
        assert np.shape(t["sync_s"]) == W.shape
        assert (np.diff(np.broadcast_to(t["sync_bytes"], W.shape))
                >= 0).all()
