"""Integration tests: convergence, checkpointing, simulator, HLO parser."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import restore, save
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy, losses
from repro.data import cifar_like, lm_batches, token_stream
from repro.models import build_cnn, build_model
from repro.serverless import paper_cost_check, simulate_epoch


def test_lm_loss_decreases():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ts = build_train_step(model, optim.adamw(3e-3),
                          get_strategy("allreduce"), mesh)
    state = ts.init_state(jax.random.PRNGKey(0))
    stream = token_stream(100_000, cfg.vocab_size)
    batches = lm_batches(stream, 16, 64)
    losses_seen = []
    for i, b in zip(range(25), batches):
        state, metrics = ts.step_fn(state, jax.tree.map(jnp.asarray, b))
        losses_seen.append(float(metrics["loss"]))
    assert np.mean(losses_seen[-5:]) < np.mean(losses_seen[:5]) - 0.3


def test_cnn_learns_synthetic_cifar():
    cfg = get_config("mobilenet-cifar").reduced()
    model = build_cnn(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def loss_fn(params, b):
        logits, _ = model.apply(params, b)
        return losses.classification_loss(logits, b["labels"])

    ts = build_train_step(model, optim.sgd(0.05, momentum=0.9),
                          get_strategy("spirt"), mesh, loss_fn=loss_fn)
    state = ts.init_state(jax.random.PRNGKey(0))
    imgs, labels = cifar_like(2048, seed=0)
    rs = np.random.RandomState(0)
    for step in range(40):
        idx = rs.randint(0, len(imgs), 64)
        b = {"images": jnp.asarray(imgs[idx]),
             "labels": jnp.asarray(labels[idx])}
        state, metrics = ts.step_fn(state, b)
    test_imgs, test_labels = cifar_like(512, seed=7)
    logits, _ = jax.jit(model.apply)(state["params"],
                                     {"images": jnp.asarray(test_imgs)})
    acc = float(losses.accuracy(logits, jnp.asarray(test_labels)))
    assert acc > 0.25, acc           # well above 10% chance


def test_checkpoint_roundtrip():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        save(path, params)
        zeros = jax.tree.map(jnp.zeros_like, params)
        back = restore(path, zeros)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rejects_mismatch():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.msgpack")
        save(path, {"a": jnp.zeros((3,))})
        with pytest.raises(ValueError):
            restore(path, {"a": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            restore(path, {"a": jnp.zeros((3,)), "b": jnp.zeros((1,))})


# ---------------------------------------------------------------------------
# serverless simulator + cost model
# ---------------------------------------------------------------------------
def test_paper_table2_arithmetic_reproduces():
    """Our cost formulas must reproduce the paper's Table 2 USD numbers
    from its reported times/RAM (GPU exact; Lambda within rounding)."""
    for model in ("mobilenet", "resnet18"):
        for arch in ("spirt", "scatterreduce", "allreduce", "mlless"):
            r = paper_cost_check(model, arch)
            rel = abs(r["our_total"] - r["paper_total"]) / r["paper_total"]
            assert rel < 0.12, (model, arch, r)
        r = paper_cost_check(model, "gpu")
        assert abs(r["our_total"] - r["paper_total"]) / r["paper_total"] \
            < 0.01


def test_simulator_stage_structure():
    """Table 1 structure: every architecture decomposes into
    fetch/compute/sync/update; statelessness costs MLLess per batch while
    SPIRT amortizes (gradient accumulation)."""
    kw = dict(n_params=4_200_000, compute_s_per_batch=2.0)
    spirt = simulate_epoch("spirt", **kw)
    mlless = simulate_epoch("mlless", **kw)
    gpu = simulate_epoch("gpu", **kw)
    assert spirt.stages.fetch < mlless.stages.fetch   # fewer invocations
    # at accumulation=24 SPIRT runs a single invocation per epoch — its
    # load cost matches the stateful GPU baseline's one-time load
    assert gpu.stages.fetch <= spirt.stages.fetch
    for rep in (spirt, mlless, gpu):
        assert rep.stages.compute == pytest.approx(24 * 2.0)
        assert rep.total_cost > 0


def test_gpu_cheaper_for_heavy_models_crossover():
    """The paper's headline: serverless wins for light models, GPU wins
    as the model grows (Table 2 MobileNet vs ResNet-18 pattern)."""
    def costs(npar, comp_sls, comp_gpu, ram):
        from repro.serverless import ServerlessSetup
        s = simulate_epoch("scatterreduce", n_params=npar,
                           compute_s_per_batch=comp_sls,
                           setup=ServerlessSetup(ram_gb=ram))
        g = simulate_epoch("gpu", n_params=npar,
                           compute_s_per_batch=comp_gpu)
        return s.total_cost, g.total_cost
    # MobileNet anchor: serverless competitive
    s_small, g_small = costs(4_200_000, 14.3, 92 / 24, 2.0)
    # 10x heavier model: Lambda time×RAM grows, GPU hourly doesn't
    s_big, g_big = costs(42_000_000, 143.0, 920 / 24, 6.0)
    assert (s_small / g_small) < (s_big / g_big)
    assert s_big > g_big


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------
def test_hlo_collective_parser_counts_scan_trips():
    import re
    from repro.costmodel.hlo_analysis import analyze_collectives
    from jax.sharding import PartitionSpec as P
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device")

    mesh = jax.make_mesh((2,), ("data",))

    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "data"), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    from repro.compat import shard_map
    sm = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                   check_vma=False, axis_names={"data"})
    hlo = jax.jit(sm).lower(
        jnp.ones((2, 64), jnp.float32)).compile().as_text()
    stats = analyze_collectives(hlo)
    assert stats.counts["all-reduce"] >= 7   # 7 loop iterations counted
    assert stats.total_bytes >= 7 * 64 * 4


def test_trainstate_checkpoint_resume_equivalence():
    """save at step k, restore, continue == uninterrupted training."""
    from repro.core import build_train_step, get_strategy
    from repro import optim
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ts = build_train_step(model, optim.adamw(1e-3),
                          get_strategy("mlless"), mesh)
    r = np.random.RandomState(3)
    batches = [{"tokens": r.randint(0, cfg.vocab_size, (4, 16)).astype(
        np.int32)} for _ in range(6)]
    for b in batches:
        b["labels"] = b["tokens"]

    state = ts.init_state(jax.random.PRNGKey(0))
    for b in batches[:3]:
        state, _ = ts.step_fn(state, b)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "state.msgpack")
        save(path, state)
        resumed = restore(path, jax.tree.map(jnp.zeros_like, state))
    for b in batches[3:]:
        state, m1 = ts.step_fn(state, b)
        resumed, m2 = ts.step_fn(resumed, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-6)
    for a, b_ in zip(jax.tree.leaves(state["params"]),
                     jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
