"""Continuous-batching engine == sequential single-request decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def _sequential_generate(model, params, prompt, n_new, cache_len):
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len))(
        params, {"tokens": jnp.asarray(prompt[None, :])})
    toks = [int(jnp.argmax(logits[0, -1, :model.cfg.vocab_size]))]
    pos = len(prompt)
    dec = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for _ in range(n_new - 1):
        logits, cache = dec(params, jnp.asarray([[toks[-1]]], jnp.int32),
                            cache, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0, 0,
                                          :model.cfg.vocab_size])))
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b"])
def test_engine_matches_sequential(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 12, 5)]
    n_new = [4, 3, 5]
    cache_len = 32

    engine = ServingEngine(model, params, batch_size=2,
                           cache_len=cache_len)
    rids = [engine.submit(p, n) for p, n in zip(prompts, n_new)]
    out = engine.run()
    assert set(out) == set(rids)

    for rid, prompt, n in zip(rids, prompts, n_new):
        expect = _sequential_generate(model, params, prompt, n, cache_len)
        assert out[rid] == expect, (rid, out[rid], expect)


def test_engine_more_requests_than_slots():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_size=2, cache_len=16)
    rs = np.random.RandomState(1)
    rids = [engine.submit(rs.randint(0, cfg.vocab_size, 4), 3)
            for _ in range(5)]
    out = engine.run()
    assert len(out) == 5
    assert all(len(v) == 3 for v in out.values())


# ---------------------------------------------------------------------
# Regression pins for the FleetSim per-replica model
# (src/repro/serving/fleet.py cites exactly these semantics)
# ---------------------------------------------------------------------
@pytest.fixture(scope="module")
def small():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, lens, seed=2):
    rs = np.random.RandomState(seed)
    return [rs.randint(0, cfg.vocab_size, L).astype(np.int32)
            for L in lens[:n]]


def test_slot_reuse_after_retire(small):
    """A retired slot admits the next queued request immediately (no
    head-of-line blocking), and reuse does not corrupt outputs."""
    cfg, model, params = small
    prompts = _prompts(cfg, 4, (6, 9, 5, 7))
    n_new = [2, 5, 3, 4]                 # rid 0 retires early -> reuse
    engine = ServingEngine(model, params, batch_size=2, cache_len=32)
    rids = [engine.submit(p, n) for p, n in zip(prompts, n_new)]
    out = engine.run()
    assert len(out) == 4
    for rid, prompt, n in zip(rids, prompts, n_new):
        assert out[rid] == _sequential_generate(model, params, prompt,
                                                n, 32)


def test_admission_waits_for_free_slot(small):
    """With the batch full, a new submission stays queued — step()
    decodes the residents and only admits once one retires."""
    cfg, model, params = small
    prompts = _prompts(cfg, 3, (6, 8, 5))
    engine = ServingEngine(model, params, batch_size=2, cache_len=32)
    engine.submit(prompts[0], 4)
    engine.submit(prompts[1], 4)
    engine.step()                        # both admitted + 1 decode each
    late = engine.submit(prompts[2], 2)
    assert len(engine.queue) == 1        # batch full: queued, not admitted
    assert engine.step() == 2            # still the two residents
    assert len(engine.queue) == 1 and late not in engine.finished
    out = engine.run()
    assert out[late] == _sequential_generate(model, params, prompts[2],
                                             2, 32)


def test_eos_early_stop(small):
    """Generation stops the step the eos id is produced, freeing the
    slot before max_new_tokens is exhausted."""
    cfg, model, params = small
    prompt = _prompts(cfg, 1, (7,))[0]
    free_run = _sequential_generate(model, params, prompt, 6, 32)
    eos = free_run[2]                    # greedy decode is deterministic
    engine = ServingEngine(model, params, batch_size=2, cache_len=32)
    rid = engine.submit(prompt, 6, eos_id=eos)
    out = engine.run()
    stop = free_run.index(eos)
    assert out[rid] == free_run[:stop + 1]
    assert out[rid][-1] == eos and len(out[rid]) < 6


def test_single_token_request_stops_at_prefill(small):
    """max_new_tokens=1 must yield exactly one token (the prefill's)
    without ever occupying a decode slot."""
    cfg, model, params = small
    prompt = _prompts(cfg, 1, (6,))[0]
    engine = ServingEngine(model, params, batch_size=1, cache_len=32)
    rid = engine.submit(prompt, 1)
    other = engine.submit(prompt, 3)     # rides the same single slot
    out = engine.run()
    assert out[rid] == _sequential_generate(model, params, prompt, 1, 32)
    assert len(out[rid]) == 1
    assert out[other] == _sequential_generate(model, params, prompt, 3,
                                              32)


def test_seeded_queue_is_deterministic(small):
    """Same seeded queue -> bit-identical outputs across fresh engines
    (the fleet model's determinism assumption)."""
    cfg, model, params = small

    def run_once():
        rs = np.random.RandomState(7)
        engine = ServingEngine(model, params, batch_size=2,
                               cache_len=32)
        for _ in range(5):
            engine.submit(rs.randint(0, cfg.vocab_size, 6),
                          int(rs.randint(1, 5)))
        return engine.run()

    assert run_once() == run_once()
