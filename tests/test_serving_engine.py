"""Continuous-batching engine == sequential single-request decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import build_model
from repro.serving.engine import ServingEngine


def _sequential_generate(model, params, prompt, n_new, cache_len):
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len))(
        params, {"tokens": jnp.asarray(prompt[None, :])})
    toks = [int(jnp.argmax(logits[0, -1, :model.cfg.vocab_size]))]
    pos = len(prompt)
    dec = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for _ in range(n_new - 1):
        logits, cache = dec(params, jnp.asarray([[toks[-1]]], jnp.int32),
                            cache, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0, 0,
                                          :model.cfg.vocab_size])))
        pos += 1
    return toks


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b"])
def test_engine_matches_sequential(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    prompts = [rs.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 12, 5)]
    n_new = [4, 3, 5]
    cache_len = 32

    engine = ServingEngine(model, params, batch_size=2,
                           cache_len=cache_len)
    rids = [engine.submit(p, n) for p, n in zip(prompts, n_new)]
    out = engine.run()
    assert set(out) == set(rids)

    for rid, prompt, n in zip(rids, prompts, n_new):
        expect = _sequential_generate(model, params, prompt, n, cache_len)
        assert out[rid] == expect, (rid, out[rid], expect)


def test_engine_more_requests_than_slots():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_size=2, cache_len=16)
    rs = np.random.RandomState(1)
    rids = [engine.submit(rs.randint(0, cfg.vocab_size, 4), 3)
            for _ in range(5)]
    out = engine.run()
    assert len(out) == 5
    assert all(len(v) == 3 for v in out.values())
