"""Trace-driven fault replay: the empirical-distribution container,
``FaultPlan.from_trace`` resampling, per-worker cold starts in the
event runtime, and the ``sweep_events(trace=...)`` wiring.

The contract under test is the ISSUE 3 tentpole's: every (trace, seed)
pair is bit-replayable, resampled values never leave the empirical
support, and the per-worker cold-start vector degenerates to the
scalar path when the trace has a single sample.
"""
import dataclasses

import pytest

from repro.serverless import (EventSweepPoint, FaultPlan, FaultRates,
                              RequestTrace, ServerlessSetup, Trace,
                              lambda_default, request_default,
                              run_event_epoch, sweep_events)

N_PARAMS = int(4.2e6)
COMP = 0.9
HORIZON = 120.0


def _trace(**kw):
    base = dict(name="t", cold_start_s=(2.0, 4.0, 9.0, 30.0),
                straggler_slowdown=(1.5, 3.0, 6.0),
                straggler_duration_s=(5.0, 20.0, 60.0),
                straggler_prob=0.5)
    base.update(kw)
    return Trace(**base)


# ---------------------------------------------------------------- Trace
def test_trace_samples_stored_sorted_and_validated():
    tr = Trace(cold_start_s=(9.0, 2.0, 4.0))
    assert tr.cold_start_s == (2.0, 4.0, 9.0)
    assert tr.support("cold_start_s") == (2.0, 9.0)
    with pytest.raises(ValueError):
        Trace(cold_start_s=())
    with pytest.raises(ValueError):
        Trace(cold_start_s=(2.0,), straggler_prob=1.5)
    with pytest.raises(ValueError):                  # prob>0 needs samples
        Trace(cold_start_s=(2.0,), straggler_prob=0.2)
    with pytest.raises(ValueError):                  # slowdown < 1
        _trace(straggler_slowdown=(0.5, 2.0))


def test_trace_json_roundtrip(tmp_path):
    tr = _trace()
    path = str(tmp_path / "trace.json")
    tr.to_json(path)
    assert Trace.from_json(path) == tr


def test_trace_csv_load(tmp_path):
    path = tmp_path / "trace.csv"
    path.write_text("field,value\n"
                    "cold_start_s,2.0\ncold_start_s,9.0\n"
                    "straggler_slowdown,3.0\n"
                    "straggler_duration_s,20.0\n"
                    "straggler_prob,0.25\n")
    tr = Trace.from_csv(str(path), name="csv")
    assert tr.cold_start_s == (2.0, 9.0)
    assert tr.straggler_prob == 0.25
    bad = tmp_path / "bad.csv"
    bad.write_text("field,value\nwarm_start_s,1.0\n")
    with pytest.raises(ValueError):
        Trace.from_csv(str(bad))


def test_inverse_cdf_stays_in_support():
    """Bootstrap resampling: every value is a member of the sample set."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    tr = _trace()

    @hyp.settings(max_examples=50, deadline=None)
    @hyp.given(st.lists(st.floats(0.0, 1.0, exclude_max=True),
                        min_size=1, max_size=32))
    def prop(us):
        for field in ("cold_start_s", "straggler_slowdown",
                      "straggler_duration_s"):
            vals = tr.sample(field, us)
            assert all(v in getattr(tr, field) for v in vals)

    prop()


def test_inverse_cdf_clamps_out_of_range_u():
    """u outside [0, 1) must clamp to the distribution's ends — a
    negative u must not wrap to the maximum via negative indexing."""
    tr = _trace()
    assert float(tr.sample("cold_start_s", -0.05)) == tr.cold_start_s[0]
    assert float(tr.sample("cold_start_s", 1.0)) == tr.cold_start_s[-1]
    assert tr.quantile("cold_start_s", -1.0) == tr.cold_start_s[0]


def test_bundled_default_trace_is_heavy_tailed():
    tr = lambda_default()
    assert tr.name == "lambda-2105.07806"
    # the tail the Poisson defaults miss: p95 far above the median
    assert tr.quantile("cold_start_s", 0.95) \
        > 3 * tr.quantile("cold_start_s", 0.5)
    assert 0 < tr.straggler_prob < 1
    assert tr.straggler_slowdown[0] >= 1.0


# ---------------------------------------------------- FaultPlan.from_trace
def _plan(seed=3, n_workers=4, trace=None, **kw):
    return FaultPlan.from_trace(trace or _trace(), seed=seed,
                                n_workers=n_workers, horizon_s=HORIZON,
                                **kw)


def test_from_trace_deterministic_from_trace_and_seed():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31), n_workers=st.integers(1, 16))
    def prop(seed, n_workers):
        assert _plan(seed, n_workers) == _plan(seed, n_workers)

    prop()
    assert any(_plan(s) != _plan(s + 1) for s in range(8))


def test_from_trace_values_stay_in_empirical_support():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    tr = _trace()

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31),
               base=st.floats(0.0, 5.0, allow_nan=False))
    def prop(seed, base):
        plan = _plan(seed, 8, base_cold_start_s=base)
        assert len(plan.cold_start_extra_s) == 8
        lo, hi = tr.support("cold_start_s")
        for e in plan.cold_start_extra_s:
            assert 0.0 <= e <= hi - min(base, lo) + 1e-12
            assert e == 0.0 or any(abs(e + base - c) < 1e-9
                                   for c in tr.cold_start_s)
        for s in plan.stragglers:
            assert s.slowdown in tr.straggler_slowdown
            # (t0 + dur) - t0 wobbles in the last ulp; membership up to
            # rounding
            assert any(abs((s.end_s - s.start_s) - d) < 1e-9
                       for d in tr.straggler_duration_s)
            assert 0.0 <= s.start_s and s.end_s <= HORIZON + 1e-9
        assert plan.storm is None

    prop()


def test_from_trace_per_worker_draws_do_not_interfere():
    """Fixed draws per worker: worker w's cold start and straggler
    window are identical whatever the fleet size."""
    small, big = _plan(11, 4), _plan(11, 9)
    assert big.cold_start_extra_s[:4] == small.cold_start_extra_s
    by_w = {s.worker: s for s in big.stragglers}
    for s in small.stragglers:
        assert by_w[s.worker] == s


def test_from_trace_spare_workers_extend_cold_vector_stably():
    """Autoscaled joiners draw measured cold starts too: spares append
    to the vector without disturbing the initial fleet's extras or any
    other fault class."""
    plain = _plan(11, 4)
    spared = _plan(11, 4, n_spare_workers=5)
    assert len(spared.cold_start_extra_s) == 9
    assert spared.cold_start_extra_s[:4] == plain.cold_start_extra_s
    assert spared.stragglers == plain.stragglers
    assert spared.crashes == plain.crashes


def test_from_trace_crash_stream_shared_with_random():
    """Crashes ride the same sub-stream as FaultPlan.random's, so the
    traced and Poisson sweep arms differ only in tail behaviour."""
    traced = _plan(5, 8, crash_rate=0.5)
    synth = FaultPlan.random(seed=5, n_workers=8, horizon_s=HORIZON,
                             crash_rate=0.5)
    assert traced.crashes == synth.crashes


# --------------------------------------- per-worker cold starts, runtime
def test_degenerate_one_sample_trace_reduces_to_scalar_path():
    """A single-sample cold-start trace gives every worker the same
    extra; the event epoch must equal one run with the scalar
    plan-level cold start bumped by that extra."""
    tr = Trace(cold_start_s=(10.5,), name="degenerate")
    setup = ServerlessSetup(cold_start_s=2.5)
    plan = FaultPlan.from_trace(tr, seed=0, n_workers=setup.n_workers,
                                horizon_s=HORIZON,
                                base_cold_start_s=setup.cold_start_s)
    assert plan.cold_start_extra_s == (8.0,) * setup.n_workers
    a = run_event_epoch("allreduce", n_params=N_PARAMS,
                        compute_s_per_batch=COMP, setup=setup,
                        faults=plan)
    b = run_event_epoch("allreduce", n_params=N_PARAMS,
                        compute_s_per_batch=COMP,
                        setup=dataclasses.replace(setup, cold_start_s=10.5))
    for field in ("makespan_s", "rounds", "work_done_batches",
                  "total_cost", "stage_totals"):
        assert getattr(a, field) == getattr(b, field), field


def test_per_worker_cold_extras_gate_first_barrier():
    """The slowest empirical cold start gates the synchronous fleet,
    exactly like a storm victim's scalar extra_s does."""
    base = run_event_epoch("allreduce", n_params=N_PARAMS,
                           compute_s_per_batch=COMP,
                           setup=ServerlessSetup())
    plan = FaultPlan(cold_start_extra_s=(0.0, 3.0, 27.5, 1.0))
    rep = run_event_epoch("allreduce", n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup(), faults=plan)
    assert rep.makespan_s == pytest.approx(base.makespan_s + 27.5,
                                           rel=1e-9)
    assert rep.stage_totals["cold_start"] == pytest.approx(
        base.stage_totals["cold_start"] + 31.5, rel=1e-9)


# ----------------------------------------------------- sweep integration
def _points(trace=None):
    return [EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                            compute_s_per_batch=COMP, trace=trace),
            EventSweepPoint(arch="spirt", n_params=N_PARAMS,
                            compute_s_per_batch=COMP, trace=trace)]


def test_sweep_events_trace_spawn_matches_inline():
    """Satellite: spawn-vs-inline agreement with trace= set — the
    sweep's fan-out must not perturb trace-driven draws."""
    kw = dict(rates=FaultRates(crash_rate=0.3), trace=_trace(),
              n_replicates=2, seed=3)
    inline = sweep_events(_points(), processes=1, **kw)
    fanned = sweep_events(_points(), processes=2, **kw)
    for x, y in zip(inline, fanned):
        assert x.makespan_mean_s == y.makespan_mean_s
        assert x.cost_mean == y.cost_mean
        assert x.ttr_mean_s == y.ttr_mean_s


def test_sweep_events_trace_is_seeded_and_changes_results():
    pts = _points()
    kw = dict(rates=FaultRates(), n_replicates=3, processes=1)
    a = sweep_events(pts, trace=_trace(), seed=7, **kw)
    b = sweep_events(pts, trace=_trace(), seed=7, **kw)
    plain = sweep_events(pts, seed=7, **kw)
    for x, y in zip(a, b):
        assert x.makespan_mean_s == y.makespan_mean_s
        assert x.cost_overhead_p95 == y.cost_overhead_p95
    # measured cold-start tails actually bite: traced != rate-free runs
    assert all(x.makespan_mean_s > p.makespan_mean_s
               for x, p in zip(a, plain))


def test_sweep_events_per_point_trace_overrides_sweep_level():
    heavy = _trace(cold_start_s=(200.0,), straggler_prob=0.0)
    light = _trace(cold_start_s=(3.0,), straggler_prob=0.0)
    pts = [EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                           compute_s_per_batch=COMP, trace=heavy),
           EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                           compute_s_per_batch=COMP)]
    stats = sweep_events(pts, rates=FaultRates(), trace=light,
                         n_replicates=2, seed=0, processes=1)
    # point 0's own heavy trace wins over the light sweep-level default
    assert stats[0].makespan_mean_s > stats[1].makespan_mean_s + 100.0


# ------------------------------------------------------- RequestTrace
def _req_trace(**kw):
    base = dict(name="r", inter_arrival_s=(0.5, 1.0, 4.0),
                prompt_tokens=(64.0, 256.0, 1024.0),
                decode_tokens=(8.0, 32.0, 128.0))
    base.update(kw)
    return RequestTrace(**base)


def test_request_trace_sorted_and_validated():
    tr = RequestTrace(inter_arrival_s=(4.0, 0.5, 1.0))
    assert tr.inter_arrival_s == (0.5, 1.0, 4.0)
    assert tr.support("inter_arrival_s") == (0.5, 4.0)
    with pytest.raises(ValueError):
        RequestTrace(inter_arrival_s=())
    with pytest.raises(ValueError):                 # negative gap
        RequestTrace(inter_arrival_s=(1.0, -0.5))
    with pytest.raises(ValueError):                 # fractional tokens
        _req_trace(prompt_tokens=(64.5,))
    with pytest.raises(ValueError):                 # zero token count
        _req_trace(decode_tokens=(0.0,))


def test_request_trace_json_roundtrip(tmp_path):
    tr = _req_trace()
    path = str(tmp_path / "req.json")
    tr.to_json(path)
    assert RequestTrace.from_json(path) == tr
    bad = tmp_path / "bad.json"
    bad.write_text('{"inter_arrival_s": [1.0], "cold_start_s": [2.0]}')
    with pytest.raises(ValueError):                 # fault-trace field
        RequestTrace.from_json(str(bad))


def test_request_trace_csv_load(tmp_path):
    path = tmp_path / "req.csv"
    path.write_text("field,value\n"
                    "inter_arrival_s,0.5\ninter_arrival_s,2.0\n"
                    "prompt_tokens,128\ndecode_tokens,64\n")
    tr = RequestTrace.from_csv(str(path), name="csv")
    assert tr.inter_arrival_s == (0.5, 2.0)
    assert tr.prompt_tokens == (128.0,)
    bad = tmp_path / "bad.csv"
    bad.write_text("field,value\ncold_start_s,1.0\n")
    with pytest.raises(ValueError):
        RequestTrace.from_csv(str(bad))


def test_request_trace_resampling_stays_in_support():
    """Empirical-support containment: every resampled value is a member
    of the sample set, whatever u (satellite property)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    tr = _req_trace()

    @hyp.settings(max_examples=50, deadline=None)
    @hyp.given(st.lists(st.floats(-0.2, 1.2, allow_nan=False),
                        min_size=1, max_size=32))
    def prop(us):
        for field in ("inter_arrival_s", "prompt_tokens",
                      "decode_tokens"):
            vals = tr.sample(field, us)
            assert all(v in getattr(tr, field) for v in vals)

    prop()


def test_request_trace_workload_deterministic_from_trace_and_seed():
    """(trace, seed) -> bit-identical request plans (satellite
    property), and the seed actually matters."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.serving.workload import Workload
    tr = _req_trace()

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31), n=st.integers(1, 64))
    def prop(seed, n):
        w = Workload(n_requests=n, trace=tr)
        assert w.generate(seed) == w.generate(seed)

    prop()
    w = Workload(n_requests=32, trace=tr)
    assert any(w.generate(s) != w.generate(s + 1) for s in range(8))


def test_bundled_request_trace_shape():
    tr = request_default()
    assert tr.name == "azure-llm-2311.18677"
    # bursty arrivals: p95 an order of magnitude above the median
    assert tr.quantile("inter_arrival_s", 0.95) \
        > 5 * tr.quantile("inter_arrival_s", 0.5)
    # long-tailed token counts, integral by construction
    assert tr.quantile("prompt_tokens", 0.95) \
        > 3 * tr.quantile("prompt_tokens", 0.5)
    assert all(v == int(v) for v in tr.prompt_tokens + tr.decode_tokens)
    assert 0.1 < tr.mean_rate_rps() < 10.0
