"""Gradient-sync strategy semantics (the paper's core, §2/Table 1).

Key invariants:
  * allreduce == scatterreduce == parameter_server (exact same mean)
  * spirt(K) equals allreduce when the global batch is identical
    (mean of microbatch means == full-batch mean)
  * mlless with threshold=0 equals allreduce; with threshold>0 the
    filtered+residual decomposition conserves the gradient
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy
from repro.core.strategies import MLLess
from repro.models import build_model


@pytest.fixture(scope="module")
def setting():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = np.random.RandomState(1)
    batch = {"tokens": r.randint(0, cfg.vocab_size, (8, 32)).astype(
        np.int32)}
    batch["labels"] = batch["tokens"]
    return cfg, model, mesh, batch


def _run(model, mesh, batch, strategy, steps=2):
    ts = build_train_step(model, optim.sgd(0.1), strategy, mesh)
    state = ts.init_state(jax.random.PRNGKey(0))
    for _ in range(steps):
        state, metrics = ts.step_fn(state, batch)
    flat = np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(state["params"])])
    return flat, metrics


def test_reduce_strategies_agree(setting):
    cfg, model, mesh, batch = setting
    base, _ = _run(model, mesh, batch, get_strategy("allreduce"))
    for name in ("scatterreduce", "parameter_server", "spirt"):
        other, _ = _run(model, mesh, batch, get_strategy(name))
        np.testing.assert_allclose(base, other, atol=1e-5, err_msg=name)


def test_mlless_zero_threshold_equals_allreduce(setting):
    cfg, model, mesh, batch = setting
    base, _ = _run(model, mesh, batch, get_strategy("allreduce"))
    ml, metrics = _run(model, mesh, batch, MLLess(threshold=0.0))
    # threshold 0 keeps every non-zero block (zero-gradient blocks, e.g.
    # unseen vocabulary rows, are dropped but contribute nothing anyway)
    assert float(metrics["significant_fraction"]) > 0.5
    np.testing.assert_allclose(base, ml, atol=1e-5)


def test_mlless_filters_and_converges_direction(setting):
    cfg, model, mesh, batch = setting
    _, metrics = _run(model, mesh, batch, MLLess(threshold=1.0), steps=3)
    frac = float(metrics["significant_fraction"])
    assert 0.0 < frac < 1.0  # actually filtering something
    assert np.isfinite(float(metrics["loss"]))


def test_strategy_comm_bytes_ordering():
    """Paper §4.2: PS(master) moves W× bytes; ring strategies 2G(W-1)/W;
    MLLess a fraction; SPIRT amortizes by K."""
    grads = [np.zeros(1000, np.float32)]
    W = 8
    ar = get_strategy("allreduce").comm_bytes(grads, W)
    sr = get_strategy("scatterreduce").comm_bytes(grads, W)
    ps = get_strategy("parameter_server").comm_bytes(grads, W)
    sp = get_strategy("spirt").comm_bytes(grads, W)
    ml = get_strategy("mlless").comm_bytes(grads, W,
                                           significant_fraction=0.25)
    assert ar == sr                 # scatter-reduce IS decomposed ring
    assert ps > ar                  # master blowup
    assert sp < ar                  # K-step amortization
    assert ml < ar                  # filtering
