"""ISSUE 4 hard constraint: the ArchSpec registry refactor must be
bit-exact for the five paper architectures.

``tests/golden/serverless_golden.json`` was captured from the
pre-registry ``main`` (see ``tests/golden_utils.py``, which defines the
scenario matrix and the lossless fingerprints — floats via
``float.hex``, sweep columns via sha256 of their raw bytes).  These
tests recompute every fingerprint through today's code and assert EXACT
equality: scalar ``EpochReport``s, the vectorized analytic sweep, and
event-engine ``RuntimeReport``s across crash/straggler/storm/byzantine/
trace/autoscale scenarios under both recovery policies.
"""
import json

import pytest

import golden_utils as gu
from repro.serverless import run_event_epoch, simulate_epoch


@pytest.fixture(scope="module")
def golden():
    with open(gu.GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("arch", gu.PAPER_ARCHS)
@pytest.mark.parametrize("scenario", sorted(gu.epoch_scenarios()))
def test_epoch_reports_bit_identical(golden, arch, scenario):
    kw = gu.epoch_scenarios()[scenario]
    fp = gu.epoch_fingerprint(simulate_epoch(arch, **kw))
    assert fp == golden["epoch"][arch][scenario]


@pytest.mark.parametrize("arch", gu.PAPER_ARCHS)
@pytest.mark.parametrize("scenario", sorted(gu.runtime_scenarios()))
def test_runtime_reports_bit_identical(golden, arch, scenario):
    kw = gu.runtime_scenarios()[scenario]
    fp = gu.runtime_fingerprint(run_event_epoch(arch, **kw))
    assert fp == golden["runtime"][arch][scenario]


def test_vectorized_sweep_columns_bit_identical(golden):
    fresh = gu.sweep_fingerprint()
    assert fresh["n_points"] == golden["sweep"]["n_points"]
    for col in gu.SWEEP_COLUMNS:
        assert fresh[col] == golden["sweep"][col], col
