"""Regression: the optimized EventRuntime reproduces the frozen PR 1
reference engine (``runtime_ref``) byte-for-byte.

Every scenario class the runtime supports — fault-free, crash under
both recovery policies, stragglers, cold-start storms, byzantine
bookkeeping, scheduled and reactive autoscaling, and randomized mixed
fault plans — is run through both engines and every ``RuntimeReport``
field is compared with EXACT equality (no tolerances): the optimized
engine's inline round batching is only legal because it reproduces the
event path's floating-point operation order.
"""
import math

import pytest

from repro.serverless import (ByzantineWorker, CheckpointRestore,
                              ColdStartStorm, FaultPlan, PeerTakeover,
                              ReactiveAutoscaler, ScheduledScaler,
                              ServerlessSetup, Straggler, WorkerCrash)
from repro.serverless import runtime as opt
from repro.serverless import runtime_ref as ref
from repro.serverless.simulator import ARCHS

N_PARAMS = int(4.2e6)
COMP = 0.9


def _run(mod, arch, **kw):
    return mod.run_event_epoch(arch, n_params=N_PARAMS,
                               compute_s_per_batch=COMP,
                               setup=ServerlessSetup(), **kw)


def _assert_reports_identical(a, b, ctx=""):
    for field in ("arch", "makespan_s", "analytic_s", "rounds",
                  "work_done_batches", "n_workers_start", "n_workers_peak",
                  "n_workers_end", "total_cost", "stage_totals",
                  "poisoned_updates", "masked_updates", "scale_events"):
        va, vb = getattr(a, field), getattr(b, field)
        assert va == vb, (ctx, field, va, vb)
    assert len(a.recoveries) == len(b.recoveries), ctx
    for x, y in zip(a.recoveries, b.recoveries):
        assert (x.worker, x.crash_time_s, x.mode) == \
            (y.worker, y.crash_time_s, y.mode), ctx
        assert x.rejoined_time_s == y.rejoined_time_s or (
            math.isnan(x.rejoined_time_s)
            and math.isnan(y.rejoined_time_s)), ctx


def _scenarios(base_makespan):
    crash = FaultPlan(crashes=(WorkerCrash(1, 0.4 * base_makespan),))
    return {
        "fault_free": {},
        "crash_restore": dict(
            faults=crash, recovery=CheckpointRestore(checkpoint_every=4)),
        "crash_takeover": dict(faults=crash, recovery=PeerTakeover()),
        "double_crash": dict(
            faults=FaultPlan(crashes=(WorkerCrash(1, 0.3 * base_makespan),
                                      WorkerCrash(3, 0.6 * base_makespan))),
            recovery=CheckpointRestore(checkpoint_every=4)),
        "straggler": dict(
            faults=FaultPlan(stragglers=(Straggler(2, slowdown=4.0),))),
        "straggler_window": dict(
            faults=FaultPlan(stragglers=(
                Straggler(2, slowdown=3.0, start_s=0.2 * base_makespan,
                          end_s=0.5 * base_makespan),))),
        "storm": dict(faults=FaultPlan(
            storm=ColdStartStorm(extra_s=8.0, fraction=0.5), seed=7)),
        "byzantine_masked": dict(
            faults=FaultPlan(byzantine=(ByzantineWorker(0),)),
            robust_trim=1),
        "byzantine_poisoned": dict(
            faults=FaultPlan(byzantine=(ByzantineWorker(0),
                                        ByzantineWorker(2)))),
    }


@pytest.mark.parametrize("arch", list(ARCHS))
def test_optimized_engine_reproduces_reference(arch):
    base = _run(ref, arch)
    for name, kw in _scenarios(base.makespan_s).items():
        _assert_reports_identical(_run(opt, arch, **kw),
                                  _run(ref, arch, **kw), ctx=name)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_optimized_engine_reproduces_reference_random_plans(arch):
    base = _run(ref, arch)
    for seed in range(8):
        plan = FaultPlan.random(seed=seed, n_workers=4,
                                horizon_s=base.makespan_s, crash_rate=0.4,
                                straggler_rate=0.4, byzantine_fraction=0.25,
                                storm_prob=0.5)
        recovery = PeerTakeover() if seed % 2 else CheckpointRestore()
        _assert_reports_identical(
            _run(opt, arch, faults=plan, recovery=recovery, robust_trim=1),
            _run(ref, arch, faults=plan, recovery=recovery, robust_trim=1))


def test_optimized_engine_reproduces_reference_under_autoscaling():
    strag = FaultPlan(stragglers=(Straggler(2, slowdown=4.0),))
    for mk in (lambda: ScheduledScaler(schedule=((2, 4), (6, -2))),
               lambda: ReactiveAutoscaler(max_workers=8)):
        # autoscalers are stateful: fresh instance per engine
        _assert_reports_identical(
            _run(opt, "allreduce", faults=strag, autoscaler=mk()),
            _run(ref, "allreduce", faults=strag, autoscaler=mk()))


def test_timeline_mode_matches_reference_event_for_event():
    """max_timeline>0 disables round batching; the recorded timeline is
    then the reference engine's, entry for entry."""
    base = _run(ref, "allreduce")
    kw = dict(faults=FaultPlan(
        crashes=(WorkerCrash(1, 0.4 * base.makespan_s),),
        stragglers=(Straggler(2, slowdown=4.0),)),
        recovery=CheckpointRestore(checkpoint_every=4))
    a = _run(opt, "allreduce", max_timeline=4096, **kw)
    b = _run(ref, "allreduce", **kw)      # reference records by default
    _assert_reports_identical(a, b)
    assert a.timeline == b.timeline
    assert len(a.timeline) > 0


def test_timeline_off_by_default():
    rep = _run(opt, "allreduce")
    assert rep.timeline == []


def test_whole_fleet_crash_under_takeover_terminates():
    """Regression: with every worker dead under PeerTakeover the
    expected fleet is empty; a pending barrier release must account
    once and stop (the inline round loop used to spin on zero-batch
    rounds forever here)."""
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=COMP,
              setup=ServerlessSetup(n_workers=2),
              faults=FaultPlan(crashes=(WorkerCrash(0, 4.0488),
                                        WorkerCrash(1, 4.5516))),
              recovery=PeerTakeover())
    a = opt.run_event_epoch("allreduce", **kw)
    b = ref.run_event_epoch("allreduce", **kw)
    _assert_reports_identical(a, b)
    assert a.n_workers_end == 0
    assert a.work_done_batches < 2 * ServerlessSetup().batches_per_worker


@pytest.mark.parametrize("n_workers", [2, 3, 4])
def test_reference_identity_under_heavy_crash_plans(n_workers):
    """Small fleets + high crash rates probe the takeover/restore corner
    cases (partial and total fleet loss) against the reference."""
    setup = ServerlessSetup(n_workers=n_workers)
    for seed in range(6):
        plan = FaultPlan.random(seed=seed, n_workers=n_workers,
                                horizon_s=60.0, crash_rate=0.9,
                                straggler_rate=0.3)
        for recovery in (PeerTakeover(), CheckpointRestore()):
            ka = opt.run_event_epoch("allreduce", n_params=N_PARAMS,
                                     compute_s_per_batch=COMP, setup=setup,
                                     faults=plan, recovery=recovery)
            kb = ref.run_event_epoch("allreduce", n_params=N_PARAMS,
                                     compute_s_per_batch=COMP, setup=setup,
                                     faults=plan, recovery=recovery)
            _assert_reports_identical(ka, kb)
