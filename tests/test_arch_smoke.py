"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as a REDUCED same-family
variant (2 layers, d_model <= 512, <= 4 experts) and runs one forward
plus one train step on CPU, asserting output shapes and the absence of
NaNs.  Prefill/decode consistency is covered in test_serving.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy
from repro.models import build_cnn, build_model

ARCHS = [
    "mixtral-8x22b", "gemma3-4b", "mixtral-8x7b", "rwkv6-7b", "pixtral-12b",
    "smollm-135m", "whisper-small", "phi3-mini-3.8b", "recurrentgemma-2b",
    "qwen1.5-4b",
]


def _batch(cfg, B=2, S=32, seed=0):
    r = np.random.RandomState(seed)
    batch = {"tokens": r.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    batch["labels"] = batch["tokens"].copy()
    if cfg.family == "vlm":
        batch["patch_emb"] = r.randn(B, cfg.n_patches, cfg.d_model).astype(
            np.float32) * 0.1
    if cfg.is_encoder_decoder:
        batch["frames"] = r.randn(B, cfg.encoder_seq, cfg.d_model).astype(
            np.float32) * 0.1
    return jax.tree.map(jnp.asarray, batch)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(model.apply)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert not np.isnan(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = build_model(cfg, remat=False)
    ts = build_train_step(model, optim.adamw(1e-3),
                          get_strategy("allreduce"), mesh)
    state = ts.init_state(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    new_state, metrics = ts.step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(state["params"])[0]
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


@pytest.mark.parametrize("kind", ["mobilenet-cifar", "resnet18-cifar"])
def test_cnn_smoke(kind):
    cfg = get_config(kind).reduced()
    model = build_cnn(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs = jnp.asarray(np.random.RandomState(0).randn(4, 32, 32, 3),
                       jnp.float32)
    logits, _ = jax.jit(model.apply)(params, {"images": imgs})
    assert logits.shape == (4, 10)
    assert not np.isnan(np.asarray(logits)).any()
