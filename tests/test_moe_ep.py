"""Expert-parallel (all_to_all) MoE == local capacity-dispatch MoE."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_moe_ep_matches_local_dispatch():
    code = """
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import get_config
    from repro.models import moe

    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              n_experts=4, experts_per_token=2)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    mesh = jax.make_mesh((4,), ("data",))
    B, S = 8, 16
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))

    from repro.compat import shard_map
    local = shard_map(
        lambda p_, x_: moe.moe_apply(p_, x_, cfg)[0],
        mesh=mesh, in_specs=(P(), P("data")), out_specs=P("data"),
        check_vma=False, axis_names={"data"})
    ep = shard_map(
        lambda p_, x_: moe.moe_apply_ep(p_, x_, cfg, axis_name="data")[0],
        mesh=mesh, in_specs=(P(), P("data")), out_specs=P("data"),
        check_vma=False, axis_names={"data"})
    y1 = jax.jit(local)(p, x)
    y2 = jax.jit(ep)(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=560,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
