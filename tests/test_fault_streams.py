"""Regression tests for the ISSUE 3 satellite bugfixes: correlated
fault-class RNG streams, the storm-victim count clamp, and the
autoscaler's falsy ideal-round reference.
"""
import numpy as np
import pytest

from repro.serverless import (ColdStartStorm, FaultPlan,
                              ReactiveAutoscaler, ServerlessSetup,
                              run_event_epoch)

N_PARAMS = int(4.2e6)
COMP = 0.9


# ------------------------------------------------- disjoint sub-streams
def test_crash_draws_independent_of_straggler_rate():
    """Per-class sub-streams: raising the straggler rate must not shift
    crash times (the old single-stream draw interleaved them, so a
    triggered straggler consumed uniforms the next crash needed)."""
    for seed in range(10):
        plans = [FaultPlan.random(seed=seed, n_workers=8, horizon_s=100.0,
                                  crash_rate=0.5, straggler_rate=r)
                 for r in (0.0, 0.5, 1.0)]
        assert plans[0].crashes == plans[1].crashes == plans[2].crashes
        # and symmetrically: stragglers survive a crash-rate change
        a = FaultPlan.random(seed=seed, n_workers=8, horizon_s=100.0,
                             crash_rate=0.0, straggler_rate=0.5)
        b = FaultPlan.random(seed=seed, n_workers=8, horizon_s=100.0,
                             crash_rate=1.0, straggler_rate=0.5)
        assert a.stragglers == b.stragglers


def test_storm_victims_left_the_shared_random_state_stream():
    """The bug: ``storm_victims`` re-seeded ``RandomState(seed)`` — the
    very stream ``FaultPlan.random`` consumed for crash draws — so
    victims replayed the crash uniforms.  The fix derives a dedicated
    sub-stream; victims must therefore differ from the old shared-stream
    draw for at least some seeds."""
    def old_victims(seed, fraction, n):
        rng = np.random.RandomState(seed)
        k = max(1, int(round(fraction * n)))
        return tuple(sorted(rng.choice(n, size=k, replace=False)))

    plans = [FaultPlan(storm=ColdStartStorm(fraction=0.5), seed=s)
             for s in range(20)]
    assert any(p.storm_victims(8) != old_victims(p.seed, 0.5, 8)
               for p in plans)
    # still seeded: same (seed, fleet) -> same victims
    for p in plans:
        assert p.storm_victims(8) == p.storm_victims(8)


def test_storm_victims_statistically_decorrelated_from_crashes():
    """Joint frequency of (worker crashed, worker is a victim) must sit
    at the product of the marginals — the correlation the shared stream
    used to inject."""
    n, crashed_and_victim, crashed, victim, total = 16, 0, 0, 0, 0
    for seed in range(300):
        p = FaultPlan.random(seed=seed, n_workers=n, horizon_s=100.0,
                             crash_rate=0.5, storm_prob=1.0)
        victims = set(p.storm_victims(n))
        crashes = {c.worker for c in p.crashes}
        for w in range(n):
            total += 1
            crashed += w in crashes
            victim += w in victims
            crashed_and_victim += (w in crashes) and (w in victims)
    joint = crashed_and_victim / total
    product = (crashed / total) * (victim / total)
    # 4800 draws: |joint - product| ~ N(0, 0.0063); 0.04 is >6 sigma
    assert abs(joint - product) < 0.04, (joint, product)


# --------------------------------------------------- storm-victim clamp
def test_storm_fraction_zero_hits_nobody():
    plan = FaultPlan(storm=ColdStartStorm(extra_s=8.0, fraction=0.0),
                     seed=3)
    assert plan.storm_victims(4) == ()
    base = run_event_epoch("allreduce", n_params=N_PARAMS,
                           compute_s_per_batch=COMP,
                           setup=ServerlessSetup())
    rep = run_event_epoch("allreduce", n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup(), faults=plan)
    assert rep.makespan_s == base.makespan_s       # a 0-fraction storm is free


def test_storm_fraction_above_one_clamps_to_fleet():
    plan = FaultPlan(storm=ColdStartStorm(fraction=1.5), seed=3)
    assert plan.storm_victims(4) == (0, 1, 2, 3)   # no crash, whole fleet
    assert plan.storm_victims(1) == (0,)


def test_byzantine_fraction_clamps_like_storm_fraction():
    full = FaultPlan.random(seed=1, n_workers=4, horizon_s=100.0,
                            byzantine_fraction=1.2)
    assert full.byzantine_workers() == (0, 1, 2, 3)
    none = FaultPlan.random(seed=1, n_workers=4, horizon_s=100.0,
                            byzantine_fraction=-0.5)
    assert none.byzantine == ()


def test_storm_fraction_rounds_to_nearest_count():
    plan = FaultPlan(storm=ColdStartStorm(fraction=0.5), seed=9)
    assert len(plan.storm_victims(4)) == 2
    assert len(plan.storm_victims(5)) == 2          # round(2.5) banker's
    assert len(plan.storm_victims(100)) == 50


# ------------------------------------------- autoscaler falsy reference
def _prime(scaler, round_s=10.0, workers=4):
    """Feed round 1 (ignored: embeds the cold start) so the EMA exists."""
    scaler.observe(round_idx=1, now_s=round_s, active_workers=workers,
                   remaining_batches=960.0, batches_per_round=1.0,
                   ideal_round_s=None)


def test_autoscaler_zero_ideal_round_still_scales_out():
    """The bug: ``ideal_round_s=0.0`` is falsy, so the reference fell
    back to the EMA and a permanently-slow fleet (every round equals the
    EMA) never scaled.  With ``is not None``, any positive round beats a
    zero ideal."""
    a = ReactiveAutoscaler(max_workers=8)
    _prime(a)
    delta = a.observe(round_idx=2, now_s=20.0, active_workers=4,
                      remaining_batches=800.0, batches_per_round=1.0,
                      ideal_round_s=0.0)
    assert delta == 1
    assert a.decisions and a.decisions[-1][1] == 1


def test_autoscaler_near_zero_ideal_round_scales_out():
    a = ReactiveAutoscaler(max_workers=8)
    _prime(a)
    assert a.observe(round_idx=2, now_s=20.0, active_workers=4,
                     remaining_batches=800.0, batches_per_round=1.0,
                     ideal_round_s=1e-9) == 1


def test_autoscaler_none_ideal_still_uses_ema():
    """No reference provided -> trailing EMA, as before the fix: a round
    matching the EMA is not anomalous and must not scale out."""
    a = ReactiveAutoscaler(max_workers=8)
    _prime(a)
    assert a.observe(round_idx=2, now_s=20.0, active_workers=4,
                     remaining_batches=800.0, batches_per_round=1.0,
                     ideal_round_s=None) == 0
    # but a blowout vs the EMA still triggers
    b = ReactiveAutoscaler(max_workers=8)
    _prime(b)
    assert b.observe(round_idx=2, now_s=10.0 + 50.0, active_workers=4,
                     remaining_batches=800.0, batches_per_round=1.0,
                     ideal_round_s=None) == 1


def test_autoscaler_logs_applied_delta_near_cap():
    """decisions must record the clamped delta actually returned, not
    the configured step — replayed decision logs used to overstate
    applied scale-outs near the fleet cap."""
    a = ReactiveAutoscaler(max_workers=8, step=3)
    _prime(a)
    delta = a.observe(round_idx=2, now_s=20.0, active_workers=6,
                      remaining_batches=800.0, batches_per_round=1.0,
                      ideal_round_s=0.0)
    assert delta == 2                 # clamped: 8 - 6 < step
    assert a.decisions[-1][1] == delta
