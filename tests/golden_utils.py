"""Golden-snapshot fingerprints for the serverless simulator stack.

ISSUE 4's hard constraint is bit-exactness: the registry refactor
(``repro.serverless.archs``) must leave every number the five paper
architectures produce — scalar ``EpochReport``, vectorized analytic
sweep columns, and event-engine ``RuntimeReport`` under every
fault/recovery scenario — byte-identical.  This module defines the
scenario matrix and a lossless fingerprint (floats serialized via
``float.hex``, arrays via sha256 of their raw bytes), shared by

  * the one-shot capture run that snapshotted current ``main`` into
    ``tests/golden/serverless_golden.json`` (run as
    ``PYTHONPATH=src python tests/golden_utils.py``), and
  * ``tests/test_golden_parity.py``, which recomputes the fingerprints
    and asserts exact equality against the snapshot.

Every scenario passes an EXPLICIT recovery policy: the snapshot pins
engine arithmetic, not default-resolution policy (which the registry
refactor deliberately makes arch-aware).
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

from repro.serverless import (ByzantineWorker, CheckpointRestore,
                              ColdStartStorm, FaultPlan, PeerTakeover,
                              ReactiveAutoscaler, S3, ServerlessSetup,
                              Straggler, WorkerCrash, lambda_default,
                              run_event_epoch, simulate_epoch)
from repro.serverless.sweep import SweepGrid, ram_scaled_compute, \
    sweep_analytic
from repro.serverless.simulator import REDIS

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "serverless_golden.json")
PAPER_ARCHS = ("spirt", "mlless", "scatterreduce", "allreduce", "gpu")
N_PARAMS = int(4.2e6)

# analytic-sweep columns that predate the registry (new columns the
# refactor adds are additive and not part of the frozen snapshot)
SWEEP_COLUMNS = ("arch", "channel_idx", "n_workers", "ram_gb",
                 "accumulation", "significant_fraction",
                 "compute_s_per_batch", "fetch_s", "compute_s", "sync_s",
                 "update_s", "per_worker_s", "per_batch_s",
                 "comm_bytes_per_worker", "cost_per_worker", "total_cost")


def _hex(x) -> str:
    """Lossless scalar encoding (floats via hex, ints verbatim)."""
    if isinstance(x, (bool, np.bool_)):
        return str(bool(x))
    if isinstance(x, (int, np.integer)):
        return str(int(x))
    return float(x).hex()


def epoch_fingerprint(rep) -> dict:
    return {
        "arch": rep.arch,
        "per_batch_s": _hex(rep.per_batch_s),
        "per_worker_s": _hex(rep.per_worker_s),
        "total_time_s": _hex(rep.total_time_s),
        "stages": {k: _hex(getattr(rep.stages, k))
                   for k in ("fetch", "compute", "sync", "update")},
        "comm_bytes_per_worker": _hex(rep.comm_bytes_per_worker),
        "cost_per_worker": _hex(rep.cost_per_worker),
        "total_cost": _hex(rep.total_cost),
        "ram_gb": _hex(rep.ram_gb),
    }


def runtime_fingerprint(rep) -> dict:
    return {
        "arch": rep.arch,
        "makespan_s": _hex(rep.makespan_s),
        "analytic_s": _hex(rep.analytic_s),
        "rounds": rep.rounds,
        "work_done_batches": _hex(rep.work_done_batches),
        "n_workers_start": rep.n_workers_start,
        "n_workers_peak": rep.n_workers_peak,
        "n_workers_end": rep.n_workers_end,
        "total_cost": _hex(rep.total_cost),
        "stage_totals": {k: _hex(v)
                         for k, v in sorted(rep.stage_totals.items())},
        "recoveries": [[r.worker, _hex(r.crash_time_s),
                        _hex(r.rejoined_time_s), r.mode]
                       for r in rep.recoveries],
        "poisoned_updates": rep.poisoned_updates,
        "masked_updates": rep.masked_updates,
        "scale_events": [[_hex(t), int(d)] for t, d in rep.scale_events],
    }


# ---------------------------------------------------------------------------
# Scenario matrix
# ---------------------------------------------------------------------------
def epoch_scenarios():
    """(name, simulate_epoch kwargs sans arch) — scalar analytic path."""
    return {
        "default": dict(n_params=N_PARAMS, compute_s_per_batch=0.9,
                        setup=ServerlessSetup()),
        "s3_w8": dict(n_params=N_PARAMS, compute_s_per_batch=0.9,
                      setup=ServerlessSetup(n_workers=8, ram_gb=3.0,
                                            channel=S3),
                      accumulation=8, significant_fraction=0.1),
        "small": dict(n_params=N_PARAMS, compute_s_per_batch=1.7,
                      setup=ServerlessSetup(n_workers=2, ram_gb=1.0),
                      significant_fraction=0.5),
    }


def runtime_scenarios():
    """(name, run_event_epoch kwargs sans arch) — event engine.  Every
    crash scenario names its recovery policy explicitly (see module
    docstring)."""
    crash = FaultPlan(crashes=(WorkerCrash(1, 30.0),))
    strag = FaultPlan(stragglers=(Straggler(2, slowdown=4.0),))
    mixed = FaultPlan.random(seed=3, n_workers=4, horizon_s=120.0,
                             crash_rate=0.5, straggler_rate=0.5,
                             byzantine_fraction=0.25, storm_prob=0.5)
    traced = FaultPlan.from_trace(lambda_default(), seed=5, n_workers=4,
                                  horizon_s=120.0, base_cold_start_s=2.5,
                                  crash_rate=0.3)
    base = dict(n_params=N_PARAMS, compute_s_per_batch=0.9,
                setup=ServerlessSetup())
    s3 = dict(n_params=N_PARAMS, compute_s_per_batch=0.9,
              setup=ServerlessSetup(n_workers=8, ram_gb=3.0, channel=S3))
    return {
        "fault_free": dict(base),
        "crash_restore": dict(base, faults=crash,
                              recovery=CheckpointRestore(
                                  checkpoint_every=4)),
        "crash_takeover": dict(base, faults=crash,
                               recovery=PeerTakeover()),
        "straggler": dict(base, faults=strag,
                          recovery=CheckpointRestore()),
        "storm": dict(base,
                      faults=FaultPlan(storm=ColdStartStorm(
                          extra_s=8.0, fraction=0.5), seed=7),
                      recovery=CheckpointRestore()),
        "byzantine_masked": dict(base,
                                 faults=FaultPlan(byzantine=(
                                     ByzantineWorker(0),)),
                                 recovery=CheckpointRestore(),
                                 robust_trim=1),
        "random_mix_restore": dict(base, faults=mixed,
                                   recovery=CheckpointRestore(),
                                   robust_trim=1),
        "random_mix_takeover": dict(base, faults=mixed,
                                    recovery=PeerTakeover(),
                                    robust_trim=1),
        "trace_replay": dict(base, faults=traced,
                             recovery=CheckpointRestore(
                                 checkpoint_every=3)),
        "autoscaled_straggler": dict(
            base, faults=strag, recovery=CheckpointRestore(),
            autoscaler=ReactiveAutoscaler(min_workers=1, max_workers=8)),
        "s3_crash_restore": dict(
            s3, faults=FaultPlan(crashes=(WorkerCrash(3, 20.0),)),
            recovery=CheckpointRestore(checkpoint_every=4)),
    }


def golden_sweep_grid() -> SweepGrid:
    return SweepGrid(n_params=N_PARAMS,
                     compute_s_per_batch=ram_scaled_compute(0.9),
                     archs=PAPER_ARCHS, n_workers=(2, 4, 8),
                     ram_gb=(1.0, 2.0, 3.0), channels=(REDIS, S3),
                     accumulation=(8, 24),
                     significant_fraction=(0.1, 0.3))


def sweep_fingerprint() -> dict:
    """Per-column sha256 over the raw bytes + first/last values in hex
    (the spot values make diffs debuggable when a hash moves)."""
    vec = sweep_analytic(golden_sweep_grid())
    out = {"n_points": len(vec)}
    for col in SWEEP_COLUMNS:
        a = getattr(vec, col)
        arr = np.asarray(a)
        spots = ([str(arr[0]), str(arr[-1])] if arr.dtype.kind == "U"
                 else [_hex(arr[0]), _hex(arr[-1])])
        out[col] = {"sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
                    "first_last": spots}
    return out


def collect() -> dict:
    golden = {"epoch": {}, "runtime": {}, "sweep": sweep_fingerprint()}
    for arch in PAPER_ARCHS:
        golden["epoch"][arch] = {
            name: epoch_fingerprint(simulate_epoch(arch, **kw))
            for name, kw in epoch_scenarios().items()}
        golden["runtime"][arch] = {
            name: runtime_fingerprint(run_event_epoch(arch, **kw))
            for name, kw in runtime_scenarios().items()}
    return golden


def main():
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    golden = collect()
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    n = sum(len(v) for v in golden["epoch"].values()) \
        + sum(len(v) for v in golden["runtime"].values())
    print(f"wrote {GOLDEN_PATH}: {n} report fingerprints "
          f"+ {golden['sweep']['n_points']}-point sweep")


if __name__ == "__main__":
    main()
