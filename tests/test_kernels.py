"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RS = np.random.RandomState(0)


# ---------------------------------------------------------------------------
# sliding-window flash attention
# ---------------------------------------------------------------------------
SWA_CASES = [
    # (B, S, H, KV, hd, window, dtype)
    (2, 256, 4, 2, 64, None, jnp.float32),
    (1, 512, 8, 8, 128, 128, jnp.float32),
    (2, 256, 4, 1, 32, 64, jnp.bfloat16),
    (1, 128, 2, 2, 64, None, jnp.bfloat16),
    (1, 256, 6, 3, 32, 32, jnp.float32),
    (3, 128, 4, 4, 128, 96, jnp.float32),
]


@pytest.mark.parametrize("B,S,H,KV,hd,window,dtype", SWA_CASES)
def test_swa_kernel_vs_ref(B, S, H, KV, hd, window, dtype):
    q = jnp.asarray(RS.randn(B, S, H, hd), dtype)
    k = jnp.asarray(RS.randn(B, S, KV, hd), dtype)
    v = jnp.asarray(RS.randn(B, S, KV, hd), dtype)
    out = ops.swa_attention(q, k, v, window=window)
    expect = ref.swa_attention(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_swa_kernel_grad():
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(RS.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(RS.randn(B, S, KV, hd), jnp.float32)
    v = jnp.asarray(RS.randn(B, S, KV, hd), jnp.float32)
    f1 = lambda *a: jnp.sum(jnp.tanh(ops.swa_attention(*a, window=64)))
    f2 = lambda *a: jnp.sum(jnp.tanh(ref.swa_attention(*a, window=64)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# block significance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,b", [(100, 128), (1000, 256), (7, 512),
                                 (513, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_norms_vs_ref(n, b, dtype):
    x = jnp.asarray(RS.randn(n, b), dtype)
    from repro.kernels.block_significance import block_norms
    got = block_norms(x, interpret=True)
    want = ref.block_norms(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("n,b", [(100, 128), (513, 64)])
@pytest.mark.parametrize("threshold", [0.5, 1.0, 2.0])
def test_block_significance_vs_ref(n, b, threshold):
    x = jnp.asarray(RS.randn(n, b), jnp.float32)
    got = ops.block_significance(x, threshold)
    want = ref.block_significance(x, threshold)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,b", [(64, 128), (257, 256)])
def test_significance_filter_vs_ref(n, b):
    x = jnp.asarray(RS.randn(n, b), jnp.float32)
    kept, resid, mask = ops.significance_filter(x, threshold=1.0)
    k2, r2, m2 = ref.significance_filter(x, threshold=1.0)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(m2))
    np.testing.assert_allclose(np.asarray(kept), np.asarray(k2),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(r2),
                               atol=1e-6)


@pytest.mark.parametrize("n,b", [(64, 128), (1000, 256)])
def test_significance_filter_conservation(n, b):
    x = jnp.asarray(RS.randn(n, b), jnp.float32)
    kept, resid, mask = ops.significance_filter(x, threshold=1.0)
    k2, r2 = ref.masked_filter(x, mask)
    np.testing.assert_allclose(np.asarray(kept), np.asarray(k2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(r2), atol=1e-6)
    # error feedback conservation: kept + residual == input
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(x),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# fused adamw
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [100, 4096, 65537])
@pytest.mark.parametrize("pdtype", [jnp.float32, jnp.bfloat16])
def test_fused_adamw_vs_ref(n, pdtype):
    g = jnp.asarray(RS.randn(n), pdtype)
    m = jnp.asarray(RS.randn(n) * 0.01, jnp.float32)
    v = jnp.abs(jnp.asarray(RS.randn(n) * 0.01, jnp.float32))
    p = jnp.asarray(RS.randn(n), pdtype)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.01)
    u1, m1, v1 = ops.fused_adamw(g, m, v, p, c1=jnp.asarray(0.1),
                                 c2=jnp.asarray(0.05), **kw)
    u2, m2, v2 = ref.fused_adamw_flat(g, m, v, p, jnp.asarray(0.1),
                                      jnp.asarray(0.05), **kw)
    np.testing.assert_allclose(np.asarray(u1, np.float32),
                               np.asarray(u2.astype(pdtype), np.float32),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5)


def test_fused_adamw_optimizer_path():
    """optim.adamw(use_fused=True) must match the unfused optimizer."""
    from repro import optim
    params = {"a": jnp.asarray(RS.randn(33, 7), jnp.float32),
              "b": jnp.asarray(RS.randn(5), jnp.float32)}
    grads = jax.tree.map(lambda p: jnp.asarray(RS.randn(*p.shape),
                                               jnp.float32), params)
    o1 = optim.adamw(1e-3, weight_decay=0.01)
    o2 = optim.adamw(1e-3, weight_decay=0.01, use_fused=True)
    s1, s2 = o1.init(params), o2.init(params)
    u1, s1 = o1.update(grads, s1, params)
    u2, s2 = o2.update(grads, s2, params)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# RWKV6 chunked wkv
# ---------------------------------------------------------------------------
WKV_CASES = [
    # (B, T, H, N, chunk, dtype)
    (2, 64, 2, 32, 16, jnp.float32),
    (1, 128, 4, 64, 64, jnp.float32),
    (2, 96, 3, 16, 32, jnp.float32),   # chunk auto-halves to divide T
    (1, 64, 2, 32, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("B,T,H,N,chunk,dtype", WKV_CASES)
def test_wkv6_kernel_vs_exact_recurrence(B, T, H, N, chunk, dtype):
    r = jnp.asarray(RS.randn(B, T, H, N) * 0.5, dtype)
    k = jnp.asarray(RS.randn(B, T, H, N) * 0.5, dtype)
    v = jnp.asarray(RS.randn(B, T, H, N) * 0.5, dtype)
    logw = -jnp.exp(jnp.asarray(RS.randn(B, T, H, N) * 0.5 - 2.0,
                                jnp.float32)).astype(dtype)
    u = jnp.asarray(RS.randn(H, N) * 0.5, dtype)
    got = ops.wkv6(r, k, v, logw, u, chunk=chunk)
    want = ref.wkv6(r, k, v, logw, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
