"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, robust_agg

RS = np.random.RandomState(0)


# ---------------------------------------------------------------------------
# sliding-window flash attention
# ---------------------------------------------------------------------------
SWA_CASES = [
    # (B, S, H, KV, hd, window, dtype)
    (2, 256, 4, 2, 64, None, jnp.float32),
    (1, 512, 8, 8, 128, 128, jnp.float32),
    (2, 256, 4, 1, 32, 64, jnp.bfloat16),
    (1, 128, 2, 2, 64, None, jnp.bfloat16),
    (1, 256, 6, 3, 32, 32, jnp.float32),
    (3, 128, 4, 4, 128, 96, jnp.float32),
]


@pytest.mark.parametrize("B,S,H,KV,hd,window,dtype", SWA_CASES)
def test_swa_kernel_vs_ref(B, S, H, KV, hd, window, dtype):
    q = jnp.asarray(RS.randn(B, S, H, hd), dtype)
    k = jnp.asarray(RS.randn(B, S, KV, hd), dtype)
    v = jnp.asarray(RS.randn(B, S, KV, hd), dtype)
    out = ops.swa_attention(q, k, v, window=window)
    expect = ref.swa_attention(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_swa_kernel_grad():
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(RS.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(RS.randn(B, S, KV, hd), jnp.float32)
    v = jnp.asarray(RS.randn(B, S, KV, hd), jnp.float32)
    f1 = lambda *a: jnp.sum(jnp.tanh(ops.swa_attention(*a, window=64)))
    f2 = lambda *a: jnp.sum(jnp.tanh(ref.swa_attention(*a, window=64)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# block significance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,b", [(100, 128), (1000, 256), (7, 512),
                                 (513, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_norms_vs_ref(n, b, dtype):
    x = jnp.asarray(RS.randn(n, b), dtype)
    from repro.kernels.block_significance import block_norms
    got = block_norms(x, interpret=True)
    want = ref.block_norms(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("n,b", [(100, 128), (513, 64)])
@pytest.mark.parametrize("threshold", [0.5, 1.0, 2.0])
def test_block_significance_vs_ref(n, b, threshold):
    x = jnp.asarray(RS.randn(n, b), jnp.float32)
    got = ops.block_significance(x, threshold)
    want = ref.block_significance(x, threshold)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,b", [(64, 128), (257, 256)])
def test_significance_filter_vs_ref(n, b):
    x = jnp.asarray(RS.randn(n, b), jnp.float32)
    kept, resid, mask = ops.significance_filter(x, threshold=1.0)
    k2, r2, m2 = ref.significance_filter(x, threshold=1.0)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(m2))
    np.testing.assert_allclose(np.asarray(kept), np.asarray(k2),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(r2),
                               atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_filter_preserves_dtype(dtype):
    """bf16 gradients must come back bf16 from kernel AND oracle — the
    kernel used to pin out_shape to fp32, silently doubling the
    filtered-sync wire bytes."""
    from repro.kernels import block_significance as bs
    x = jnp.asarray(RS.randn(257, 256), dtype)
    mask = ref.block_significance(x, 1.0)
    kept, resid = bs.masked_filter(x, mask, interpret=True)
    k2, r2 = ref.masked_filter(x, mask)
    assert kept.dtype == dtype and resid.dtype == dtype
    assert k2.dtype == dtype and r2.dtype == dtype
    # both paths filter in fp32 and round once to the input dtype, so
    # they agree bit-for-bit even in bf16
    np.testing.assert_array_equal(np.asarray(kept, np.float32),
                                  np.asarray(k2, np.float32))
    np.testing.assert_array_equal(np.asarray(resid, np.float32),
                                  np.asarray(r2, np.float32))


@pytest.mark.parametrize("n,b", [(64, 128), (1000, 256)])
def test_significance_filter_conservation(n, b):
    x = jnp.asarray(RS.randn(n, b), jnp.float32)
    kept, resid, mask = ops.significance_filter(x, threshold=1.0)
    k2, r2 = ref.masked_filter(x, mask)
    np.testing.assert_allclose(np.asarray(kept), np.asarray(k2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(r2), atol=1e-6)
    # error feedback conservation: kept + residual == input
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(x),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# fused adamw
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [100, 4096, 65537])
@pytest.mark.parametrize("pdtype", [jnp.float32, jnp.bfloat16])
def test_fused_adamw_vs_ref(n, pdtype):
    g = jnp.asarray(RS.randn(n), pdtype)
    m = jnp.asarray(RS.randn(n) * 0.01, jnp.float32)
    v = jnp.abs(jnp.asarray(RS.randn(n) * 0.01, jnp.float32))
    p = jnp.asarray(RS.randn(n), pdtype)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.01)
    u1, m1, v1 = ops.fused_adamw(g, m, v, p, c1=jnp.asarray(0.1),
                                 c2=jnp.asarray(0.05), **kw)
    u2, m2, v2 = ref.fused_adamw_flat(g, m, v, p, jnp.asarray(0.1),
                                      jnp.asarray(0.05), **kw)
    np.testing.assert_allclose(np.asarray(u1, np.float32),
                               np.asarray(u2.astype(pdtype), np.float32),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5)


@pytest.mark.parametrize("n,tile", [
    (100, (8, 16)),     # n < one tile
    (256, (8, 16)),     # exact tile multiple, empty remainder
    (257, (8, 16)),     # one past a tile boundary
    (7, (16, 128)),     # n smaller than a single row
])
def test_fused_adamw_tile_edges(n, tile):
    g = jnp.asarray(RS.randn(n), jnp.float32)
    m = jnp.asarray(RS.randn(n) * 0.01, jnp.float32)
    v = jnp.abs(jnp.asarray(RS.randn(n) * 0.01, jnp.float32))
    p = jnp.asarray(RS.randn(n), jnp.float32)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.01)
    c1, c2 = jnp.asarray(0.1), jnp.asarray(0.05)
    from repro.kernels.fused_adamw import fused_adamw_flat
    got = fused_adamw_flat(g, m, v, p, c1, c2, tile=tile,
                           interpret=True, **kw)
    want = ref.fused_adamw_flat(g, m, v, p, c1, c2, **kw)
    for a, b in zip(got, want):
        assert a.shape == (n,)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_fused_adamw_optimizer_path():
    """optim.adamw(use_fused=True) must match the unfused optimizer."""
    from repro import optim
    params = {"a": jnp.asarray(RS.randn(33, 7), jnp.float32),
              "b": jnp.asarray(RS.randn(5), jnp.float32)}
    grads = jax.tree.map(lambda p: jnp.asarray(RS.randn(*p.shape),
                                               jnp.float32), params)
    o1 = optim.adamw(1e-3, weight_decay=0.01)
    o2 = optim.adamw(1e-3, weight_decay=0.01, use_fused=True)
    s1, s2 = o1.init(params), o2.init(params)
    u1, s1 = o1.update(grads, s1, params)
    u2, s2 = o2.update(grads, s2, params)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# RWKV6 chunked wkv
# ---------------------------------------------------------------------------
WKV_CASES = [
    # (B, T, H, N, chunk, dtype)
    (2, 64, 2, 32, 16, jnp.float32),
    (1, 128, 4, 64, 64, jnp.float32),
    (2, 96, 3, 16, 32, jnp.float32),   # chunk auto-halves to divide T
    (1, 64, 2, 32, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("B,T,H,N,chunk,dtype", WKV_CASES)
def test_wkv6_kernel_vs_exact_recurrence(B, T, H, N, chunk, dtype):
    r = jnp.asarray(RS.randn(B, T, H, N) * 0.5, dtype)
    k = jnp.asarray(RS.randn(B, T, H, N) * 0.5, dtype)
    v = jnp.asarray(RS.randn(B, T, H, N) * 0.5, dtype)
    logw = -jnp.exp(jnp.asarray(RS.randn(B, T, H, N) * 0.5 - 2.0,
                                jnp.float32)).astype(dtype)
    u = jnp.asarray(RS.randn(H, N) * 0.5, dtype)
    got = ops.wkv6(r, k, v, logw, u, chunk=chunk)
    want = ref.wkv6(r, k, v, logw, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# robust aggregation kernels (trimmed mean / median / krum / weiszfeld)
# ---------------------------------------------------------------------------
# (W, trailing shape, dtype) — exercising every tiling regime:
#  D < one lane (pad-to-128), D == one tile (empty remainder),
#  D % tile != 0 (one-past-boundary and ragged), odd D, bf16 inputs,
#  and a trailing shape that must round-trip.
RA_CASES = [
    (5, (1000,), jnp.float32),     # ragged remainder inside one tile
    (8, (513,), jnp.float32),      # one past a 512-tile boundary
    (16, (127,), jnp.float32),     # D < one lane: pad to 128
    (3, (512,), jnp.float32),      # exact tile, empty remainder
    (12, (131,), jnp.bfloat16),    # odd (prime) D + bf16 stack
    (4, (7, 9), jnp.float32),      # trailing shape round-trip
]
# small tile so multi-tile grids actually run in the interpreter
RA_TILE = 512


def _ra_stack(W, shape, dtype):
    x = RS.randn(W, *shape) * RS.choice([1.0, 30.0], size=(W,) + (1,) *
                                        len(shape))
    return jnp.asarray(x, dtype)


def _ra_tols(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("W,shape,dtype", RA_CASES)
@pytest.mark.parametrize("trim", [1, 2])
def test_robust_trimmed_mean_kernel_vs_ref(W, shape, dtype, trim):
    if W <= 2 * trim:
        pytest.skip("W too small for this trim")
    x = _ra_stack(W, shape, dtype)
    want = np.asarray(ref.trimmed_mean(x, trim))
    fused = robust_agg.trimmed_mean(x, trim, tile_d=RA_TILE)
    interp = robust_agg.trimmed_mean(x, trim, tile_d=RA_TILE,
                                     interpret=True)
    assert fused.shape == x.shape[1:]
    np.testing.assert_allclose(np.asarray(fused), want, **_ra_tols(dtype))
    np.testing.assert_allclose(np.asarray(interp), want,
                               **_ra_tols(dtype))


@pytest.mark.parametrize("W,shape,dtype", RA_CASES)
def test_robust_coordinate_median_kernel_vs_ref(W, shape, dtype):
    x = _ra_stack(W, shape, dtype)
    want = np.asarray(ref.coordinate_median(x))
    fused = robust_agg.coordinate_median(x, tile_d=RA_TILE)
    interp = robust_agg.coordinate_median(x, tile_d=RA_TILE,
                                          interpret=True)
    assert fused.shape == x.shape[1:]
    np.testing.assert_allclose(np.asarray(fused), want, **_ra_tols(dtype))
    np.testing.assert_allclose(np.asarray(interp), want,
                               **_ra_tols(dtype))


@pytest.mark.parametrize("W,shape,dtype", RA_CASES)
def test_robust_krum_pairwise_kernel_vs_ref(W, shape, dtype):
    x = _ra_stack(W, shape, dtype)
    want = np.asarray(ref.krum_pairwise(x))
    scale = want.max() + 1e-6
    fused = np.asarray(robust_agg.krum_pairwise(x, tile_d=RA_TILE))
    interp = np.asarray(robust_agg.krum_pairwise(x, tile_d=RA_TILE,
                                                 interpret=True))
    # Gram-form cancellation: compare relative to the matrix scale
    rel = 2e-2 if dtype == jnp.bfloat16 else 2e-6
    assert np.max(np.abs(fused - want)) / scale < rel
    assert np.max(np.abs(interp - want)) / scale < rel
    assert (fused >= 0).all() and (interp >= 0).all()


@pytest.mark.parametrize("W,shape,dtype", RA_CASES)
def test_robust_weiszfeld_step_kernel_vs_ref(W, shape, dtype):
    x = _ra_stack(W, shape, dtype)
    flat = np.asarray(x, np.float32).reshape(W, -1)
    z = jnp.asarray(np.median(flat, axis=0))
    floor = 1e-12 * max(np.linalg.norm(flat, axis=1).max(), 1e-12)
    want = np.asarray(ref.weiszfeld_step(x, z, floor))
    fused = robust_agg.weiszfeld_step(x, z, floor, tile_d=RA_TILE)
    cached = robust_agg.weiszfeld_step(
        x, z, floor, row_sqnorms=jnp.sum(jnp.asarray(flat) ** 2, axis=1),
        tile_d=RA_TILE)
    interp = robust_agg.weiszfeld_step(x, z, floor, tile_d=RA_TILE,
                                       interpret=True)
    for got in (fused, cached, interp):
        np.testing.assert_allclose(np.asarray(got), want,
                                   **_ra_tols(dtype))


def test_robust_agg_kernels_validate_inputs():
    x = jnp.ones((4, 16))
    with pytest.raises(ValueError):
        robust_agg.trimmed_mean(x, trim=0)
    with pytest.raises(ValueError):
        robust_agg.trimmed_mean(x, trim=2)       # W <= 2*trim
    with pytest.raises(ValueError):
        robust_agg.weiszfeld_step(x, jnp.ones(15), 1e-12)  # z length


# ---------------------------------------------------------------------------
# kernel bench (BENCH_kernels.json): deterministic spec + floors
# ---------------------------------------------------------------------------
def test_entry_io_bytes_pins_compiled_io():
    from repro.costmodel.hlo_analysis import entry_io_bytes
    fn = jax.jit(lambda x: jnp.sum(x, axis=0))
    hlo = fn.lower(jnp.zeros((8, 4096), jnp.float32)).compile().as_text()
    assert entry_io_bytes(hlo) == (8 * 4096 * 4, 4096 * 4)
    assert entry_io_bytes("no entry header here") == (0, 0)


def test_kernel_bench_spec_is_deterministic():
    """The hashed sections of BENCH_kernels.json are a pure function of
    (configs, SEED): same case table on re-derivation, timings and the
    machine probe excluded from the content hash."""
    from benchmarks import kernel_bench as kb
    a = kb.kernel_cases(quick=True)
    assert a == kb.kernel_cases(quick=True)
    # every public kernel appears in both modes; krum's oracle-memory
    # cap stays tighter than the general cap
    full = kb.kernel_cases(quick=False)
    assert {c["kernel"] for c in full} == {c["kernel"] for c in a}
    krum_d = max(c["D"] for c in full if c["kernel"] == "krum_pairwise")
    other_d = max(c["D"] for c in full if c["kernel"] == "trimmed_mean")
    assert krum_d < other_d
    payload = {"benchmark": "kernel_bench", "quick": True,
               "seed": kb.SEED, "spec": a,
               "probe": {"stream_bytes_per_s": 123.0},
               "results": [{"kernel_s": 1.0}]}
    h = kb._content_hash(payload)
    payload["probe"]["stream_bytes_per_s"] = 456.0
    payload["results"] = []
    assert kb._content_hash(payload) == h


@pytest.mark.slow
def test_kernel_bench_quick_floors(tmp_path):
    """Every --quick row clears its per-backend roofline and speedup
    floors, and the stored content hash re-derives from the payload's
    deterministic sections."""
    import json
    from benchmarks import kernel_bench as kb
    rows = []
    path = tmp_path / "BENCH_kernels.json"
    kb.run(rows, quick=True, json_path=str(path))
    payload = json.loads(path.read_text())
    assert payload["results"]
    misses = [r for r in payload["results"] if not r["passed"]]
    assert not misses, misses
    clone = dict(payload)
    clone.pop("content_hash")
    assert payload["content_hash"] == kb._content_hash(clone)
    for r in payload["results"]:
        assert r["entry_param_bytes"] > 0 and r["entry_result_bytes"] > 0
