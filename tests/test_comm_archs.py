"""ISSUE 10: asynchronous / semi-sync / compressed-communication
architectures.  The specs register ONLY through ``register_arch``
(paper specs and goldens untouched); these tests pin the staleness
model, the compressed wire bytes, the barrier-free event-runtime path,
and the flow through the sweep machinery.
"""
import dataclasses

import numpy as np
import pytest

from repro.serverless import (ARCHS, EventSweepPoint, FaultPlan,
                              FaultRates, ServerlessSetup, SweepGrid,
                              get_arch, list_archs, run_event_epoch,
                              simulate_epoch, sweep_analytic,
                              sweep_events)
from repro.serverless.archs import (COMPRESSION_SCHEMES, ArchSpec,
                                    _spirt_terms)
from repro.serverless.faults import (ByzantineWorker, Straggler,
                                     WorkerCrash)
from repro.serverless.simulator import round_plan
from repro.serverless.sweep import scalar_sweep
from repro.serverless.traces import lambda_default

N_PARAMS = int(4.2e6)
COMP = 0.9
NEW_ARCHS = ("local_sgd", "async_spirt", "async_spirt_q8",
             "scatterreduce_q8", "spirt_sf")


# ---------------------------------------------------------------------------
# registry + spec validation
# ---------------------------------------------------------------------------
def test_new_archs_registered_after_paper_five():
    names = list_archs()
    assert names[:5] == ARCHS
    for a in NEW_ARCHS:
        assert a in names and not get_arch(a).paper


def test_async_spec_requires_bounded_staleness():
    base = get_arch("async_spirt")
    with pytest.raises(ValueError, match="staleness_bound"):
        dataclasses.replace(base, name="_bad", staleness_bound=0.0)
    with pytest.raises(ValueError, match="staleness_bound"):
        dataclasses.replace(base, name="_bad",
                            staleness_bound=float("inf"))
    with pytest.raises(ValueError, match="staleness_penalty"):
        dataclasses.replace(base, name="_bad", staleness_penalty=0.0)
    with pytest.raises(ValueError, match="non-negative"):
        dataclasses.replace(base, name="_bad", staleness_penalty=-0.1)


def test_unknown_compression_scheme_rejected():
    with pytest.raises(ValueError, match="unknown compression"):
        ArchSpec(name="_bad", round_terms=_spirt_terms,
                 compression="fp8")
    assert set(COMPRESSION_SCHEMES) == {"int8", "significance"}


def test_paper_specs_carry_no_async_or_compression_fields():
    """Goldens depend on the paper five never entering the new code
    paths — their arithmetic must be provably untouched."""
    for a in ARCHS:
        spec = get_arch(a)
        assert spec.barrier_sync and spec.compression is None
        assert spec.staleness_penalty == 0.0


# ---------------------------------------------------------------------------
# staleness model
# ---------------------------------------------------------------------------
def test_staleness_tax_inflates_work_not_rounds():
    plain = round_plan("spirt", n_params=N_PARAMS,
                       compute_s_per_batch=COMP)
    taxed = round_plan("async_spirt", n_params=N_PARAMS,
                       compute_s_per_batch=COMP)
    assert taxed.n_rounds == plain.n_rounds          # integral, untouched
    spec = get_arch("async_spirt")
    W = ServerlessSetup().n_workers
    factor = 1.0 + spec.staleness_penalty * min(W - 1,
                                                spec.staleness_bound)
    assert taxed.batches_per_round == pytest.approx(
        plain.batches_per_round * factor)
    assert taxed.sync_bytes > 0


def test_staleness_capped_at_bound():
    """Past the bound, growing the fleet must not grow the tax."""
    spec = get_arch("async_spirt")
    def batches(W):
        return round_plan("async_spirt", n_params=N_PARAMS,
                          compute_s_per_batch=COMP,
                          setup=ServerlessSetup(n_workers=W)
                          ).batches_per_round
    wide, wider = batches(16), batches(64)
    assert wide == wider                 # both capped at staleness_bound
    assert batches(2) < wide             # below the bound the tax grows


def test_async_sync_is_o1_in_fleet_size():
    """The point of going barrier-free: SPIRT's (W-1) cross-worker
    fan-in disappears, so at scale the async variant syncs cheaper even
    after the staleness tax."""
    def sync(arch, W):
        return simulate_epoch(
            arch, n_params=N_PARAMS, compute_s_per_batch=COMP,
            setup=ServerlessSetup(n_workers=W)).stages.sync
    assert sync("async_spirt", 16) < sync("spirt", 16)
    assert sync("async_spirt", 64) < 0.25 * sync("spirt", 64)


# ---------------------------------------------------------------------------
# compressed wire bytes
# ---------------------------------------------------------------------------
def test_int8_wire_scale_matches_quantized_scatterreduce():
    """The analytic scheme and the real strategy must bill the same
    bytes-per-gradient-byte, or the sweeps lie about the hardware."""
    a = simulate_epoch("scatterreduce_q8", n_params=N_PARAMS,
                       compute_s_per_batch=COMP)
    b = simulate_epoch("scatterreduce", n_params=N_PARAMS,
                       compute_s_per_batch=COMP)
    ratio = a.comm_bytes_per_worker / b.comm_bytes_per_worker
    assert ratio == pytest.approx(0.25 * (1 + 4.0 / 512))


def test_significance_wire_scale_tracks_fraction():
    def comm(sf):
        return simulate_epoch("spirt_sf", n_params=N_PARAMS,
                              compute_s_per_batch=COMP,
                              significant_fraction=sf
                              ).comm_bytes_per_worker
    dense = simulate_epoch("spirt", n_params=N_PARAMS,
                           compute_s_per_batch=COMP).comm_bytes_per_worker
    for sf in (0.1, 0.3, 0.9):
        assert comm(sf) / dense == pytest.approx(sf)


def test_compression_shrinks_sync_time_and_cost():
    for comp_arch, dense_arch in (("scatterreduce_q8", "scatterreduce"),
                                  ("spirt_sf", "spirt"),
                                  ("async_spirt_q8", "async_spirt")):
        a = simulate_epoch(comp_arch, n_params=N_PARAMS,
                           compute_s_per_batch=COMP)
        b = simulate_epoch(dense_arch, n_params=N_PARAMS,
                           compute_s_per_batch=COMP)
        assert a.stages.sync < b.stages.sync, comp_arch


# ---------------------------------------------------------------------------
# vectorized sweep bit-exactness (the elementwise contract)
# ---------------------------------------------------------------------------
def test_new_archs_vectorized_matches_scalar():
    grid = SweepGrid(n_params=N_PARAMS, compute_s_per_batch=COMP,
                     archs=NEW_ARCHS, n_workers=(2, 4, 16),
                     accumulation=(8, 24))
    vec = sweep_analytic(grid)
    for i, rep in enumerate(scalar_sweep(grid)):
        assert vec.per_worker_s[i] == rep.per_worker_s, i
        assert vec.total_cost[i] == rep.total_cost, i


# ---------------------------------------------------------------------------
# barrier-free event runtime
# ---------------------------------------------------------------------------
def test_async_plan_is_barrier_free():
    assert not round_plan("async_spirt", n_params=N_PARAMS,
                          compute_s_per_batch=COMP).barrier
    assert round_plan("local_sgd", n_params=N_PARAMS,
                      compute_s_per_batch=COMP).barrier


def test_async_straggler_hurts_less_than_sync():
    """A straggler stalls a barrier fleet for the whole epoch; async
    peers just keep committing — the makespan overhead ratio must be
    strictly smaller for the barrier-free arch."""
    # accumulation=2 -> 12 self-paced rounds per worker; with a single
    # round the straggler's one giant compute gates both modes equally
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=COMP,
              accumulation=2, setup=ServerlessSetup(n_workers=4))
    faults = FaultPlan(stragglers=(Straggler(worker=1, slowdown=4.0),))
    def overhead(arch):
        clean = run_event_epoch(arch, **kw).makespan_s
        slow = run_event_epoch(arch, faults=faults, **kw).makespan_s
        return slow / clean
    assert overhead("async_spirt") < 0.7 * overhead("spirt")
    # fast peers absorb the straggler's share from the shared pool, but
    # total work is conserved
    rep = run_event_epoch("async_spirt", faults=faults, **kw)
    assert rep.work_done_batches == pytest.approx(
        4 * round_plan("async_spirt", **kw).total_batches, rel=1e-6)


def test_async_cold_start_spread_spawns_no_phantom_rounds():
    """Regression: a barrier-free worker may only start a round against
    the pool MINUS its peers' in-flight claims.  Without the
    reservation, staggered cold starts let early finishers overdraft
    the epoch with phantom extra rounds (~2x makespan under the
    measured Lambda trace)."""
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=COMP,
              setup=ServerlessSetup(n_workers=4))
    clean = run_event_epoch("async_spirt", **kw)
    spread = FaultPlan(cold_start_extra_s=(0.0, 40.0, 3.0, 9.0))
    rep = run_event_epoch("async_spirt", faults=spread, **kw)
    # exactly one self-paced round per worker: work equals the pool and
    # the compute wall is unchanged
    assert rep.work_done_batches == pytest.approx(
        4 * round_plan("async_spirt", **kw).total_batches)
    assert rep.stage_totals["compute"] == pytest.approx(
        clean.stage_totals["compute"])
    # the epoch ends one cold-start delta after the clean one — no
    # phantom round stretching the tail
    assert rep.makespan_s == pytest.approx(clean.makespan_s + 40.0)


def test_async_crash_takeover_records_recovery():
    rep = run_event_epoch(
        "async_spirt", n_params=N_PARAMS, compute_s_per_batch=COMP,
        faults=FaultPlan(crashes=(WorkerCrash(1, 5.0),)),
        recovery="auto")
    assert [r.mode for r in rep.recoveries] == ["takeover"]
    assert rep.recoveries[0].rejoined_time_s is not None
    assert rep.n_workers_end == 3
    # survivors absorb the dead worker's share of the pool
    assert rep.work_done_batches > 0


def test_async_crash_restore_rejoins_at_next_commit():
    rep = run_event_epoch(
        "async_spirt", n_params=N_PARAMS, compute_s_per_batch=COMP,
        faults=FaultPlan(crashes=(WorkerCrash(1, 5.0),)),
        recovery="restore")
    assert [r.mode for r in rep.recoveries] == ["restore"]
    assert rep.recoveries[0].rejoined_time_s is not None
    assert rep.n_workers_end == 4


def test_async_byzantine_masked_only_with_feasible_trim():
    faults = FaultPlan(byzantine=(ByzantineWorker(worker=2),))
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=COMP,
              faults=faults)
    masked = run_event_epoch("async_spirt", robust_trim=1, **kw)
    assert masked.masked_updates > 0 and masked.poisoned_updates == 0
    poisoned = run_event_epoch("async_spirt", robust_trim=0, **kw)
    assert poisoned.poisoned_updates > 0 and poisoned.masked_updates == 0


def test_async_autoscaler_ticks_on_fleet_equivalent_rounds():
    from repro.serverless.autoscale import ScheduledScaler
    rep = run_event_epoch(
        "async_spirt", n_params=N_PARAMS, compute_s_per_batch=COMP,
        accumulation=2,                  # 12 fleet-equivalent rounds
        autoscaler=ScheduledScaler(schedule=((2, 1),)))
    assert rep.scale_events and rep.scale_events[0][1] == 1
    assert rep.n_workers_peak == 5


@pytest.mark.parametrize("arch", NEW_ARCHS)
def test_new_archs_flow_through_event_sweep_with_trace(arch):
    points = [EventSweepPoint(arch=arch, n_params=N_PARAMS,
                              compute_s_per_batch=COMP)]
    kw = dict(rates=FaultRates(crash_rate=0.5), trace=lambda_default(),
              n_replicates=3, seed=11, processes=1)
    s = sweep_events(points, **kw)[0]
    assert s.makespan_mean_s > 0 and s.cost_mean > 0
    again = sweep_events(points, **kw)[0]
    assert again.makespan_mean_s == s.makespan_mean_s
    assert again.cost_mean == s.cost_mean
