"""Discrete-event runtime: fault injection, recovery, autoscaling.

Covers the behaviours the analytic model cannot express — the point of
the subsystem: crashes recovered by checkpoint-restore vs SPIRT peer
takeover, stragglers gating every barrier, cold-start storms, byzantine
bookkeeping under robust aggregation, reactive elasticity, and
seed-determinism of the whole pipeline.
"""
import dataclasses

import pytest

from repro.serverless import (ByzantineWorker, CheckpointRestore,
                              ColdStartStorm, FaultPlan, PeerTakeover,
                              ReactiveAutoscaler, ScheduledScaler,
                              ServerlessSetup, Straggler, WorkerCrash,
                              run_event_epoch)

N_PARAMS = int(4.2e6)
COMP = 0.9


def _run(arch="allreduce", **kw):
    return run_event_epoch(arch, n_params=N_PARAMS,
                           compute_s_per_batch=COMP,
                           setup=ServerlessSetup(), **kw)


@pytest.fixture(scope="module")
def baseline():
    return {arch: _run(arch) for arch in ("spirt", "allreduce")}


def _crash_plan(base, worker=1, frac=0.4):
    return FaultPlan(crashes=(WorkerCrash(worker, frac * base.makespan_s),))


def test_crash_checkpoint_restore_stalls_fleet(baseline):
    base = baseline["allreduce"]
    rep = _run(faults=_crash_plan(base),
               recovery=CheckpointRestore(checkpoint_every=4))
    assert len(rep.recoveries) == 1
    rec = rep.recoveries[0]
    assert rec.mode == "restore" and rec.worker == 1
    # re-invocation pays detection + cold start at minimum
    assert rec.time_to_recover_s > 1.0 + 2.0
    assert rep.makespan_s > base.makespan_s
    # survivors stalled at the barrier while the worker replayed
    assert rep.stage_totals["wait"] > 0
    # all the work still got done
    assert rep.work_done_batches == pytest.approx(base.work_done_batches)


def test_crash_peer_takeover_spirt(baseline):
    base = baseline["spirt"]
    rep = _run("spirt", faults=_crash_plan(base),
               recovery=PeerTakeover())
    assert len(rep.recoveries) == 1
    assert rep.recoveries[0].mode == "takeover"
    assert rep.n_workers_end == 3          # fleet continues with W-1
    # survivors absorb the partition: full epoch work still completes
    assert rep.work_done_batches == pytest.approx(base.work_done_batches)


def test_spirt_takeover_recovers_faster_than_restore(baseline):
    """The paper's fault-tolerance headline: in-database state makes
    recovery a detection + state-fetch, not a replay."""
    t_spirt = _run("spirt", faults=_crash_plan(baseline["spirt"]),
                   recovery=PeerTakeover()).time_to_recover_s
    t_ar = _run(faults=_crash_plan(baseline["allreduce"]),
                recovery=CheckpointRestore()).time_to_recover_s
    assert 0 < t_spirt < t_ar


def test_straggler_gates_every_barrier(baseline):
    base = baseline["allreduce"]
    rep = _run(faults=FaultPlan(stragglers=(Straggler(2, slowdown=4.0),)))
    # synchronous training: the whole epoch slows toward the straggler's
    # compute, and the three healthy workers burn billed wait time
    assert rep.makespan_s > base.makespan_s + 0.5 * 3 * COMP \
        * ServerlessSetup().batches_per_worker
    assert rep.stage_totals["wait"] > 0
    assert rep.total_cost > base.total_cost


def test_cold_start_storm_delays_and_is_seeded(baseline):
    base = baseline["allreduce"]
    plan = FaultPlan(storm=ColdStartStorm(extra_s=8.0, fraction=0.5),
                     seed=7)
    rep = _run(faults=plan)
    # the slowest cold start gates the first barrier
    assert rep.makespan_s == pytest.approx(base.makespan_s + 8.0, rel=1e-6)
    assert plan.storm_victims(4) == FaultPlan(
        storm=ColdStartStorm(fraction=0.5), seed=7).storm_victims(4)


def test_byzantine_masked_only_under_robust_aggregation():
    plan = FaultPlan(byzantine=(ByzantineWorker(0),))
    plain = _run(faults=plan)
    robust = _run(faults=plan, robust_trim=1)
    assert plain.poisoned_updates > 0 and plain.masked_updates == 0
    assert robust.masked_updates > 0 and robust.poisoned_updates == 0
    # byzantine workers poison updates, not timing
    assert plain.makespan_s == pytest.approx(robust.makespan_s)


def test_autoscaler_counteracts_straggler(baseline):
    plan = FaultPlan(stragglers=(Straggler(2, slowdown=4.0),))
    slow = _run(faults=plan)
    scaled = _run(faults=plan,
                  autoscaler=ReactiveAutoscaler(max_workers=8))
    assert scaled.n_workers_peak > 4
    assert scaled.makespan_s < slow.makespan_s
    # fault-free epochs must not trigger spurious scaling
    quiet = _run(autoscaler=ReactiveAutoscaler(max_workers=8))
    assert quiet.scale_events == []
    assert quiet.makespan_s == pytest.approx(
        baseline["allreduce"].makespan_s)


def test_scheduled_scaler_shortens_epoch(baseline):
    base = baseline["allreduce"]
    rep = _run(autoscaler=ScheduledScaler(schedule=((2, 4),)))
    assert rep.n_workers_peak == 8
    # doubling the fleet after round 2 halves the remaining rounds
    assert rep.rounds < base.rounds
    assert rep.makespan_s < base.makespan_s


def test_fault_plan_random_is_deterministic():
    kw = dict(n_workers=8, horizon_s=100.0, crash_rate=0.3,
              straggler_rate=0.3, byzantine_fraction=0.25, storm_prob=0.5)
    a = FaultPlan.random(seed=11, **kw)
    b = FaultPlan.random(seed=11, **kw)
    c = FaultPlan.random(seed=12, **kw)
    assert a == b
    assert a != c


def test_event_runs_are_deterministic():
    plan = FaultPlan.random(seed=5, n_workers=4, horizon_s=80.0,
                            crash_rate=0.4, straggler_rate=0.4)
    # timeline recording is off by default now; opt in so the
    # event-sequence comparison stays meaningful
    a = _run(faults=plan, recovery=CheckpointRestore(), max_timeline=4096)
    b = _run(faults=plan, recovery=CheckpointRestore(), max_timeline=4096)
    assert a.makespan_s == b.makespan_s
    assert a.total_cost == b.total_cost
    assert a.timeline == b.timeline
    assert len(a.timeline) > 0


def test_billing_follows_pricing_model(baseline):
    """Lambda epochs bill GB-seconds of invocation wall-clock; a crash
    under takeover stops the dead worker's meter early."""
    from repro.costmodel import pricing
    base = baseline["spirt"]
    setup = ServerlessSetup()
    expect = 4 * pricing.lambda_cost(base.makespan_s, setup.ram_gb)
    assert base.total_cost == pytest.approx(expect, rel=1e-9)
    crashed = _run("spirt", faults=_crash_plan(base),
                   recovery=PeerTakeover())
    # dead worker billed < full epoch, survivors billed > fault-free
    assert crashed.total_cost != pytest.approx(base.total_cost, rel=1e-3)
