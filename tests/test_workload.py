"""Workload/RequestPlan: eager validation, seeded determinism, prefix
stability, and trace-rate rescaling (ISSUE 6 tentpole + satellite 4)."""
import dataclasses

import numpy as np
import pytest

from repro.serverless.traces import RequestTrace, request_default
from repro.serving.workload import RequestPlan, Workload


def _trace(**kw):
    base = dict(name="r", inter_arrival_s=(0.5, 1.0, 4.0),
                prompt_tokens=(64.0, 256.0, 1024.0),
                decode_tokens=(8.0, 32.0, 128.0))
    base.update(kw)
    return RequestTrace(**base)


# ------------------------------------------------------------ validation
@pytest.mark.parametrize("kw", [
    dict(n_requests=0, rate_rps=1.0),
    dict(n_requests=-3, rate_rps=1.0),
    dict(),                                  # no rate, no trace
    dict(rate_rps=0.0),
    dict(rate_rps=-2.0),
    dict(rate_rps=float("inf")),
    dict(rate_rps=1.0, prompt_tokens=0),
    dict(rate_rps=1.0, decode_tokens=0),
])
def test_workload_rejects_bad_inputs(kw):
    with pytest.raises(ValueError):
        Workload(**kw)


def test_request_plan_rejects_ragged_and_unsorted():
    with pytest.raises(ValueError):
        RequestPlan(arrival_s=(1.0, 2.0), prompt_tokens=(4,),
                    decode_tokens=(2, 2))
    with pytest.raises(ValueError):
        RequestPlan(arrival_s=(2.0, 1.0), prompt_tokens=(4, 4),
                    decode_tokens=(2, 2))


# ----------------------------------------------------------- determinism
def test_plan_is_pure_function_of_workload_and_seed():
    w = Workload(n_requests=64, rate_rps=2.0)
    assert w.generate(9) == w.generate(9)
    assert w.generate(9) != w.generate(10)
    # equal workloads (fresh objects) agree too
    assert dataclasses.replace(w).generate(9) == w.generate(9)


def test_plan_prefix_stable_as_n_requests_grows():
    """Request i's draws never move when the stream is extended — the
    fault stack's fixed-draws discipline."""
    for kw in (dict(rate_rps=3.0),
               dict(trace=_trace()),
               dict(trace=_trace(), rate_rps=5.0)):
        small = Workload(n_requests=16, **kw).generate(4)
        big = Workload(n_requests=48, **kw).generate(4)
        assert big.arrival_s[:16] == small.arrival_s
        assert big.prompt_tokens[:16] == small.prompt_tokens
        assert big.decode_tokens[:16] == small.decode_tokens


# -------------------------------------------------------------- sampling
def test_poisson_plan_matches_rate_and_fixed_tokens():
    w = Workload(n_requests=4000, rate_rps=8.0, prompt_tokens=256,
                 decode_tokens=32)
    plan = w.generate(0)
    gaps = np.diff((0.0,) + plan.arrival_s)
    assert gaps.min() >= 0
    assert np.mean(gaps) == pytest.approx(1 / 8.0, rel=0.1)
    assert set(plan.prompt_tokens) == {256}
    assert set(plan.decode_tokens) == {32}
    assert plan.total_tokens == 4000 * 32


def test_trace_plan_stays_in_empirical_support():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    tr = _trace()

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31))
    def prop(seed):
        plan = Workload(n_requests=40, trace=tr).generate(seed)
        gaps = np.diff((0.0,) + plan.arrival_s)
        assert all(any(abs(g - s) < 1e-9 for s in tr.inter_arrival_s)
                   for g in gaps)
        assert set(plan.prompt_tokens) <= {int(v)
                                           for v in tr.prompt_tokens}
        assert set(plan.decode_tokens) <= {int(v)
                                           for v in tr.decode_tokens}

    prop()


def test_with_rate_rescales_gaps_preserving_shape():
    """Rescaled gaps hit the target mean rate but keep the trace's
    burstiness (same gap ranking, proportional values)."""
    tr = request_default()
    native = Workload(n_requests=2000, trace=tr).generate(3)
    fast = Workload(n_requests=2000, trace=tr).with_rate(10.0).generate(3)
    g_nat = np.diff((0.0,) + native.arrival_s)
    g_fast = np.diff((0.0,) + fast.arrival_s)
    # same draws, scaled: exact proportionality per request
    scale = (1.0 / 10.0) / float(np.mean(tr.inter_arrival_s))
    assert np.allclose(g_fast, g_nat * scale)
    assert np.mean(g_fast) == pytest.approx(0.1, rel=0.1)
    # token streams untouched by the rate change
    assert fast.prompt_tokens == native.prompt_tokens
    assert fast.decode_tokens == native.decode_tokens


def test_mean_service_tokens_and_rate_helpers():
    w = Workload(n_requests=8, rate_rps=2.0, prompt_tokens=100,
                 decode_tokens=10)
    assert w.mean_rate_rps() == 2.0
    assert w.mean_service_tokens() == (100.0, 10.0)
    wt = Workload(n_requests=8, trace=_trace())
    assert wt.mean_rate_rps() == pytest.approx(1 / np.mean((0.5, 1, 4)))
    p, d = wt.mean_service_tokens()
    assert p == pytest.approx(np.mean((64, 256, 1024)))
    assert d == pytest.approx(np.mean((8, 32, 128)))
