"""Prefill/decode consistency: teacher-forced full forward must match
prefill + single-token decode for every architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import build_model

ARCHS = ["smollm-135m", "gemma3-4b", "rwkv6-7b", "recurrentgemma-2b",
         "mixtral-8x7b", "whisper-small", "pixtral-12b", "phi3-mini-3.8b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        # capacity-based routing drops tokens batch-dependently; disable
        # drops so the equality is exact
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B, S = 2, 33
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_emb"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    logits_full, _ = jax.jit(model.apply)(params, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    logits_pre, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=S))(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0], np.float32),
        np.asarray(logits_full[:, S - 2], np.float32), atol=2e-4)

    logits_dec, _ = jax.jit(
        lambda p, t, c: model.decode_step(p, t, c, S - 1))(
        params, batch["tokens"][:, -1:], cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, S - 1], np.float32), atol=2e-4)


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b",
                                  "mixtral-8x7b"])
def test_swa_variant_decode_runs(arch):
    """The long-context SWA variant must produce finite decode logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, 128, swa_variant=True)
    logits, cache = jax.jit(
        lambda p, t, c: model.decode_step(p, t, c, 0, swa_variant=True))(
        params, jnp.zeros((1, 1), jnp.int32), cache)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_multi_token_decode_matches_forward():
    """Decode 8 tokens sequentially == teacher-forced forward."""
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    B, S, T = 1, 24, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + T), 0,
                              cfg.vocab_size)
    logits_full, _ = model.apply(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :S]},
                             cache_len=S + T)
    dec = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for i in range(T):
        logits, cache = dec(params, toks[:, S + i:S + i + 1], cache,
                            jnp.asarray(S + i))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(logits_full[:, S + i], np.float32), atol=2e-4)
