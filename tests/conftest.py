"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see 1 device (the dry-run sets its own)."""
import jax
import numpy as np
import pytest

# the repro-lint fixture corpus is deliberately-violating source, not
# importable test code
collect_ignore = ["analysis_fixtures"]


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
