"""Fixture: every way seeded-rng fires (serverless/ is a strict dir)."""
import random

import numpy as np

JITTER = np.random.uniform()        # global stream at module level

rng = np.random.default_rng()       # unseeded ctor: OS entropy


def sample_noise():
    return random.random()          # global stream in a strict dir


def make_stream():
    return np.random.RandomState(1234)   # hard-coded seed in a strict dir


def seeded_ok(seed):
    return np.random.default_rng(seed)   # clean: the seed flows in
