"""Fixture oracles for fancy.py (orphan_norm deliberately missing)."""


def fused_scale(x, s):
    return x * s


def half_covered(x):
    return x + 1


def interp_entry(x):
    return x


def forced_interp(x):
    return x


def auto_entry(x):
    return x
