"""Fixture kernels: one covered, one orphan, one twin-but-untested."""


def fused_scale(x, s):
    return x * s


def orphan_norm(x):
    return (x * x).sum()


def half_covered(x):
    return x + 1


def _private_helper(x):
    return x
