"""Fixture Pallas entries: the interpreter hard-coded both ways
(default-True parameter and a literal call-site keyword)."""
from jax.experimental import pallas as pl


def _copy_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def interp_entry(x, interpret=True):
    return pl.pallas_call(_copy_body, out_shape=x,
                          interpret=interpret)(x)


def forced_interp(x):
    return pl.pallas_call(_copy_body, out_shape=x, interpret=True)(x)


def auto_entry(x, interpret=None):
    if interpret is None:
        interpret = False
    return pl.pallas_call(_copy_body, out_shape=x,
                          interpret=interpret)(x)
