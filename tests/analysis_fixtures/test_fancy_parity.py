"""Fixture parity test: covers fused_scale, not half_covered."""
from kernels import fancy, interp_default, ref


def test_fused_scale_parity():
    assert fancy.fused_scale(2.0, 3.0) == ref.fused_scale(2.0, 3.0)


def test_interp_default_fixture_parity():
    pairs = [(interp_default.interp_entry, ref.interp_entry),
             (interp_default.forced_interp, ref.forced_interp),
             (interp_default.auto_entry, ref.auto_entry)]
    assert all(k is not r for k, r in pairs)
