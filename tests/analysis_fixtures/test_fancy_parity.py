"""Fixture parity test: covers fused_scale, not half_covered."""
from kernels import fancy, ref


def test_fused_scale_parity():
    assert fancy.fused_scale(2.0, 3.0) == ref.fused_scale(2.0, 3.0)
