"""Fixture: wall-clock reads outside launch/ and benchmarks/."""
import datetime
import time


def stamp_report(report):
    report["built_at"] = time.time()
    report["day"] = datetime.date.today()
    return report


def measured_ok():
    t0 = time.perf_counter()  # repro: allow[no-wallclock] -- fixture: exercises a reasoned suppression
    return t0
