"""Fixture: host syncs in a helper reachable from a jitted entry."""
import jax
import jax.numpy as jnp
import numpy as np


def _leaky_norm(x):
    total = float(jnp.sum(x))       # Python cast of a fresh traced value
    if jnp.any(x > 0):              # Python branch on a traced array
        x = x / total
    host = np.asarray(x)            # numpy materialisation
    return host.item()              # explicit host sync


@jax.jit
def step(x):
    return _leaky_norm(x * 2.0)
