"""Fixture: every way frozen-spec-mutation fires inside src/."""
import dataclasses

from repro.serverless.archs import ArchSpec, get_arch


def rescale(factor):
    spec = get_arch("scatter_reduce")
    spec.cost_per_gb = factor            # attr assign on a resolved spec
    return spec


def fork():
    return dataclasses.replace(get_arch("allreduce"), n_workers=64)


def tweak(spec: ArchSpec):
    object.__setattr__(spec, "name", "hacked")   # outside __post_init__
    spec.n_workers = 2                   # annotated-param taint
