"""Fixture: barrier-free ArchSpecs registered without a priced
staleness model — each call violates staleness-spec exactly once."""
from repro.serverless.archs import ArchSpec, register_arch


def _terms(**kw):
    return {}


# missing staleness_bound (finding anchors at the call line)
register_arch(ArchSpec(
    name="free_lunch_async",
    round_terms=_terms,
    barrier_sync=False,
    staleness_penalty=0.02,
))

# missing staleness_penalty
register_arch(ArchSpec(
    name="taxless_async",
    round_terms=_terms,
    barrier_sync=False,
    staleness_bound=8.0,
))

# bound present but infinite: unbounded staleness
register_arch(ArchSpec(
    name="unbounded_async",
    round_terms=_terms,
    barrier_sync=False,
    staleness_bound=1e400,
    staleness_penalty=0.02,
))

# penalty present but zero: the tax is disabled
register_arch(ArchSpec(
    name="zero_tax_async",
    round_terms=_terms,
    barrier_sync=False,
    staleness_bound=8.0,
    staleness_penalty=0.0,
))
