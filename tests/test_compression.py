"""Quantized scatter-reduce (beyond-paper): accuracy + byte accounting."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy
from repro.core.compression import QuantizedScatterReduce, _dequant, _quant
from repro.models import build_model


def test_quant_roundtrip_accuracy():
    x = jnp.asarray(np.random.RandomState(0).randn(16, 4, 512), jnp.float32)
    q, s = _quant(x)
    err = jnp.abs(_dequant(q, s) - x)
    assert float(err.max()) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_quantized_sync_close_to_allreduce():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = np.random.RandomState(0)
    batch = {"tokens": r.randint(0, cfg.vocab_size, (8, 32)).astype(
        np.int32)}
    batch["labels"] = batch["tokens"]

    outs = {}
    for name in ("allreduce", "quantized_scatterreduce"):
        ts = build_train_step(model, optim.sgd(0.1), get_strategy(name),
                              mesh)
        state = ts.init_state(jax.random.PRNGKey(0))
        for _ in range(3):
            state, metrics = ts.step_fn(state, batch)
        outs[name] = (np.concatenate(
            [np.asarray(l, np.float32).ravel()
             for l in jax.tree.leaves(state["params"])]),
            float(metrics["loss"]))
    a, qz = outs["allreduce"][0], outs["quantized_scatterreduce"][0]
    # int8 quantization error is small relative to the update magnitude
    rel = np.abs(a - qz).max() / (np.abs(a).max() + 1e-9)
    assert rel < 5e-2, rel
    assert np.isfinite(outs["quantized_scatterreduce"][1])


def test_quantized_comm_bytes_quarter_of_ring():
    grads = [np.zeros(10**6, np.float32)]
    ring = get_strategy("allreduce").comm_bytes(grads, 16)
    qz = get_strategy("quantized_scatterreduce").comm_bytes(grads, 16)
    assert qz < ring / 3.5   # ~4x minus scale overhead
