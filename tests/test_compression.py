"""Quantized scatter-reduce (beyond-paper): accuracy + byte accounting."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy
from repro.core.compression import QuantizedScatterReduce, _dequant, _quant
from repro.models import build_model


def test_quant_roundtrip_accuracy():
    x = jnp.asarray(np.random.RandomState(0).randn(16, 4, 512), jnp.float32)
    q, s = _quant(x)
    err = jnp.abs(_dequant(q, s) - x)
    assert float(err.max()) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_quantized_sync_close_to_allreduce():
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = np.random.RandomState(0)
    batch = {"tokens": r.randint(0, cfg.vocab_size, (8, 32)).astype(
        np.int32)}
    batch["labels"] = batch["tokens"]

    outs = {}
    for name in ("allreduce", "quantized_scatterreduce"):
        ts = build_train_step(model, optim.sgd(0.1), get_strategy(name),
                              mesh)
        state = ts.init_state(jax.random.PRNGKey(0))
        for _ in range(3):
            state, metrics = ts.step_fn(state, batch)
        outs[name] = (np.concatenate(
            [np.asarray(l, np.float32).ravel()
             for l in jax.tree.leaves(state["params"])]),
            float(metrics["loss"]))
    a, qz = outs["allreduce"][0], outs["quantized_scatterreduce"][0]
    # int8 quantization error is small relative to the update magnitude
    rel = np.abs(a - qz).max() / (np.abs(a).max() + 1e-9)
    assert rel < 5e-2, rel
    assert np.isfinite(outs["quantized_scatterreduce"][1])


def test_quantized_comm_bytes_quarter_of_ring():
    grads = [np.zeros(10**6, np.float32)]
    ring = get_strategy("allreduce").comm_bytes(grads, 16)
    qz = get_strategy("quantized_scatterreduce").comm_bytes(grads, 16)
    assert qz < ring / 3.5   # ~4x minus scale overhead


def test_quant_dequant_deterministic():
    """Same input -> bitwise identical quantization, jitted twice (the
    compressed sweeps are a pure function of (grid, seed))."""
    x = jnp.asarray(np.random.RandomState(1).randn(8, 16, 512),
                    jnp.float32)
    f = jax.jit(lambda a: _quant(a))
    q1, s1 = f(x)
    q2, s2 = f(x)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    d = jax.jit(_dequant)
    np.testing.assert_array_equal(np.asarray(d(q1, s1)),
                                  np.asarray(d(q2, s2)))


def test_ef_residual_roundtrip_padded_tail():
    """G=1030 floats with chunk=512 pads 2x512-1030=… a 1018-element
    tail; the residual must be the error-feedback term of the ORIGINAL
    (unpadded) slice, reshaped to the gradient's shape."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    qsr = QuantizedScatterReduce(chunk=512)
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.random.RandomState(2).randn(2, 515), jnp.float32)

    def body(g):
        out, resid, info = qsr.sync([g], [jnp.zeros_like(g)], "data")
        return out[0], resid[0]

    out, resid = shard_map(body, mesh=mesh, in_specs=P(),
                           out_specs=P(), check_vma=False)(x)
    assert out.shape == x.shape and resid.shape == x.shape
    # the residual is exactly acc - dequant(quant(acc)) on the unpadded
    # slice (the padded tail quantizes but never feeds back)
    flat = jnp.pad(x.reshape(-1), (0, (-x.size) % 512))
    q, s = _quant(flat.reshape(1, -1, 512))
    want = (flat - _dequant(q, s).reshape(-1))[:x.size].reshape(x.shape)
    np.testing.assert_array_equal(np.asarray(resid), np.asarray(want))
    # W=1 round trip: output = double-quantized input, error bounded by
    # two quantization steps
    step = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               atol=2 * step + 1e-6)
    # error feedback conserves the signal: out + resid ~ x at the same
    # tolerance
    np.testing.assert_allclose(np.asarray(out + resid), np.asarray(x),
                               atol=2 * step + 1e-6)


def test_comm_bytes_matches_compiled_entry_io():
    """The analytic wire-byte formula vs the compiler: the ENTRY result
    bytes of the quantization stage (the exact payload the all_to_all
    ships) must equal G/4 * (1 + 4/chunk) — the factor comm_bytes and
    archs.COMPRESSION_SCHEMES['int8'] both charge."""
    from repro.costmodel.hlo_analysis import entry_io_bytes
    W, chunk, n = 4, 512, 4 * 512 * 8            # divides evenly
    x = jnp.asarray(np.random.RandomState(3).randn(n), jnp.float32)

    def quant_stage(flat):
        rows = flat.reshape(W, -1, chunk)
        return _quant(rows)

    hlo = jax.jit(quant_stage).lower(x).compile().as_text()
    _, result_bytes = entry_io_bytes(hlo)
    G = n * 4
    want_payload = G / 4 * (1 + 4.0 / chunk)
    assert result_bytes == want_payload
    # and the strategy's end-to-end formula is 2 phases x (W-1)/W of it
    qsr = QuantizedScatterReduce(chunk=chunk)
    assert qsr.comm_bytes([x], W) == int(2 * want_payload * (W - 1) / W)
    # which is exactly what the analytic int8 scheme bills per byte
    from repro.serverless.archs import COMPRESSION_SCHEMES
    assert COMPRESSION_SCHEMES["int8"](0.3) == want_payload / G


def test_mlless_converges_with_compression():
    """PR 5's converges-under-attack pattern, compression edition: real
    training with the significance-filtered strategy (the arch
    spirt_sf's jax_strategy) must still reduce the loss."""
    from repro.serverless.archs import get_arch
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = np.random.RandomState(0)
    batch = {"tokens": r.randint(0, cfg.vocab_size, (8, 32)).astype(
        np.int32)}
    batch["labels"] = batch["tokens"]
    strategy = get_arch("spirt_sf").make_strategy(use_kernel=False)
    ts = build_train_step(model, optim.sgd(0.1), strategy, mesh)
    state = ts.init_state(jax.random.PRNGKey(0))
    losses = []
    for _ in range(6):
        state, metrics = ts.step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        assert 0.0 < float(metrics["significant_fraction"]) <= 1.0
    assert losses[-1] < losses[0]


def test_quantized_converges_with_compression():
    """Same row for the int8 path (async_spirt_q8's jax_strategy)."""
    from repro.serverless.archs import get_arch
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg, remat=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = np.random.RandomState(0)
    batch = {"tokens": r.randint(0, cfg.vocab_size, (8, 32)).astype(
        np.int32)}
    batch["labels"] = batch["tokens"]
    ts = build_train_step(model, optim.sgd(0.1),
                          get_arch("async_spirt_q8").make_strategy(),
                          mesh)
    state = ts.init_state(jax.random.PRNGKey(0))
    losses = []
    for _ in range(6):
        state, metrics = ts.step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
