"""Byzantine-robust aggregation: statistics, Strategy wiring, and a
real 4-way data-parallel training run under an active byzantine worker
(subprocess via repro.launch.byzantine_train — needs its own XLA
device-count flag, same pattern as test_multidevice)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_strategy
from repro.serverless.recovery import (coordinate_median, geometric_median,
                                       krum, trimmed_mean)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def test_trimmed_mean_drops_outliers():
    rs = np.random.RandomState(0)
    honest = rs.randn(3, 64).astype(np.float32)
    evil = honest[0:1] * -50.0
    stacked = jnp.asarray(np.concatenate([evil, honest], axis=0))
    robust = np.asarray(trimmed_mean(stacked, trim=1))
    # the poisoned row never dominates: every coordinate stays inside
    # the honest span
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert (robust >= lo - 1e-6).all() and (robust <= hi + 1e-6).all()
    # and the statistic tracks the honest mean far better than the
    # contaminated mean does
    contaminated = np.asarray(stacked).mean(axis=0)
    err_r = np.abs(robust - honest.mean(axis=0)).mean()
    err_c = np.abs(contaminated - honest.mean(axis=0)).mean()
    assert err_r < 0.2 * err_c


def test_trimmed_mean_validates_width():
    with pytest.raises(ValueError):
        trimmed_mean(jnp.ones((2, 4)), trim=1)


def test_trimmed_mean_fast_path_matches_sort_reference():
    """trim=1 masks one min and one max entry and sums the middle
    values (O(W), no sort — and deliberately NOT the cancellation-prone
    (sum - min - max)/(W - 2) form); it must agree with the full-sort
    reference path on random stacks."""
    from repro.serverless.recovery import trimmed_mean_sort
    rs = np.random.RandomState(3)
    for W, shape in ((3, (16,)), (4, (8, 5)), (7, (4, 3, 2)), (16, (64,))):
        stacked = jnp.asarray(rs.randn(W, *shape).astype(np.float32)
                              * rs.choice([1.0, 50.0], size=(W,) + tuple(
                                  1 for _ in shape)))
        fast = np.asarray(trimmed_mean(stacked, trim=1))
        slow = np.asarray(trimmed_mean_sort(stacked, trim=1))
        np.testing.assert_allclose(fast, slow, rtol=1e-5, atol=1e-5)
    # trim > 1 still routes through the sort path
    stacked = jnp.asarray(rs.randn(7, 11).astype(np.float32))
    np.testing.assert_allclose(np.asarray(trimmed_mean(stacked, trim=2)),
                               np.asarray(trimmed_mean_sort(stacked, 2)),
                               rtol=1e-6)
    # all-equal coordinates (argmin == argmax) return the common value
    np.testing.assert_allclose(
        np.asarray(trimmed_mean(jnp.full((5, 3), 2.5), trim=1)),
        np.full(3, 2.5))


def test_trimmed_mean_fast_path_survives_huge_outliers():
    """The adversarial case the aggregator exists for: a byzantine
    worker shipping a 1e8-scaled gradient must not destroy the honest
    mean through fp32 cancellation (a naive (sum-min-max)/(W-2) returns
    0 here)."""
    from repro.serverless.recovery import trimmed_mean_sort
    honest = np.asarray([[1e-3], [2e-3], [3e-3], [4e-3]], np.float32)
    for evil in (1e8, -1e8, 3e7):
        stacked = jnp.asarray(np.concatenate(
            [honest, np.full((1, 1), evil, np.float32)]))
        fast = np.asarray(trimmed_mean(stacked, trim=1))
        slow = np.asarray(trimmed_mean_sort(stacked, trim=1))
        np.testing.assert_allclose(fast, slow, rtol=1e-6)
        # the outlier is fully masked: result stays in the honest span
        assert honest.min() <= fast[0] <= honest.max(), (evil, fast)


def test_flat_buffer_sync_matches_per_leaf_reference():
    """_RobustAggregate.sync flattens the gradient pytree into one
    contiguous fp32 buffer before the all-gather; under a real
    multi-device shard_map it must agree with the per-leaf reference
    path and round-trip shapes/dtypes."""
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.serverless.recovery import TrimmedMean, CoordinateMedian
        mesh = jax.make_mesh((4,), ("data",))
        r = np.random.RandomState(0)
        grads = {"a": jnp.asarray(r.randn(4, 8, 3), jnp.float32),
                 "b": jnp.asarray(r.randn(4, 5), jnp.bfloat16),
                 "c": jnp.asarray(r.randn(4, 1, 2, 2), jnp.float32)}
        specs = jax.tree.map(lambda g: P("data"), grads)
        for strat in (TrimmedMean(trim=1), CoordinateMedian()):
            f = shard_map(lambda g: strat.sync(g, (), "data")[0],
                          mesh=mesh, in_specs=(specs,), out_specs=specs)
            fr = shard_map(lambda g: strat.sync_per_leaf(g, (), "data")[0],
                           mesh=mesh, in_specs=(specs,), out_specs=specs)
            a, b = f(grads), fr(grads)
            for k in grads:
                assert a[k].dtype == grads[k].dtype
                assert a[k].shape == grads[k].shape
                np.testing.assert_allclose(
                    np.asarray(a[k], np.float32),
                    np.asarray(b[k], np.float32), rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    import os
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_coordinate_median_ignores_minority():
    stacked = jnp.asarray([[1.0, 2.0], [1.2, 2.2], [0.8, 1.8],
                           [1e6, -1e6]])
    med = np.asarray(coordinate_median(stacked))
    np.testing.assert_allclose(med, [1.1, 2.1], atol=0.2)


def test_get_strategy_wires_robust_and_byzantine():
    tm = get_strategy("trimmed_mean", trim=1, microbatches=4)
    assert tm.name == "trimmed_mean" and tm.microbatches == 4
    cm = get_strategy("coordinate_median")
    byz = get_strategy("byzantine", inner=tm, workers=(0,), scale=-8.0)
    assert byz.microbatches == 4            # rides SPIRT accumulation
    like = [jnp.ones((8, 8))]
    assert byz.comm_bytes(like, 4) == tm.comm_bytes(like, 4)
    assert cm.comm_bytes(like, 4) == 4 * 8 * 8 * 4
    with pytest.raises(ValueError):
        get_strategy("byzantine")           # inner is required
    with pytest.raises(ValueError):         # conflicting accumulation
        get_strategy("byzantine", inner=get_strategy("allreduce"),
                     microbatches=4)


def _stats_for(W):
    """Every (statistic, kwargs) applicable at fleet width W."""
    out = [(trimmed_mean, dict(trim=1)), (coordinate_median, {}),
           (geometric_median, dict(tol=1e-6, max_iter=60))]
    if W > 4:
        out.append((trimmed_mean, dict(trim=2)))
    if W >= 5:
        out.append((krum, dict(f=1, m=2)))
    return out


def test_use_pallas_paths_match_jnp_paths():
    """The kernel-backed reductions (use_pallas=True) must agree with
    the original jnp formulations — the paths golden snapshots and
    BENCH_adversarial.json pin — including under a scaled byzantine
    row and with a non-flat trailing shape."""
    rs = np.random.RandomState(7)
    for W, shape in ((5, (257,)), (8, (33, 5)), (12, (40,))):
        x = rs.randn(W, *shape).astype(np.float32)
        x[0] *= 1e4                     # adversarial scaled row
        stacked = jnp.asarray(x)
        for fn, kw in _stats_for(W):
            a = np.asarray(fn(stacked, **kw))
            b = np.asarray(fn(stacked, use_pallas=True, **kw))
            scale = np.abs(a).max() + 1e-12
            np.testing.assert_allclose(b, a, rtol=5e-5,
                                       atol=5e-5 * scale,
                                       err_msg=f"{fn.__name__} {kw}")


def test_use_pallas_matches_adversarial_numpy_twins():
    """Both recovery paths stay pinned to the vectorized numpy twins
    the adversarial sweep simulates with (SIM_AGGREGATORS)."""
    from repro.serverless import adversarial as adv
    rs = np.random.RandomState(11)
    x = rs.randn(9, 128).astype(np.float32)
    x[-1] = -40.0 * x[:-1].mean(axis=0)
    stacked = jnp.asarray(x)
    cases = [
        (trimmed_mean, dict(trim=2), adv.np_trimmed_mean, dict(f=2)),
        (coordinate_median, {}, adv.np_coordinate_median, {}),
        (krum, dict(f=2, m=3), adv.np_krum, dict(f=2, m=3)),
        (geometric_median, dict(tol=1e-7, max_iter=200),
         adv.np_geometric_median, dict(tol=1e-7, max_iter=200)),
    ]
    for fn, kw, np_fn, np_kw in cases:
        want = np_fn(x, **np_kw)
        for use_pallas in (False, True):
            got = np.asarray(fn(stacked, use_pallas=use_pallas, **kw))
            np.testing.assert_allclose(
                got, want, rtol=1e-4, atol=1e-4,
                err_msg=f"{fn.__name__} use_pallas={use_pallas}")


def test_krum_boundary_width_both_paths():
    """W = 2f + 3 is the tightest legal fleet; one fewer worker must
    raise on both paths."""
    rs = np.random.RandomState(5)
    for f in (1, 2):
        W = 2 * f + 3
        stacked = jnp.asarray(rs.randn(W, 64).astype(np.float32))
        a = np.asarray(krum(stacked, f=f))
        b = np.asarray(krum(stacked, f=f, use_pallas=True))
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-5)
        for use_pallas in (False, True):
            with pytest.raises(ValueError):
                krum(stacked[:-1], f=f, use_pallas=use_pallas)


def test_robust_stats_nan_free_under_extremes():
    """Degenerate stacks the aggregators meet in practice — identical
    rows (zero Weiszfeld distances), an all-zero stack, and near-fp32-
    overflow magnitudes — must yield finite results on both paths."""
    ones = np.ones((5, 33), np.float32)
    extremes = [
        jnp.asarray(ones * 3.25),                      # identical rows
        jnp.asarray(np.zeros((5, 33), np.float32)),    # all-zero
        jnp.asarray(ones * np.asarray(
            [[1e15], [-1e15], [2.0], [3.0], [5.0]], np.float32)),
    ]
    for stacked in extremes:
        for fn, kw in _stats_for(5):
            for use_pallas in (False, True):
                out = np.asarray(fn(stacked, use_pallas=use_pallas,
                                    **kw))
                assert np.isfinite(out).all(), (fn.__name__, kw,
                                                use_pallas)


def test_strategy_use_pallas_wiring():
    """use_pallas threads through get_strategy into _reduce; None
    auto-detects (off on CPU) so golden paths stay bit-identical."""
    rs = np.random.RandomState(2)
    stacked = jnp.asarray(rs.randn(7, 90).astype(np.float32))
    for name, kw in (("trimmed_mean", dict(trim=1)),
                     ("coordinate_median", {}),
                     ("krum", dict(f=1, m=1)),
                     ("geometric_median", dict(tol=1e-6, max_iter=40))):
        auto = get_strategy(name, **kw)
        on = get_strategy(name, use_pallas=True, **kw)
        off = get_strategy(name, use_pallas=False, **kw)
        assert auto.use_pallas is None and not auto._kernels_enabled()
        assert on._kernels_enabled() and not off._kernels_enabled()
        a = np.asarray(off._reduce(stacked))
        b = np.asarray(on._reduce(stacked))
        np.testing.assert_allclose(b, a, rtol=5e-5, atol=5e-5)
        # auto on CPU takes the exact jnp path
        np.testing.assert_array_equal(np.asarray(auto._reduce(stacked)),
                                      a)


def test_pallas_twin_deterministic_sweep():
    """Deterministic stand-in for the hypothesis fuzz below (always
    runs): (W, D, trim, dtype) grid over both reduction paths."""
    rs = np.random.RandomState(13)
    for W, D in ((3, 1), (4, 17), (5, 129), (7, 128), (9, 150),
                 (11, 64)):
        x = rs.randn(W, D).astype(np.float32) * rs.choice(
            [1.0, 100.0], size=(W, 1))
        for dtype, tol in ((jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)):
            stacked = jnp.asarray(x, dtype)
            for trim in (1, 2, 3):
                if W <= 2 * trim:
                    continue
                a = np.asarray(trimmed_mean(stacked, trim=trim))
                b = np.asarray(trimmed_mean(stacked, trim=trim,
                                            use_pallas=True))
                scale = np.abs(a).max() + 1e-12
                np.testing.assert_allclose(
                    b, a, rtol=tol, atol=tol * scale,
                    err_msg=f"W={W} D={D} trim={trim} {dtype}")
            a = np.asarray(coordinate_median(stacked))
            b = np.asarray(coordinate_median(stacked, use_pallas=True))
            np.testing.assert_allclose(b, a, rtol=tol, atol=tol,
                                       err_msg=f"W={W} D={D} {dtype}")


if HAVE_HYPOTHESIS:
    @given(W=st.integers(3, 11), D=st.integers(1, 150),
           trim=st.integers(1, 3), seed=st.integers(0, 2**31 - 1),
           bf16=st.booleans())
    @settings(max_examples=40, deadline=None)
    def test_trimmed_mean_pallas_twin_fuzz(W, D, trim, seed, bf16):
        if W <= 2 * trim:
            return
        rs = np.random.RandomState(seed)
        x = rs.randn(W, D).astype(np.float32) * rs.choice(
            [1.0, 100.0], size=(W, 1))
        stacked = jnp.asarray(x, jnp.bfloat16 if bf16 else jnp.float32)
        a = np.asarray(trimmed_mean(stacked, trim=trim))
        b = np.asarray(trimmed_mean(stacked, trim=trim, use_pallas=True))
        tol = 3e-2 if bf16 else 1e-5
        scale = np.abs(a).max() + 1e-12
        np.testing.assert_allclose(b, a, rtol=tol, atol=tol * scale)

    @given(W=st.integers(2, 11), D=st.integers(1, 150),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_coordinate_median_pallas_twin_fuzz(W, D, seed):
        rs = np.random.RandomState(seed)
        stacked = jnp.asarray(rs.randn(W, D).astype(np.float32))
        a = np.asarray(coordinate_median(stacked))
        b = np.asarray(coordinate_median(stacked, use_pallas=True))
        np.testing.assert_allclose(b, a, rtol=1e-6, atol=1e-6)


def test_byzantine_training_converges_only_with_robust_agg():
    """SPIRT accumulation + trimmed mean trains through a -8x byzantine
    worker; plain allreduce under the same attack diverges.  Shares the
    harness with benchmarks/fault_tolerance.py (shorter runs here)."""
    from repro.launch.byzantine_train import run_in_subprocess
    robust = run_in_subprocess("trimmed_mean", steps=40, data_size=2048,
                               timeout=560)
    plain = run_in_subprocess("allreduce", steps=15, data_size=2048,
                              timeout=560)
    # robust: bounded + trending down (averaged tail below head)
    assert robust["max_loss"] < 4.0, robust
    assert robust["tail_loss"] < robust["head_loss"], robust
    # plain averaging under the same attack blows up
    assert plain["final_loss"] > 10.0 * robust["final_loss"], (plain,
                                                               robust)
