"""Byzantine-robust aggregation: statistics, Strategy wiring, and a
real 4-way data-parallel training run under an active byzantine worker
(subprocess via repro.launch.byzantine_train — needs its own XLA
device-count flag, same pattern as test_multidevice)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_strategy
from repro.serverless.recovery import coordinate_median, trimmed_mean


def test_trimmed_mean_drops_outliers():
    rs = np.random.RandomState(0)
    honest = rs.randn(3, 64).astype(np.float32)
    evil = honest[0:1] * -50.0
    stacked = jnp.asarray(np.concatenate([evil, honest], axis=0))
    robust = np.asarray(trimmed_mean(stacked, trim=1))
    # the poisoned row never dominates: every coordinate stays inside
    # the honest span
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert (robust >= lo - 1e-6).all() and (robust <= hi + 1e-6).all()
    # and the statistic tracks the honest mean far better than the
    # contaminated mean does
    contaminated = np.asarray(stacked).mean(axis=0)
    err_r = np.abs(robust - honest.mean(axis=0)).mean()
    err_c = np.abs(contaminated - honest.mean(axis=0)).mean()
    assert err_r < 0.2 * err_c


def test_trimmed_mean_validates_width():
    with pytest.raises(ValueError):
        trimmed_mean(jnp.ones((2, 4)), trim=1)


def test_coordinate_median_ignores_minority():
    stacked = jnp.asarray([[1.0, 2.0], [1.2, 2.2], [0.8, 1.8],
                           [1e6, -1e6]])
    med = np.asarray(coordinate_median(stacked))
    np.testing.assert_allclose(med, [1.1, 2.1], atol=0.2)


def test_get_strategy_wires_robust_and_byzantine():
    tm = get_strategy("trimmed_mean", trim=1, microbatches=4)
    assert tm.name == "trimmed_mean" and tm.microbatches == 4
    cm = get_strategy("coordinate_median")
    byz = get_strategy("byzantine", inner=tm, workers=(0,), scale=-8.0)
    assert byz.microbatches == 4            # rides SPIRT accumulation
    like = [jnp.ones((8, 8))]
    assert byz.comm_bytes(like, 4) == tm.comm_bytes(like, 4)
    assert cm.comm_bytes(like, 4) == 4 * 8 * 8 * 4
    with pytest.raises(ValueError):
        get_strategy("byzantine")           # inner is required
    with pytest.raises(ValueError):         # conflicting accumulation
        get_strategy("byzantine", inner=get_strategy("allreduce"),
                     microbatches=4)


def test_byzantine_training_converges_only_with_robust_agg():
    """SPIRT accumulation + trimmed mean trains through a -8x byzantine
    worker; plain allreduce under the same attack diverges.  Shares the
    harness with benchmarks/fault_tolerance.py (shorter runs here)."""
    from repro.launch.byzantine_train import run_in_subprocess
    robust = run_in_subprocess("trimmed_mean", steps=40, data_size=2048,
                               timeout=560)
    plain = run_in_subprocess("allreduce", steps=15, data_size=2048,
                              timeout=560)
    # robust: bounded + trending down (averaged tail below head)
    assert robust["max_loss"] < 4.0, robust
    assert robust["tail_loss"] < robust["head_loss"], robust
    # plain averaging under the same attack blows up
    assert plain["final_loss"] > 10.0 * robust["final_loss"], (plain,
                                                               robust)
