"""Byzantine-robust aggregation: statistics, Strategy wiring, and a
real 4-way data-parallel training run under an active byzantine worker
(subprocess via repro.launch.byzantine_train — needs its own XLA
device-count flag, same pattern as test_multidevice)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_strategy
from repro.serverless.recovery import coordinate_median, trimmed_mean


def test_trimmed_mean_drops_outliers():
    rs = np.random.RandomState(0)
    honest = rs.randn(3, 64).astype(np.float32)
    evil = honest[0:1] * -50.0
    stacked = jnp.asarray(np.concatenate([evil, honest], axis=0))
    robust = np.asarray(trimmed_mean(stacked, trim=1))
    # the poisoned row never dominates: every coordinate stays inside
    # the honest span
    lo, hi = honest.min(axis=0), honest.max(axis=0)
    assert (robust >= lo - 1e-6).all() and (robust <= hi + 1e-6).all()
    # and the statistic tracks the honest mean far better than the
    # contaminated mean does
    contaminated = np.asarray(stacked).mean(axis=0)
    err_r = np.abs(robust - honest.mean(axis=0)).mean()
    err_c = np.abs(contaminated - honest.mean(axis=0)).mean()
    assert err_r < 0.2 * err_c


def test_trimmed_mean_validates_width():
    with pytest.raises(ValueError):
        trimmed_mean(jnp.ones((2, 4)), trim=1)


def test_trimmed_mean_fast_path_matches_sort_reference():
    """trim=1 masks one min and one max entry and sums the middle
    values (O(W), no sort — and deliberately NOT the cancellation-prone
    (sum - min - max)/(W - 2) form); it must agree with the full-sort
    reference path on random stacks."""
    from repro.serverless.recovery import trimmed_mean_sort
    rs = np.random.RandomState(3)
    for W, shape in ((3, (16,)), (4, (8, 5)), (7, (4, 3, 2)), (16, (64,))):
        stacked = jnp.asarray(rs.randn(W, *shape).astype(np.float32)
                              * rs.choice([1.0, 50.0], size=(W,) + tuple(
                                  1 for _ in shape)))
        fast = np.asarray(trimmed_mean(stacked, trim=1))
        slow = np.asarray(trimmed_mean_sort(stacked, trim=1))
        np.testing.assert_allclose(fast, slow, rtol=1e-5, atol=1e-5)
    # trim > 1 still routes through the sort path
    stacked = jnp.asarray(rs.randn(7, 11).astype(np.float32))
    np.testing.assert_allclose(np.asarray(trimmed_mean(stacked, trim=2)),
                               np.asarray(trimmed_mean_sort(stacked, 2)),
                               rtol=1e-6)
    # all-equal coordinates (argmin == argmax) return the common value
    np.testing.assert_allclose(
        np.asarray(trimmed_mean(jnp.full((5, 3), 2.5), trim=1)),
        np.full(3, 2.5))


def test_trimmed_mean_fast_path_survives_huge_outliers():
    """The adversarial case the aggregator exists for: a byzantine
    worker shipping a 1e8-scaled gradient must not destroy the honest
    mean through fp32 cancellation (a naive (sum-min-max)/(W-2) returns
    0 here)."""
    from repro.serverless.recovery import trimmed_mean_sort
    honest = np.asarray([[1e-3], [2e-3], [3e-3], [4e-3]], np.float32)
    for evil in (1e8, -1e8, 3e7):
        stacked = jnp.asarray(np.concatenate(
            [honest, np.full((1, 1), evil, np.float32)]))
        fast = np.asarray(trimmed_mean(stacked, trim=1))
        slow = np.asarray(trimmed_mean_sort(stacked, trim=1))
        np.testing.assert_allclose(fast, slow, rtol=1e-6)
        # the outlier is fully masked: result stays in the honest span
        assert honest.min() <= fast[0] <= honest.max(), (evil, fast)


def test_flat_buffer_sync_matches_per_leaf_reference():
    """_RobustAggregate.sync flattens the gradient pytree into one
    contiguous fp32 buffer before the all-gather; under a real
    multi-device shard_map it must agree with the per-leaf reference
    path and round-trip shapes/dtypes."""
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.serverless.recovery import TrimmedMean, CoordinateMedian
        mesh = jax.make_mesh((4,), ("data",))
        r = np.random.RandomState(0)
        grads = {"a": jnp.asarray(r.randn(4, 8, 3), jnp.float32),
                 "b": jnp.asarray(r.randn(4, 5), jnp.bfloat16),
                 "c": jnp.asarray(r.randn(4, 1, 2, 2), jnp.float32)}
        specs = jax.tree.map(lambda g: P("data"), grads)
        for strat in (TrimmedMean(trim=1), CoordinateMedian()):
            f = shard_map(lambda g: strat.sync(g, (), "data")[0],
                          mesh=mesh, in_specs=(specs,), out_specs=specs)
            fr = shard_map(lambda g: strat.sync_per_leaf(g, (), "data")[0],
                           mesh=mesh, in_specs=(specs,), out_specs=specs)
            a, b = f(grads), fr(grads)
            for k in grads:
                assert a[k].dtype == grads[k].dtype
                assert a[k].shape == grads[k].shape
                np.testing.assert_allclose(
                    np.asarray(a[k], np.float32),
                    np.asarray(b[k], np.float32), rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    import os
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_coordinate_median_ignores_minority():
    stacked = jnp.asarray([[1.0, 2.0], [1.2, 2.2], [0.8, 1.8],
                           [1e6, -1e6]])
    med = np.asarray(coordinate_median(stacked))
    np.testing.assert_allclose(med, [1.1, 2.1], atol=0.2)


def test_get_strategy_wires_robust_and_byzantine():
    tm = get_strategy("trimmed_mean", trim=1, microbatches=4)
    assert tm.name == "trimmed_mean" and tm.microbatches == 4
    cm = get_strategy("coordinate_median")
    byz = get_strategy("byzantine", inner=tm, workers=(0,), scale=-8.0)
    assert byz.microbatches == 4            # rides SPIRT accumulation
    like = [jnp.ones((8, 8))]
    assert byz.comm_bytes(like, 4) == tm.comm_bytes(like, 4)
    assert cm.comm_bytes(like, 4) == 4 * 8 * 8 * 4
    with pytest.raises(ValueError):
        get_strategy("byzantine")           # inner is required
    with pytest.raises(ValueError):         # conflicting accumulation
        get_strategy("byzantine", inner=get_strategy("allreduce"),
                     microbatches=4)


def test_byzantine_training_converges_only_with_robust_agg():
    """SPIRT accumulation + trimmed mean trains through a -8x byzantine
    worker; plain allreduce under the same attack diverges.  Shares the
    harness with benchmarks/fault_tolerance.py (shorter runs here)."""
    from repro.launch.byzantine_train import run_in_subprocess
    robust = run_in_subprocess("trimmed_mean", steps=40, data_size=2048,
                               timeout=560)
    plain = run_in_subprocess("allreduce", steps=15, data_size=2048,
                              timeout=560)
    # robust: bounded + trending down (averaged tail below head)
    assert robust["max_loss"] < 4.0, robust
    assert robust["tail_loss"] < robust["head_loss"], robust
    # plain averaging under the same attack blows up
    assert plain["final_loss"] > 10.0 * robust["final_loss"], (plain,
                                                               robust)
