"""Adversarial robustness lab: attack-model registry contract, numpy/
JAX aggregator parity, breakdown-point properties (every robust
aggregator stays near the honest mean under <= f adversarial rows for
EVERY registered attack, while plain averaging violates the same
bound), error paths, and seeded-determinism regressions for the
byzantine-fraction sweep and the real-training harness."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_strategy
from repro.serverless import adversarial as adv
from repro.serverless.recovery import (GeometricMedian, Krum, TrimmedMean,
                                       coordinate_median,
                                       geometric_median, krum,
                                       trimmed_mean_sort)
from repro.serverless.sweep import (AdversarialGrid, adversarial_curve,
                                    adversarial_sweep)

ROBUST = ("trimmed_mean", "coordinate_median", "krum",
          "geometric_median")
# magnitudes the property tests drive each attack at: large enough that
# an unfiltered mean is dragged far outside the honest cluster
# (sign_flip and zero carry their own fixed displacement)
ATTACK_TEST_SCALE = {"scale": -1e4, "gaussian_noise": 1e4,
                     "little_is_enough": 1e4, "sign_flip": 1.0,
                     "zero": 1.0}


# ---------------------------------------------------------------------------
# Attack-model registry contract (mirrors the ArchSpec registry's)
# ---------------------------------------------------------------------------
def test_registry_lists_the_paper_attacks():
    names = adv.list_attacks()
    for expected in ("sign_flip", "scale", "gaussian_noise",
                     "little_is_enough", "zero"):
        assert expected in names, names
    lie = adv.get_attack("little_is_enough")
    assert lie.colluding and lie.default_scale == 1.5


def test_registry_unknown_name_is_actionable():
    with pytest.raises(ValueError, match="little_is_enough"):
        adv.get_attack("nope")
    with pytest.raises(ValueError, match="registered"):
        adv.get_attack("")


def test_registry_register_round_trip_and_duplicates():
    spec = adv.AttackSpec(name="test_attack",
                          apply_rows=lambda s, b, r, k: s,
                          jax_apply=lambda g, b, a, k, s: g)
    try:
        assert adv.register_attack(spec) is spec
        assert adv.get_attack("test_attack") is spec
        with pytest.raises(ValueError, match="already registered"):
            adv.register_attack(spec)
        adv.register_attack(spec, overwrite=True)     # explicit is fine
    finally:
        adv.unregister_attack("test_attack")
    assert "test_attack" not in adv.list_attacks()


def test_attack_specs_are_frozen():
    with pytest.raises(dataclasses.FrozenInstanceError):
        adv.get_attack("scale").default_scale = 0.0


def test_attacks_leave_honest_rows_bit_identical():
    rng = np.random.default_rng(0)
    stacked = rng.standard_normal((4, 9, 6))
    mask = np.arange(9) < np.array([0, 2, 3, 4])[:, None]
    for name in adv.list_attacks():
        out = adv.get_attack(name).rows(stacked, mask,
                                        np.random.default_rng(1))
        assert out.shape == stacked.shape
        honest = ~mask[..., None] & np.ones_like(stacked, bool)
        assert (out[honest] == stacked[honest]).all(), name
        assert np.array_equal(out[0], stacked[0]), name  # no byz row


# ---------------------------------------------------------------------------
# numpy twins agree with the JAX statistics (the sweep measures what
# real training applies)
# ---------------------------------------------------------------------------
def test_np_trimmed_mean_matches_jax_reference():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((9, 17))
    for f in (1, 2, 4):
        np.testing.assert_allclose(
            adv.np_trimmed_mean(x, f),
            np.asarray(trimmed_mean_sort(jnp.asarray(x), f)),
            rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        adv.np_coordinate_median(x),
        np.asarray(coordinate_median(jnp.asarray(x))), rtol=1e-6)


def test_np_krum_matches_jax():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((11, 4, 3)).astype(np.float32)
    for f, m in ((0, 1), (1, 1), (2, 3), (4, 2)):
        np.testing.assert_allclose(
            adv.np_krum(x.reshape(11, -1), f, m=m).reshape(4, 3),
            np.asarray(krum(jnp.asarray(x), f=f, m=m)),
            rtol=1e-5, atol=1e-5)


def test_np_geometric_median_matches_jax():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((9, 5)).astype(np.float32)
    x[0] *= 200.0                       # one far outlier
    np.testing.assert_allclose(
        adv.np_geometric_median(x, tol=1e-10, max_iter=500),
        np.asarray(geometric_median(jnp.asarray(x), tol=1e-7,
                                    max_iter=500)),
        rtol=1e-4, atol=1e-4)
    # symmetric configuration -> the exact center
    pts = np.array([[1., 0], [-1., 0], [0, 1.], [0, -1.]])
    np.testing.assert_allclose(adv.np_geometric_median(pts),
                               [0.0, 0.0], atol=1e-6)


def test_batched_aggregators_match_per_row_loop():
    """The fraction-axis vectorization (per-row f budgets) must agree
    with scalar calls row by row."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((4, 11, 6))
    f = np.array([0, 1, 2, 4])
    for name in ("trimmed_mean", "krum"):
        fn = adv.SIM_AGGREGATORS[name]
        batched = fn(x, f)
        for i in range(len(f)):
            np.testing.assert_allclose(batched[i], fn(x[i], int(f[i])),
                                       rtol=1e-9, err_msg=name)


# ---------------------------------------------------------------------------
# Breakdown-point property: <= f adversaries never drag a robust
# aggregate far from the honest mean; plain averaging always is
# ---------------------------------------------------------------------------
def _breakdown_case(agg_name, attack_name, W, n_byz, D, seed):
    """Returns (robust_err, plain_err, bound) for one drawn fleet."""
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(D)
    base *= 200.0 / max(np.linalg.norm(base), 1e-12)
    rows = base + 0.02 * rng.standard_normal((W, D))
    mask = np.arange(W) < n_byz
    spec = adv.get_attack(attack_name)
    stacked = spec.apply_rows(rows, mask, np.random.default_rng(seed + 1),
                              ATTACK_TEST_SCALE[attack_name])
    mu = rows[n_byz:].mean(axis=0)      # the honest workers' mean
    spread = np.linalg.norm(rows[n_byz:] - mu, axis=-1).max()
    f = max(n_byz, 1)
    bound = 6.0 * (spread + 1e-3) * (np.sqrt(W) + W / (W - 2 * f))
    est = adv.SIM_AGGREGATORS[agg_name](stacked, f)
    return (float(np.linalg.norm(est - mu)),
            float(np.linalg.norm(stacked.mean(axis=0) - mu)), bound)


def _check_breakdown(agg_name, attack_name, W, n_byz, D, seed):
    assert W >= 2 * max(n_byz, 1) + 3   # krum's strictest feasibility
    err, plain_err, bound = _breakdown_case(agg_name, attack_name, W,
                                            n_byz, D, seed)
    assert err <= bound, (
        f"{agg_name} left the honest cluster under {attack_name}: "
        f"err={err:.3g} > bound={bound:.3g} "
        f"(W={W}, n_byz={n_byz}, D={D}, seed={seed})")
    if n_byz > 0:
        assert plain_err > bound, (
            f"plain mean survived {attack_name} (W={W}, n_byz={n_byz}, "
            f"seed={seed}): err={plain_err:.3g} <= bound={bound:.3g}")


BREAKDOWN_CASES = [(7, 0), (7, 2), (9, 3), (13, 5)]


@pytest.mark.parametrize("attack",
                         ["sign_flip", "scale", "gaussian_noise",
                          "little_is_enough", "zero"])
@pytest.mark.parametrize("agg", ROBUST)
def test_breakdown_point_fixed_cases(agg, attack):
    for W, n_byz in BREAKDOWN_CASES:
        for seed in (0, 1, 2):
            _check_breakdown(agg, attack, W, n_byz, D=12, seed=seed)


def test_breakdown_point_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(W=st.integers(5, 13), frac=st.floats(0.0, 1.0),
               D=st.integers(2, 16), seed=st.integers(0, 2 ** 31))
    def run(W, frac, D, seed):
        n_byz = int(round(frac * ((W - 3) // 2)))
        for agg in ROBUST:
            for attack in adv.list_attacks():
                _check_breakdown(agg, attack, W, n_byz, D, seed)

    run()


# ---------------------------------------------------------------------------
# Error paths (mirror get_arch's actionable-error style)
# ---------------------------------------------------------------------------
def test_trimmed_mean_width_validation():
    with pytest.raises(ValueError, match="W > 2"):
        adv.np_trimmed_mean(np.ones((4, 3)), 2)
    with pytest.raises(ValueError):     # 2*trim >= n_workers, jax side
        TrimmedMean(trim=2)._reduce(jnp.ones((4, 3)))


def test_krum_validation():
    # f too large names the largest feasible budget
    with pytest.raises(ValueError, match="max feasible f is 1"):
        krum(jnp.ones((5, 2)), f=2)
    with pytest.raises(ValueError, match="max feasible f"):
        adv.np_krum(np.ones((5, 2)), 2)
    with pytest.raises(ValueError, match="f >= 0"):
        adv.np_krum(np.ones((5, 2)), -1)
    with pytest.raises(ValueError, match="1 <= m <= W"):
        krum(jnp.ones((5, 2)), f=0, m=9)
    with pytest.raises(ValueError):
        Krum(f=-1)
    with pytest.raises(ValueError):
        Krum(m=0)
    with pytest.raises(ValueError):     # strategy reduce, fleet too small
        Krum(f=1)._reduce(jnp.ones((4, 3)))


def test_geometric_median_validation():
    for kw in (dict(tol=0.0), dict(max_iter=0), dict(tol=-1.0)):
        with pytest.raises(ValueError):
            GeometricMedian(**kw)
        with pytest.raises(ValueError):
            geometric_median(jnp.ones((4, 2)), **kw)
        with pytest.raises(ValueError):
            adv.np_geometric_median(np.ones((4, 2)), **kw)


def test_get_strategy_byzantine_unknown_attack_lists_registry():
    tm = get_strategy("trimmed_mean", trim=1)
    with pytest.raises(ValueError) as ei:
        get_strategy("byzantine", inner=tm, attack="nope")
    for name in adv.list_attacks():
        assert name in str(ei.value)


def test_get_strategy_wires_new_aggregators():
    k = get_strategy("krum", f=1, m=2, microbatches=4)
    assert (k.name, k.f, k.m, k.microbatches) == ("krum", 1, 2, 4)
    g = get_strategy("geometric_median", tol=1e-5)
    assert g.name == "geometric_median" and g.tol == 1e-5
    byz = get_strategy("byzantine", inner=k, attack="little_is_enough")
    assert byz.microbatches == 4        # rides the inner accumulation
    assert byz.scale == 1.5             # the attack's own default


def test_byzantine_gradients_post_init_validation():
    tm = get_strategy("trimmed_mean", trim=1)
    # valid: fraction exactly at the (W-1)/2W cap
    ok = get_strategy("byzantine", inner=tm, workers=(0, 2), n_workers=5)
    assert ok.workers == (0, 2) and ok.scale == -10.0
    cases = [
        (dict(workers=()), "non-empty"),
        (dict(workers=(0, 0)), "distinct"),
        (dict(workers=(-1,)), "distinct non-negative"),
        (dict(workers=(0,), n_workers=0), "n_workers"),
        (dict(workers=(4,), n_workers=4), "out of range"),
        (dict(workers=(0, 1), n_workers=4), "majority"),
        (dict(workers=(0, 1, 2), n_workers=5), "majority"),
        (dict(attack="bogus"), "registered"),
        (dict(scale=float("inf")), "finite"),
        (dict(scale=float("nan")), "finite"),
    ]
    for kw, match in cases:
        with pytest.raises(ValueError, match=match):
            get_strategy("byzantine", inner=tm, **kw)


def test_sim_helpers_validation():
    with pytest.raises(ValueError, match="registered"):
        adv.sim_aggregator_max_f("nope", 8)
    with pytest.raises(ValueError, match="n_workers"):
        adv.byzantine_fractions(2)
    with pytest.raises(ValueError, match="n_workers"):
        AdversarialGrid(n_workers=2)
    with pytest.raises(ValueError, match="steps"):
        AdversarialGrid(steps=0)
    with pytest.raises(ValueError, match="lr"):
        AdversarialGrid(lr=0.0)
    with pytest.raises(ValueError, match="aggregatable range"):
        adversarial_sweep(AdversarialGrid(fractions=(0.0, 0.6)))
    with pytest.raises(ValueError, match="registered"):
        AdversarialGrid(aggregators=("trimmed-mean",))  # typo'd name
    with pytest.raises(ValueError, match="unknown attack"):
        adversarial_sweep(AdversarialGrid(
            attack_scales=(("bogus", 2.0),)))
    with pytest.raises(ValueError, match="no cells"):
        adversarial_curve([], "mean", "scale")


def test_arch_default_aggregator_validated_and_set():
    from repro.serverless import ArchSpec, get_arch
    for name in ("spirt", "hier_spirt", "spirt_s3"):
        assert get_arch(name).default_aggregator == "trimmed_mean"
    assert get_arch("allreduce").default_aggregator == "mean"
    assert get_arch("gpu").default_aggregator == "mean"
    with pytest.raises(ValueError, match="default_aggregator"):
        ArchSpec(name="x", round_terms=lambda **k: {},
                 default_aggregator="bogus")


# ---------------------------------------------------------------------------
# The fraction sweep: determinism + the degradation/floor contract
# ---------------------------------------------------------------------------
def _small_grid(**kw):
    base = dict(n_workers=8, steps=50)
    base.update(kw)
    return AdversarialGrid(**base)


def test_adversarial_sweep_bit_reproducible():
    grid = _small_grid()
    a = adversarial_sweep(grid, seed=11)
    b = adversarial_sweep(grid, seed=11)
    assert a == b                       # frozen cells, exact floats
    c = adversarial_sweep(grid, seed=12)
    assert a != c                       # the seed actually matters


def test_adversarial_sweep_reproducible_past_float_overflow():
    """A grid long enough to drive plain averaging clean through inf
    must still satisfy the same-seed equality contract (NaN floats
    would make identical sweeps compare unequal) and keep min_dist
    finite."""
    grid = _small_grid(steps=3000, attacks=("scale",),
                       aggregators=("mean",))
    a = adversarial_sweep(grid, seed=0)
    assert a == adversarial_sweep(grid, seed=0)
    assert any(c.final_dist == float("inf") and c.diverged for c in a)
    assert all(np.isfinite(c.min_dist) for c in a)


def test_adversarial_sweep_cells_invariant_to_grid_shape():
    """A cell is a pure function of its OWN (aggregator, attack,
    fraction) coordinates and the seed: shrinking the grid elsewhere —
    fewer attacks, fewer aggregators — must reproduce the surviving
    cells bit-identically (the attack noise stream is keyed by attack
    name, not grid position)."""
    full = adversarial_sweep(_small_grid(), seed=5)
    sub = adversarial_sweep(
        _small_grid(attacks=("gaussian_noise",),
                    aggregators=("mean", "krum")), seed=5)
    want = [c for c in full if c.attack == "gaussian_noise"
            and c.aggregator in ("mean", "krum")]
    assert sub == want


def test_adversarial_sweep_fraction_zero_is_attack_free():
    """With nobody byzantine every attack column is identical — the
    corruption machinery must be a no-op at fraction 0."""
    cells = adversarial_sweep(_small_grid(), seed=3)
    for agg in ("mean",) + ROBUST:
        per_attack = {c.attack: c.final_dist for c in cells
                      if c.aggregator == agg and c.n_byz == 0}
        assert len(set(per_attack.values())) == 1, (agg, per_attack)


def test_mean_degrades_monotonically_robust_holds_floor():
    """Tier-1 version of the benchmark's acceptance assertion."""
    grid = _small_grid()
    cells = adversarial_sweep(grid, seed=0)
    floor = 2 * grid.converge_tol
    for attack in adv.list_attacks():
        _, cs = adversarial_curve(cells, "mean", attack,
                                  "converged_step")
        cs = np.where(cs < 0, grid.steps + 1, cs)
        assert all(b >= a for a, b in zip(cs, cs[1:])), (attack, cs)
        for agg in ROBUST:
            cap = adv.sim_aggregator_max_f(agg, grid.n_workers)
            held = [c for c in cells
                    if c.aggregator == agg and c.attack == attack
                    and c.n_byz <= cap]
            assert held and all(c.final_dist <= floor
                                and not c.diverged for c in held), (
                agg, attack, [(c.fraction, c.final_dist) for c in held])
    # the strong attack's contrast: mean diverges, every robust holds
    _, mean_d = adversarial_curve(cells, "mean", "scale")
    assert mean_d[-1] > 10 * grid.init_dist
    for agg in ROBUST:
        _, rob_d = adversarial_curve(cells, agg, "scale")
        assert mean_d[-1] > 100 * rob_d[-1], (agg, rob_d)


def test_oracle_budget_is_capped_at_breakdown():
    cells = adversarial_sweep(_small_grid(), seed=0)
    for c in cells:
        cap = adv.sim_aggregator_max_f(c.aggregator, 8)
        assert c.f_used == min(c.n_byz, cap), c


def test_jax_gaussian_noise_is_fresh_per_step():
    """The JAX gaussian attack must redraw noise every sync step (the
    numpy twin does) — a step-independent key would freeze one draw
    into a constant-bias attack.  ByzantineGradients threads the step
    counter through its strategy state."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    spec = adv.get_attack("gaussian_noise")
    mesh = jax.make_mesh((1,), ("data",))
    g = {"a": jnp.ones((1, 4), jnp.float32)}
    specs = jax.tree.map(lambda _: P("data"), g)

    def corrupt(step):
        f = shard_map(
            lambda x: spec.jax_apply(x, jnp.asarray(True), "data", 5.0,
                                     7, jnp.asarray(step))["a"],
            mesh=mesh, in_specs=(specs,), out_specs=P("data"))
        return np.asarray(f(g))

    s0, s0b, s1 = corrupt(0), corrupt(0), corrupt(1)
    np.testing.assert_array_equal(s0, s0b)      # same step: replayable
    assert not np.array_equal(s0, s1)           # new step: fresh noise
    assert not np.array_equal(s0, np.ones((1, 4)))  # actually corrupts
    # the wrapper's state carries (step counter, inner state)
    byz = get_strategy("byzantine", inner=get_strategy("allreduce"),
                       attack="gaussian_noise")
    step0, inner0 = byz.init_state(g)
    assert int(step0) == 0 and inner0 == ()


# ---------------------------------------------------------------------------
# Real-training regressions (subprocess: own XLA device count)
# ---------------------------------------------------------------------------
def _run_subprocess_code(code, timeout=560):
    import os
    import subprocess
    import sys
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout, out.stdout[-2000:]


def test_krum_and_geometric_median_sync_match_numpy_twins():
    """Under a real 4-device shard_map the flat-buffer sync must apply
    the SAME statistic the simulated sweep uses: reconstruct each
    worker's flattened gradient on the host, reduce with the numpy
    twin, and demand agreement.  (Unlike the coordinate-wise trimmed
    mean / median, Krum and the geometric median are JOINT rules over
    the whole gradient — per-leaf application is a different statistic,
    so sync_per_leaf is deliberately not the reference here.)"""
    import textwrap
    _run_subprocess_code(textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.serverless.adversarial import (np_geometric_median,
                                                  np_krum)
        from repro.serverless.recovery import GeometricMedian, Krum
        mesh = jax.make_mesh((4,), ("data",))
        r = np.random.RandomState(0)
        grads = {"a": jnp.asarray(r.randn(4, 8, 3), jnp.float32),
                 "b": jnp.asarray(r.randn(4, 5), jnp.float32)}
        specs = jax.tree.map(lambda g: P("data"), grads)
        # each worker's whole flattened gradient, [W, N] on the host
        stack = np.stack([np.concatenate(
            [np.asarray(grads[k][w]).ravel() for k in grads])
            for w in range(4)])
        for strat, ref in (
                (Krum(f=0), lambda s: np_krum(s, 0)),
                (Krum(f=0, m=2), lambda s: np_krum(s, 0, m=2)),
                (GeometricMedian(tol=1e-7, max_iter=300),
                 lambda s: np_geometric_median(s, tol=1e-10,
                                               max_iter=600))):
            f = shard_map(lambda g: strat.sync(g, (), "data")[0],
                          mesh=mesh, in_specs=(specs,), out_specs=specs)
            out = f(grads)
            want = ref(stack)
            got = np.concatenate([np.asarray(out[k][0]).ravel()
                                  for k in out])
            for k in grads:
                assert out[k].dtype == grads[k].dtype
                assert out[k].shape == grads[k].shape
                # every worker receives the same aggregate
                np.testing.assert_array_equal(np.asarray(out[k][0]),
                                              np.asarray(out[k][1]))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        print("OK")
    """))


def test_byzantine_train_seeded_determinism():
    """Same seed -> bit-identical loss trace across two in-process runs
    of the refactored harness (and a different seed diverges)."""
    import textwrap
    _run_subprocess_code(textwrap.dedent("""
        from repro.launch.byzantine_train import run
        kw = dict(attack="sign_flip", steps=6, batch=32, data_size=256,
                  eval_size=64, seed=3)
        a = run("trimmed_mean", **kw)
        b = run("trimmed_mean", **kw)
        assert a["losses"] == b["losses"], (a["losses"], b["losses"])
        assert a["acc"] == b["acc"]
        c = run("trimmed_mean", **dict(kw, seed=4))
        assert c["losses"] != a["losses"]
        print("OK")
    """))
