"""Compressed / asynchronous communication regimes — the PR-10 family.

The registry now carries barrier-free and compressed variants of the
paper architectures (``local_sgd``, ``async_spirt``, ``async_spirt_q8``,
``scatterreduce_q8``, ``spirt_sf``).  This benchmark prices them against
their dense synchronous parents and answers the headline question with a
chart: *does async SPIRT dominate the sync Pareto front once measured
cold-start tails (the PR-3 Lambda trace) are replayed?*  Three sections,
recorded in a content-hashed ``BENCH_comm.json``:

  1. *Wire accounting* — per-arch bytes-per-epoch from the analytic
     simulator, pinned against the real JAX strategies' ``comm_bytes``
     billing (the int8 scatter-reduce payload and the significance
     fraction must price identically in both worlds).
  2. *Compression x architecture x fault rate* — every compressed arch
     vs its dense parent swept under increasing crash rates
     (``sweep_events``), plus an analytic channel sweep (Redis vs S3)
     showing where compression buys the most.
  3. *Pareto under measured tails* — the joint cost-vs-makespan front
     over (arch x fleet size) with the measured Lambda trace replayed;
     reports front membership, the fraction of synchronous configs
     dominated by a barrier-free one, and draws ``comm_pareto.png``.

The payload hash covers everything except wall-clock timings; two runs
with equal (grid, seed) must produce byte-identical deterministic
sections — section 3 asserts that before writing.

Rows: comm/<section>/<name>,value,notes
Usage:
    PYTHONPATH=src python -m benchmarks.comm_regimes [--quick]
        [--json BENCH_comm.json] [--chart comm_pareto.png]
        [--processes N]
    PYTHONPATH=src python -m benchmarks.run --only comm
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import numpy as np

from repro.serverless import lambda_default
from repro.serverless.archs import COMPRESSION_SCHEMES, get_arch
from repro.serverless.simulator import (REDIS, S3, paper_compute_anchor,
                                        simulate_epoch)
from repro.serverless.sweep import (EventSweepPoint, FaultRates,
                                    SweepGrid, pareto_front,
                                    sweep_analytic, sweep_events)

N_PARAMS = int(4.2e6)                    # MobileNet
SEED = 10
SIG_FRACTION = 0.3                       # spirt_sf effective density

# compressed arch -> its dense synchronous parent
PAIRS = (("scatterreduce_q8", "scatterreduce"),
         ("spirt_sf", "spirt"),
         ("async_spirt_q8", "async_spirt"))
SYNC_ARCHS = ("spirt", "scatterreduce", "spirt_sf", "scatterreduce_q8",
              "local_sgd")
ASYNC_ARCHS = ("async_spirt", "async_spirt_q8")
ALL_ARCHS = SYNC_ARCHS + ASYNC_ARCHS


# ---------------------------------------------------------------------------
# 1. wire-byte accounting: analytic schemes vs real strategy billing
# ---------------------------------------------------------------------------
def bench_wire(csv_rows) -> dict:
    out = {}
    epochs = {a: simulate_epoch(a, n_params=N_PARAMS,
                                compute_s_per_batch=0.9,
                                significant_fraction=SIG_FRACTION)
              for a in ALL_ARCHS}
    for arch, rep in epochs.items():
        csv_rows.append((f"comm/wire/{arch}/bytes_per_worker",
                         rep.comm_bytes_per_worker,
                         f"sync_s={rep.stages.sync:.3f}"))
        out[arch] = dict(comm_bytes_per_worker=rep.comm_bytes_per_worker,
                         sync_s=rep.stages.sync,
                         total_cost=rep.total_cost)

    # the analytic compression scheme and the shipped JAX strategy must
    # bill the same bytes-per-gradient-byte or the sweeps lie
    from repro.core.compression import QuantizedScatterReduce
    from repro.core.strategies import get_strategy
    W = 4
    grads = [np.zeros(N_PARAMS, np.float32)]
    dense_ring = get_strategy("scatterreduce").comm_bytes(grads, W)
    qsr = QuantizedScatterReduce()
    parity = {}
    ratio = qsr.comm_bytes(grads, W) / dense_ring
    scheme = COMPRESSION_SCHEMES["int8"](SIG_FRACTION)
    parity["int8"] = dict(strategy_ratio=ratio, scheme_ratio=scheme)
    assert abs(ratio / scheme - 1) < 1e-3, (ratio, scheme)
    csv_rows.append(("comm/wire/int8_billing_parity", ratio,
                     f"scheme={scheme:.6f} (QuantizedScatterReduce)"))
    mll = get_strategy("mlless").comm_bytes(
        grads, W, significant_fraction=SIG_FRACTION)
    spirt_ring = get_strategy("spirt").comm_bytes(grads, W)
    # mlless bills per step, spirt amortizes over K microbatches: compare
    # both against the raw ring volume
    ratio_sf = mll / (spirt_ring * get_strategy("spirt").microbatches)
    scheme_sf = COMPRESSION_SCHEMES["significance"](SIG_FRACTION)
    parity["significance"] = dict(strategy_ratio=ratio_sf,
                                  scheme_ratio=scheme_sf)
    assert abs(ratio_sf / scheme_sf - 1) < 1e-6, (ratio_sf, scheme_sf)
    csv_rows.append(("comm/wire/significance_billing_parity", ratio_sf,
                     f"scheme={scheme_sf:.6f} (MLLess)"))
    return dict(per_arch=out, billing_parity=parity)


# ---------------------------------------------------------------------------
# 2. compression x architecture x fault rate
# ---------------------------------------------------------------------------
def bench_regimes(csv_rows, quick: bool, processes) -> dict:
    # analytic arm: where does compression buy the most?  One channel
    # per sweep — S3's thin pipe is where wire bytes dominate.
    channels = {}
    for ch in (REDIS, S3):
        g = SweepGrid(n_params=N_PARAMS, compute_s_per_batch=0.9,
                      archs=ALL_ARCHS, n_workers=(4,), channels=(ch,),
                      significant_fraction=(SIG_FRACTION,))
        v = sweep_analytic(g)
        by_arch = {a: float(v.per_worker_s[list(v.arch).index(a)])
                   for a in ALL_ARCHS}
        channels[ch.name.lower()] = by_arch
        for comp, dense in PAIRS:
            speedup = by_arch[dense] / by_arch[comp]
            csv_rows.append((f"comm/regimes/{comp}/{ch.name.lower()}"
                             "_speedup", speedup,
                             f"epoch_s dense={by_arch[dense]:.2f} "
                             f"comp={by_arch[comp]:.2f}"))

    # event arm: crash-rate sweep, compressed vs dense parent
    rates = (0.0, 0.5) if quick else (0.0, 0.2, 0.5)
    reps = 3 if quick else 8
    fault_curves = {}
    points = [EventSweepPoint(arch=a, n_params=N_PARAMS,
                              compute_s_per_batch=paper_compute_anchor(a),
                              label=a)
              for a in ALL_ARCHS]
    for rate in rates:
        stats = sweep_events(points, rates=FaultRates(crash_rate=rate),
                             n_replicates=reps, seed=SEED,
                             processes=processes)
        for s in stats:
            fault_curves.setdefault(s.point.arch, []).append(dict(
                crash_rate=rate, makespan_mean_s=s.makespan_mean_s,
                cost_mean=s.cost_mean,
                cost_overhead_mean=s.cost_overhead_mean))
    for comp, dense in PAIRS:
        worst = fault_curves[comp][-1]
        worst_d = fault_curves[dense][-1]
        csv_rows.append((f"comm/regimes/{comp}/crash{rates[-1]}"
                         "_cost_ratio",
                         worst["cost_mean"] / worst_d["cost_mean"],
                         f"dense={dense} reps={reps}"))
    return dict(analytic_by_channel=channels, fault_curves=fault_curves,
                crash_rates=list(rates), replicates=reps)


# ---------------------------------------------------------------------------
# 3. Pareto under measured cold-start tails
# ---------------------------------------------------------------------------
def _dominates(a, b) -> bool:
    """a dominates b on (cost, makespan): no worse on both, better on one."""
    return (a[0] <= b[0] and a[1] <= b[1]
            and (a[0] < b[0] or a[1] < b[1]))


def bench_pareto(csv_rows, quick: bool, processes) -> dict:
    trace = lambda_default()
    fleets = (4, 16) if quick else (4, 8, 16)
    reps = 3 if quick else 8
    from repro.serverless.simulator import ServerlessSetup
    points = [EventSweepPoint(
                  arch=a, n_params=N_PARAMS,
                  compute_s_per_batch=paper_compute_anchor(a),
                  setup=ServerlessSetup(n_workers=W), label=f"{a}/W{W}")
              for a in ALL_ARCHS for W in fleets]
    kw = dict(rates=FaultRates(crash_rate=0.1), trace=trace,
              n_replicates=reps, seed=SEED, processes=processes)
    t0 = time.perf_counter()
    stats = sweep_events(points, **kw)
    elapsed = time.perf_counter() - t0

    # bit-reproducibility receipt: the content hash is only meaningful
    # if (grid, seed) pins every float in the payload
    again = sweep_events(points[:2], **kw)
    assert [(s.makespan_mean_s, s.cost_mean) for s in again] == \
        [(s.makespan_mean_s, s.cost_mean) for s in stats[:2]], \
        "equal-seed trace sweeps must agree bit-exactly"
    csv_rows.append(("comm/pareto/bit_reproducible", 1,
                     "two equal-seed trace sweeps agree exactly"))

    costs = [s.cost_mean for s in stats]
    makespans = [s.makespan_mean_s for s in stats]
    front = set(pareto_front(costs, makespans).tolist())
    rows = [dict(label=s.point.label, arch=s.point.arch,
                 n_workers=s.point.setup.n_workers,
                 cost_mean=s.cost_mean, makespan_mean_s=s.makespan_mean_s,
                 makespan_p95_s=s.makespan_p95_s,
                 cost_overhead_p95=s.cost_overhead_p95,
                 on_front=i in front)
            for i, s in enumerate(stats)]

    front_archs = sorted({r["arch"] for r in rows if r["on_front"]})
    async_pts = [(r["cost_mean"], r["makespan_mean_s"]) for r in rows
                 if get_arch(r["arch"]).barrier_sync is False]
    sync_rows = [r for r in rows if get_arch(r["arch"]).barrier_sync]
    dominated = sum(
        any(_dominates(a, (r["cost_mean"], r["makespan_mean_s"]))
            for a in async_pts)
        for r in sync_rows)
    frac = dominated / len(sync_rows)
    async_on_front = any(not get_arch(r["arch"]).barrier_sync
                         for r in rows if r["on_front"])
    sync_front_survives = any(get_arch(r["arch"]).barrier_sync
                              for r in rows if r["on_front"])
    verdict = ("async dominates the sync front" if not sync_front_survives
               else "async joins but does not clear the sync front"
               if async_on_front else "sync front stands")
    csv_rows.append(("comm/pareto/front_size", len(front),
                     "archs=" + ";".join(front_archs)))
    csv_rows.append(("comm/pareto/sync_dominated_fraction", frac,
                     f"{dominated}/{len(sync_rows)} sync configs beaten "
                     "by a barrier-free one"))
    csv_rows.append(("comm/pareto/async_on_front", int(async_on_front),
                     verdict))
    return dict(trace=trace.name, replicates=reps, fleets=list(fleets),
                points=rows, front_archs=front_archs,
                sync_dominated_fraction=frac,
                async_on_front=async_on_front, verdict=verdict,
                elapsed_s=elapsed)


# ---------------------------------------------------------------------------
# chart (matplotlib-gated, like the serving/knee benches)
# ---------------------------------------------------------------------------
_SERIES_COLORS = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100", "#e87ba4",
                  "#008300", "#4a3aa7", "#e34948")
_SURFACE, _INK, _INK2 = "#fcfcfb", "#0b0b0b", "#52514e"


def pareto_chart(pareto: dict, path: str):
    """Cost vs makespan under the measured trace, front highlighted;
    returns the path or None when matplotlib is unavailable."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None
    fig, ax = plt.subplots(figsize=(7.5, 4.5), dpi=144)
    fig.patch.set_facecolor(_SURFACE)
    ax.set_facecolor(_SURFACE)
    rows = pareto["points"]
    for i, arch in enumerate(sorted({r["arch"] for r in rows})):
        pts = [r for r in rows if r["arch"] == arch]
        c = _SERIES_COLORS[i % len(_SERIES_COLORS)]
        marker = "s" if get_arch(arch).barrier_sync else "o"
        ax.scatter([r["cost_mean"] for r in pts],
                   [r["makespan_mean_s"] for r in pts],
                   s=26, color=c, marker=marker, label=arch, zorder=3,
                   edgecolors=_SURFACE, linewidths=0.8)
    fr = sorted((r for r in rows if r["on_front"]),
                key=lambda r: r["cost_mean"])
    ax.plot([r["cost_mean"] for r in fr],
            [r["makespan_mean_s"] for r in fr],
            "-", color=_INK, linewidth=1.2, zorder=2,
            label="joint front")
    ax.set_xlabel("epoch cost (USD, mean over fault replicates)",
                  color=_INK2)
    ax.set_ylabel("makespan (s)", color=_INK2)
    ax.set_title("Async/compressed regimes under measured Lambda "
                 f"tails — {pareto['verdict']}", color=_INK, loc="left",
                 fontsize=10)
    ax.grid(True, color="#e7e6e3", linewidth=0.8, zorder=0)
    for s in ("top", "right"):
        ax.spines[s].set_visible(False)
    for s in ("left", "bottom"):
        ax.spines[s].set_color("#d7d6d2")
    ax.tick_params(colors=_INK2, which="both")
    ax.legend(frameon=False, fontsize=8, ncol=2, labelcolor=_INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=_SURFACE)
    plt.close(fig)
    return path


def _content_hash(payload: dict) -> str:
    """Hash of the deterministic sections (timings excluded) — the
    bit-reproducibility receipt the tests re-derive."""
    det = {k: v for k, v in payload.items() if k != "timings"}
    blob = json.dumps(det, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def run(csv_rows, *, quick: bool = False, processes=None,
        json_path: str = "BENCH_comm.json",
        chart: str = "comm_pareto.png"):
    payload = {"benchmark": "comm_regimes", "quick": quick, "seed": SEED,
               "wire": bench_wire(csv_rows)}
    payload["regimes"] = bench_regimes(csv_rows, quick, processes)
    pareto = bench_pareto(csv_rows, quick, processes)
    payload["timings"] = {"pareto_elapsed_s": pareto.pop("elapsed_s")}
    payload["pareto"] = pareto
    payload["content_hash"] = _content_hash(payload)
    csv_rows.append(("comm/_content_hash", payload["content_hash"],
                     "sha256[:16] of the deterministic payload"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        csv_rows.append(("comm/_json", 1, json_path))
    if chart:
        out = pareto_chart(pareto, chart)
        csv_rows.append(("comm/_chart", int(out is not None),
                         out or "matplotlib unavailable"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grid / fewer replicates (CI)")
    ap.add_argument("--json", default="BENCH_comm.json")
    ap.add_argument("--chart", default="comm_pareto.png")
    ap.add_argument("--processes", type=int, default=None,
                    help="0/1 inline; default cpu count (<=8)")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, processes=args.processes,
        json_path=args.json, chart=args.chart)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")


if __name__ == "__main__":
    main()
