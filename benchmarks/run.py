"""Benchmark driver — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--only table2|fig23|table3|
        roofline|strategy_matrix|fault_tolerance|sweep|knee|trace|
        adversarial|serving|recovery|kernels|comm]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (adversarial_curves, comm_regimes,
                            fault_tolerance, fig23_comm, kernel_bench,
                            pareto_sweep, recovery_replay,
                            roofline_report, serving_sweep,
                            strategy_matrix, table2_cost,
                            table3_convergence, trace_replay)
    suites = {
        "table2": table2_cost.run,
        "fig23": fig23_comm.run,
        "table3": table3_convergence.run,
        "roofline": roofline_report.run,
        "strategy_matrix": strategy_matrix.run,
        "fault_tolerance": fault_tolerance.run,
        "sweep": pareto_sweep.run,
        "knee": pareto_sweep.run_knee,
        "trace": trace_replay.run,
        "adversarial": adversarial_curves.run,
        "serving": serving_sweep.run,
        "recovery": recovery_replay.run,
        "kernels": kernel_bench.run,
        "comm": comm_regimes.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    rows = []
    for name, fn in suites.items():
        t0 = time.time()
        try:
            fn(rows)
            rows.append((f"{name}/_suite_seconds", time.time() - t0, "ok"))
        except Exception as e:  # report, keep going
            rows.append((f"{name}/_suite_FAILED", time.time() - t0,
                         repr(e)))
            import traceback
            traceback.print_exc()
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{str(derived).replace(',', ';')}")
    if any("_suite_FAILED" in r[0] for r in rows):
        sys.exit(1)


if __name__ == "__main__":
    main()
