"""Paper Table 2: training time, peak RAM, and cost per epoch —
MobileNet & ResNet-18 × {SPIRT, ScatterReduce, AllReduce, MLLess, GPU}.

Three layers of reproduction:
  1. *Cost-arithmetic validation*: recompute the paper's own USD numbers
     from its reported times/RAM (must match to rounding).
  2. *Measured compute*: time one real train-step of each CNN on this
     CPU (reduced width, scaled by the width ratio) to anchor the
     simulator's compute term.
  3. *Simulated epoch*: full per-stage breakdown + cost per architecture
     from the serverless simulator.
Extension (beyond paper): the same table for the 10 assigned
transformer archs on TPU v5e pricing via roofline step-time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy, losses
from repro.costmodel import flops as flopslib, pricing
from repro.models import build_cnn
from repro.serverless import (PAPER_TABLE2, ServerlessSetup,
                              paper_cost_check, simulate_epoch)

ARCH_MAP = {"spirt": "spirt", "scatterreduce": "scatterreduce",
            "allreduce": "allreduce", "mlless": "mlless", "gpu": "gpu"}


def _measure_cnn_step(kind: str, batch=64) -> float:
    """Seconds per (reduced-width) train step on this CPU, scaled to
    full width by the conv-FLOP ratio (width^2)."""
    cfg = get_config(kind).reduced()
    model = build_cnn(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def loss_fn(params, b):
        logits, _ = model.apply(params, b)
        return losses.classification_loss(logits, b["labels"])

    ts = build_train_step(model, optim.sgd(0.05, momentum=0.9),
                          get_strategy("allreduce"), mesh, loss_fn=loss_fn)
    state = ts.init_state(jax.random.PRNGKey(0))
    r = np.random.RandomState(0)
    batch_d = {"images": jnp.asarray(r.randn(batch, 32, 32, 3), jnp.float32),
               "labels": jnp.asarray(r.randint(0, 10, batch), jnp.int32)}
    state, _ = ts.step_fn(state, batch_d)          # compile
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    n = 3
    for _ in range(n):
        state, m = ts.step_fn(state, batch_d)
    jax.block_until_ready(m["loss"])
    per_step = (time.perf_counter() - t0) / n
    width_ratio = 1.0 / cfg.width_mult
    # conv flops scale ~width^2; paper batch is 512 vs our 64
    return per_step * width_ratio**2 * (512 / batch)


def run(csv_rows):
    # --- layer 1: validate the paper's own cost arithmetic
    for model_name in ("mobilenet", "resnet18"):
        for arch in ("spirt", "scatterreduce", "allreduce", "mlless",
                     "gpu"):
            r = paper_cost_check(model_name, arch)
            rel = abs(r["our_total"] - r["paper_total"]) / r["paper_total"]
            csv_rows.append((f"table2/validate/{model_name}/{arch}",
                             r["our_total"],
                             f"paper={r['paper_total']:.4f} rel_err="
                             f"{rel:.3f}"))
            assert rel < 0.12, (model_name, arch, r)

    # --- layer 2: real measured CNN train-step on THIS CPU (sanity row;
    # not fed to the simulator — a 1-core container is not a Lambda vCPU)
    for model_name, kind in (("mobilenet", "mobilenet-cifar"),
                             ("resnet18", "resnet18-cifar")):
        comp = _measure_cnn_step(kind)
        csv_rows.append((f"table2/cpu_measured/{model_name}", comp,
                         "s_per_batch512_scaled (1-core container)"))

    # --- layer 3: simulated epoch, compute anchored on the paper's own
    # measured per-batch times (compute = measured minus modeled sync)
    n_params = {"mobilenet": 4.2e6, "resnet18": 11.7e6}
    for model_name in ("mobilenet", "resnet18"):
        for arch in ("spirt", "scatterreduce", "allreduce", "mlless",
                     "gpu"):
            ram = PAPER_TABLE2[model_name][arch][1]
            setup = ServerlessSetup(ram_gb=(ram or 2048) / 1024.0)
            # compute share of each framework's own measured per-batch
            # time (the remainder is the sync/orchestration we model)
            from repro.serverless.simulator import paper_compute_anchor
            comp = paper_compute_anchor(arch, model_name)
            rep = simulate_epoch(ARCH_MAP[arch], n_params=int(
                n_params[model_name]), compute_s_per_batch=comp,
                setup=setup)
            csv_rows.append((
                f"table2/simulated/{model_name}/{arch}",
                rep.total_cost,
                f"time_s={rep.per_worker_s:.1f} sync_s="
                f"{rep.stages.sync:.2f} paper_total="
                f"{PAPER_TABLE2[model_name][arch][3]}"))
        sim = {r[0].split('/')[-1]: r[1] for r in csv_rows
               if r[0].startswith(f"table2/simulated/{model_name}/")}
        # the paper's orderings: MLLess most expensive serverless;
        # SPIRT pricier than the λML pair (longer-lived functions)
        assert sim["mlless"] > sim["spirt"] > min(sim["scatterreduce"],
                                                  sim["allreduce"])

    # --- beyond paper: TPU-pod cost per step for assigned archs
    for arch in ("smollm-135m", "phi3-mini-3.8b", "mixtral-8x7b"):
        cfg = get_config(arch)
        f = flopslib.train_step_flops(cfg, 256, 4096)
        t_ideal = f / (256 * pricing.HW.peak_flops_bf16) / 0.4  # 40% MFU
        cost = pricing.tpu_cost(t_ideal, 256)
        csv_rows.append((f"table2/tpu_v5e/{arch}", cost,
                         f"step_s={t_ideal:.3f} @40%MFU 256 chips"))
    return csv_rows
