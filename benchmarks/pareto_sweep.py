"""Cost-vs-makespan Pareto fronts + sweep-engine throughput.

Three sections, all recorded in ``BENCH_sweep.json`` so the repo's perf
trajectory is tracked run over run:

  1. *Analytic throughput* — the vectorized grid
     (``repro.serverless.sweep.sweep_analytic``) vs an equivalent loop
     of scalar ``simulate_epoch`` calls on a >=1,000-point grid
     (arch x n_workers x RAM tier x channel x accumulation x
     significant_fraction), with a spot exactness re-check.
  2. *Event-engine speedup* — the optimized ``EventRuntime`` vs the
     frozen PR 1 reference (``runtime_ref``) on a fault-injected epoch
     (crash + straggler, checkpoint-restore), asserting identical
     reports while timing.
  3. *Pareto fronts* — for every registered architecture (the paper's
     five plus the registry's hybrids), the ROADMAP's elastic pricing
     sweep: ReactiveAutoscaler bounds x Lambda RAM tiers x channel
     (Redis/S3) under seeded random faults, multi-replicate mean cost
     vs mean makespan, reduced to the non-dominated front.
  4. *Fault-rate knees* (``--only knee``, ``BENCH_knee.json`` + a PNG
     chart when matplotlib is available) — the ROADMAP's queued knee
     detection: cost overhead vs a crash x straggler rate ladder per
     architecture, reduced to the max-curvature knee by
     ``repro.serverless.sweep.knee_point``.

Architectures come from ``repro.serverless.archs.list_archs()`` — a
newly registered ArchSpec shows up in every section with no edits here.
Channel axes skip grid points an architecture's pinned sync channel
would falsify (gpu x redis used to report Redis labels with S3
numbers).

Rows: sweep/<section>/<name>,value,notes
Usage:
    PYTHONPATH=src python -m benchmarks.pareto_sweep [--quick]
        [--only analytic|event_engine|pareto|knee]
        [--json BENCH_sweep.json] [--processes N]
    PYTHONPATH=src python -m benchmarks.run --only sweep|knee
"""
from __future__ import annotations

import argparse
import json
import time

from repro.serverless import (FaultPlan, CheckpointRestore, ServerlessSetup,
                              Straggler, WorkerCrash, get_arch, list_archs)
from repro.serverless import runtime as runtime_opt
from repro.serverless import runtime_ref
from repro.serverless.simulator import (REDIS, S3,
                                        paper_compute_anchor
                                        as _compute_anchor)
from repro.serverless.sweep import (EventSweepPoint, FaultRates, SweepGrid,
                                    knee_point, pareto_front,
                                    ram_scaled_compute, scalar_sweep,
                                    sweep_analytic, sweep_events)

N_PARAMS = int(4.2e6)            # MobileNet
SECTIONS = ("analytic", "event_engine", "pareto", "knee")


def _analytic_grid(quick: bool) -> SweepGrid:
    if quick:
        return SweepGrid(
            n_params=N_PARAMS, compute_s_per_batch=ram_scaled_compute(0.9),
            n_workers=(2, 4, 8, 16), ram_gb=(1.0, 2.0, 3.0, 4.0),
            channels=(REDIS, S3), accumulation=(8, 24),
            significant_fraction=(0.1, 0.3, 0.5, 0.9))        # 1280 points
    return SweepGrid(
        n_params=N_PARAMS, compute_s_per_batch=ram_scaled_compute(0.9),
        n_workers=(2, 4, 8, 16), ram_gb=(1.0, 2.0, 3.0, 4.0, 6.0),
        channels=(REDIS, S3), accumulation=(8, 24),
        significant_fraction=(0.05, 0.1, 0.3, 0.5, 0.9))      # 2000 points


def bench_analytic(csv_rows, quick: bool) -> dict:
    grid = _analytic_grid(quick)
    sweep_analytic(grid)                         # warm numpy / imports
    t_vec = min(_timed(lambda: sweep_analytic(grid)) for _ in range(3))
    t_sca, reports = _timed_r(lambda: scalar_sweep(grid))
    vec = sweep_analytic(grid)
    # spot exactness re-check (the full property test lives in
    # tests/test_sweep.py)
    step = max(1, len(reports) // 97)
    for i in range(0, len(reports), step):
        assert vec.per_worker_s[i] == reports[i].per_worker_s, i
        assert vec.total_cost[i] == reports[i].total_cost, i
    speedup = t_sca / t_vec
    sims_per_s = grid.n_points / t_vec
    csv_rows.append(("sweep/analytic/points", grid.n_points, "grid size"))
    csv_rows.append(("sweep/analytic/vectorized_s", t_vec,
                     f"scalar={t_sca:.3f}s"))
    csv_rows.append(("sweep/analytic/speedup_x", speedup,
                     "vectorized vs scalar simulate_epoch loop"))
    csv_rows.append(("sweep/analytic/sims_per_s", sims_per_s, "vectorized"))
    return dict(points=grid.n_points, vectorized_s=t_vec, scalar_s=t_sca,
                speedup=speedup, sims_per_s=sims_per_s)


def bench_event_engine(csv_rows, quick: bool) -> dict:
    """Optimized vs reference engine on a fault-injected epoch."""
    arch = "allreduce"
    comp = _compute_anchor(arch)
    base = runtime_ref.run_event_epoch(arch, n_params=N_PARAMS,
                                       compute_s_per_batch=comp,
                                       setup=ServerlessSetup())
    kw = dict(n_params=N_PARAMS, compute_s_per_batch=comp,
              setup=ServerlessSetup(),
              faults=FaultPlan(
                  crashes=(WorkerCrash(1, 0.4 * base.makespan_s),),
                  stragglers=(Straggler(2, slowdown=4.0),)),
              recovery=CheckpointRestore(checkpoint_every=4))
    a = runtime_opt.run_event_epoch(arch, **kw)
    b = runtime_ref.run_event_epoch(arch, **kw)
    assert a.makespan_s == b.makespan_s, (a.makespan_s, b.makespan_s)
    assert a.total_cost == b.total_cost
    assert a.stage_totals == b.stage_totals

    n = 100 if quick else 300
    t_ref = min(_timed(lambda: [runtime_ref.run_event_epoch(arch, **kw)
                                for _ in range(n)]) for _ in range(3)) / n
    t_opt = min(_timed(lambda: [runtime_opt.run_event_epoch(arch, **kw)
                                for _ in range(n)]) for _ in range(3)) / n
    speedup = t_ref / t_opt
    csv_rows.append(("sweep/event_engine/ref_s_per_epoch", t_ref,
                     "PR1 closure-per-event engine"))
    csv_rows.append(("sweep/event_engine/opt_s_per_epoch", t_opt,
                     "slots + opcodes + lazy heap"))
    csv_rows.append(("sweep/event_engine/speedup_x", speedup,
                     f"fault-injected {arch} epoch (crash+straggler)"))
    csv_rows.append(("sweep/event_engine/epochs_per_s", 1.0 / t_opt,
                     "optimized"))
    return dict(scenario=f"{arch} crash+straggler restore",
                ref_s_per_epoch=t_ref, opt_s_per_epoch=t_opt,
                speedup=speedup, epochs_per_s=1.0 / t_opt)


def elastic_pricing_points(rams, scalers):
    """The ROADMAP's elastic pricing sweep: autoscaler (min, max)
    bounds x RAM tiers x channel, per registered architecture.  Shared
    with ``benchmarks/trace_replay.py`` so both benchmarks chart the
    same grid and their fronts stay comparable.  Channel pairings a
    spec's pinned sync channel would falsify are skipped (the gpu
    baseline syncs via S3 whatever the label says)."""
    points = []
    for arch in list_archs():
        model = ram_scaled_compute(_compute_anchor(arch))
        for ram in rams:
            for ch in (REDIS, S3):
                if get_arch(arch).pins_channel(ch):
                    continue      # label would disagree with the numbers
                for lo, hi in scalers:
                    points.append(EventSweepPoint(
                        arch=arch, n_params=N_PARAMS,
                        compute_s_per_batch=model(arch, ram),
                        setup=ServerlessSetup(ram_gb=ram, channel=ch),
                        autoscale_min=max(lo, 1), autoscale_max=hi,
                        label=f"ram{ram:g}/{ch.name}/as{lo}-{hi}"))
    return points


def _pareto_points(quick: bool):
    rams = (1.0, 2.0, 3.0) if quick else (1.0, 2.0, 3.0, 4.0)
    scalers = ((0, 0), (1, 8), (2, 16))          # (min, max); 0,0 = fixed
    return elastic_pricing_points(rams, scalers)


def bench_pareto(csv_rows, quick: bool, processes) -> dict:
    points = _pareto_points(quick)
    rates = FaultRates(crash_rate=0.2, straggler_rate=0.3, storm_prob=0.2)
    reps = 3 if quick else 8
    t0 = time.perf_counter()
    stats = sweep_events(points, rates=rates, n_replicates=reps, seed=42,
                         processes=processes)
    elapsed = time.perf_counter() - t0
    n_sims = len(points) * reps
    csv_rows.append(("sweep/event_sweep/points", len(points),
                     f"replicates={reps}"))
    csv_rows.append(("sweep/event_sweep/sims_per_s", n_sims / elapsed,
                     f"{n_sims} fault-injected epochs in {elapsed:.2f}s"))

    fronts = {}
    for arch in list_archs():
        rows = [s for s in stats if s.point.arch == arch]
        costs = [s.cost_mean for s in rows]
        makespans = [s.makespan_mean_s for s in rows]
        front = set(pareto_front(costs, makespans).tolist())
        fronts[arch] = [
            dict(label=s.point.label, ram_gb=s.point.setup.ram_gb,
                 channel=s.point.setup.channel.name,
                 autoscale_max=s.point.autoscale_max,
                 cost_mean=s.cost_mean, makespan_mean_s=s.makespan_mean_s,
                 makespan_p95_s=s.makespan_p95_s, ttr_p95_s=s.ttr_p95_s,
                 cost_overhead_mean=s.cost_overhead_mean,
                 on_front=i in front)
            for i, s in enumerate(rows)]
        fp = sorted((r for r in fronts[arch] if r["on_front"]),
                    key=lambda r: r["cost_mean"])
        # a front is non-dominated by construction: cost strictly up,
        # makespan strictly down
        for a, b in zip(fp, fp[1:]):
            assert b["cost_mean"] >= a["cost_mean"]
            assert b["makespan_mean_s"] < a["makespan_mean_s"]
        csv_rows.append((f"sweep/pareto/{arch}/front_size", len(fp),
                         f"of {len(rows)} swept configs"))
        for r in fp:
            csv_rows.append((
                f"sweep/pareto/{arch}/{r['label']}/cost", r["cost_mean"],
                f"makespan={r['makespan_mean_s']:.1f}s "
                f"p95={r['makespan_p95_s']:.1f}s"))
    return dict(points=len(points), replicates=reps, elapsed_s=elapsed,
                sims_per_s=n_sims / elapsed, fronts=fronts)


# categorical line palette (validated colorblind-safe adjacent order —
# dataviz reference palette, light mode) + knee chart styling
_SERIES_COLORS = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100", "#e87ba4",
                  "#008300", "#4a3aa7", "#e34948")
_SURFACE, _INK, _INK2 = "#fcfcfb", "#0b0b0b", "#52514e"


def _knee_rate_ladder(quick: bool):
    return ((0.0, 0.15, 0.3, 0.45, 0.6) if quick
            else (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8))


def bench_knee(csv_rows, quick: bool, processes,
               chart_path="knee_overhead.png") -> dict:
    """Fault-rate knee per architecture: cost overhead vs a
    crash x straggler rate ladder (both rates stepped together),
    reduced to the max-curvature point by :func:`knee_point`.  Every
    point uses its architecture's ``recovery="auto"`` policy, so the
    knee compares checkpoint-restore archs against SPIRT-style
    takeover at matched fault pressure."""
    archs = list_archs()
    ladder = _knee_rate_ladder(quick)
    reps = 3 if quick else 8
    points = [EventSweepPoint(arch=a, n_params=N_PARAMS,
                              compute_s_per_batch=_compute_anchor(a),
                              label=a)
              for a in archs]
    t0 = time.perf_counter()
    curves = {a: [] for a in archs}
    # one small grid per rung: a fresh spawn pool per rung would pay
    # interpreter + jax import many times over for ~20 fast epochs, so
    # default to inline unless the caller asks for processes
    processes = 1 if processes is None else processes
    for r in ladder:
        stats = sweep_events(points,
                             rates=FaultRates(crash_rate=r,
                                              straggler_rate=r),
                             n_replicates=reps, seed=7,
                             processes=processes)
        for s in stats:
            curves[s.point.arch].append(s.cost_overhead_mean)
    elapsed = time.perf_counter() - t0

    knees = {}
    for a in archs:
        try:
            ki = knee_point(ladder, curves[a])
        except ValueError:        # flat curve: no knee to report
            ki = None
        knees[a] = ki
        rate = float("nan") if ki is None else ladder[ki]
        over = float("nan") if ki is None else curves[a][ki]
        csv_rows.append((f"sweep/knee/{a}/rate", rate,
                         f"cost_overhead={over:.3f} reps={reps} "
                         f"recovery={get_arch(a).default_recovery}"))
    chart = _knee_chart(ladder, curves, knees, archs, chart_path)
    if chart:
        csv_rows.append(("sweep/knee/_chart", 1, chart))
    return dict(rates=list(ladder), replicates=reps, elapsed_s=elapsed,
                curves=curves,
                knees={a: (None if k is None else
                           dict(rate=ladder[k],
                                cost_overhead=curves[a][k]))
                       for a, k in knees.items()},
                chart=chart)


def _knee_chart(ladder, curves, knees, archs, path):
    """One light-surface line chart, knees marked; returns the path or
    None when matplotlib is unavailable (CI installs it, the dev
    container has it — but the benchmark must not require it)."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None
    fig, ax = plt.subplots(figsize=(7.5, 4.5), dpi=144)
    fig.patch.set_facecolor(_SURFACE)
    ax.set_facecolor(_SURFACE)
    for i, a in enumerate(archs):
        c = _SERIES_COLORS[i % len(_SERIES_COLORS)]
        ax.plot(ladder, curves[a], color=c, linewidth=2, label=a,
                zorder=3)
        ki = knees[a]
        if ki is not None:
            ax.plot([ladder[ki]], [curves[a][ki]], "o", color=c,
                    markersize=7, markeredgecolor=_SURFACE,
                    markeredgewidth=1.5, zorder=4)
    ax.set_xlabel("crash x straggler rate (per worker per epoch)",
                  color=_INK2)
    ax.set_ylabel("mean cost overhead vs fault-free", color=_INK2)
    ax.set_title("Fault-rate knee per architecture (dot = max "
                 "curvature)", color=_INK, loc="left")
    ax.grid(True, color="#e7e6e3", linewidth=0.8, zorder=0)
    for s in ("top", "right"):
        ax.spines[s].set_visible(False)
    for s in ("left", "bottom"):
        ax.spines[s].set_color("#d7d6d2")
    ax.tick_params(colors=_INK2)
    ax.legend(frameon=False, fontsize=8, ncol=2, labelcolor=_INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=_SURFACE)
    plt.close(fig)
    return path


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _timed_r(fn):
    t0 = time.perf_counter()
    r = fn()
    return time.perf_counter() - t0, r


def run(csv_rows, *, quick: bool = False, processes=None,
        json_path: str = "BENCH_sweep.json", only=None,
        knee_json: str = "BENCH_knee.json",
        knee_chart: str = "knee_overhead.png"):
    # knee is opt-in (--only knee / benchmarks.run --only knee): CI runs
    # it as its own artifact-producing step next to the default three
    sections = SECTIONS[:3] if only is None else (only,)
    payload = {"benchmark": "pareto_sweep", "quick": quick}
    if "analytic" in sections:
        payload["analytic"] = bench_analytic(csv_rows, quick)
    if "event_engine" in sections:
        payload["event_engine"] = bench_event_engine(csv_rows, quick)
    if "pareto" in sections:
        payload["event_sweep"] = bench_pareto(csv_rows, quick, processes)
    if "knee" in sections:
        knee = bench_knee(csv_rows, quick, processes,
                          chart_path=knee_chart)
        payload["knee"] = knee
        if knee_json:
            with open(knee_json, "w") as f:
                json.dump({"benchmark": "knee", "quick": quick,
                           **knee}, f, indent=2)
            csv_rows.append(("sweep/knee/_json", 1, knee_json))
    # only a run of ALL default sections may replace the TRACKED
    # BENCH_sweep.json — a --only iteration must not overwrite the
    # record with a partial payload; an explicit non-default --json
    # path is always honoured (and, dumped last, carries every section
    # that ran, knee included)
    if json_path and (only is None or json_path != "BENCH_sweep.json"):
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        csv_rows.append(("sweep/_json", 1, json_path))
    return csv_rows


def run_knee(csv_rows):
    """``benchmarks.run --only knee`` entry: just the knee section."""
    return run(csv_rows, only="knee")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grid / fewer replicates (CI)")
    ap.add_argument("--only", default=None, choices=SECTIONS,
                    help="run a single section (e.g. knee)")
    ap.add_argument("--json", default="BENCH_sweep.json",
                    help="payload path; with --only, the tracked "
                         "default is left untouched (pass another "
                         "path to capture a partial run)")
    ap.add_argument("--knee-json", default="BENCH_knee.json")
    ap.add_argument("--knee-chart", default="knee_overhead.png")
    ap.add_argument("--processes", type=int, default=None,
                    help="0/1 inline; default cpu count (<=8)")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, processes=args.processes,
        json_path=args.json, only=args.only, knee_json=args.knee_json,
        knee_chart=args.knee_chart)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")


if __name__ == "__main__":
    main()
