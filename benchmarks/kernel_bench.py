"""Kernel micro-bench: every public Pallas kernel vs its ref.py
oracle, roofline-gated.

Two sections, recorded in ``BENCH_kernels.json``:

  1. *probe* — measured stream bandwidth of this container (a jitted
     fp32 triad over ~64 MB), the denominator of every roofline floor.
  2. *kernels* — one row per public kernel entry point, timed on its
     **production path for the bench backend** against its pure-jnp
     oracle (``repro.kernels.ref``) across realistic shapes derived
     from the registered model configs (CNN fleets for the [W, D]
     robust-aggregation stacks, transformer geometry for attention /
     wkv; oversize dimensions are capped with the truncation logged in
     the row).  Each row must clear two floors:

       roofline_frac >= floor   measured time vs the bytes-touched /
                                stream-bandwidth lower bound (the
                                ``costmodel.hlo_analysis.entry_io_bytes``
                                compiler-confirmed IO is recorded
                                alongside the analytic count), and
       speedup >= floor         vs the jitted oracle.

     Off-TPU the production path is the kernel's fused-jnp twin where
     one exists (the robust-aggregation set, ``interpret=None``
     auto-dispatch) or the best jnp formulation the repo ships (swa ->
     ``models.attention.chunked_attention``).  Kernels whose CPU
     production path IS the oracle (fused_adamw, wkv6, block_norms,
     masked_filter — their win is Mosaic-only) time jit(ref) against
     itself and carry a noise-tolerant parity-class floor of 0.5x;
     the roofline floor still gates them.
     Floors are therefore per-kernel *and* per-backend, recorded in the
     deterministic payload.

Everything except timings is a pure function of (configs, shapes,
SEED): the payload records a content hash over the deterministic
``spec`` section, and a slow-marked test in ``tests/test_kernels.py``
re-runs ``--quick`` and asserts every row passes its floors.

Rows: kernels/<name>/<metric>,value,notes
Usage:
    PYTHONPATH=src python -m benchmarks.kernel_bench [--quick]
        [--only probe|kernels] [--json BENCH_kernels.json]
    PYTHONPATH=src python -m benchmarks.run --only kernels
"""
from __future__ import annotations

import argparse
import hashlib
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

SECTIONS = ("probe", "kernels")
SEED = 0
_REPS = 3


# ---------------------------------------------------------------------------
# timing + probe
# ---------------------------------------------------------------------------
def _timed(fn, *args) -> float:
    """Median-of-_REPS wall-clock of a jitted callable (one warmup)."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(_REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def stream_bandwidth_bytes_per_s(n: int = 16 * 2**20) -> float:
    """Measured triad bandwidth: y = a*x + y over fp32 length n
    (3 array touches per element = 12n bytes per call)."""
    x = jnp.arange(n, dtype=jnp.float32)
    y = jnp.ones((n,), jnp.float32)
    triad = jax.jit(lambda x, y: 2.5 * x + y)
    t = _timed(triad, x, y)
    return 12.0 * n / t


def bench_probe(csv_rows) -> dict:
    bw = stream_bandwidth_bytes_per_s()
    backend = jax.default_backend()
    csv_rows.append(("kernels/probe/backend", 0, backend))
    csv_rows.append(("kernels/probe/stream_gb_per_s", bw / 1e9,
                     "fp32 triad, 64 MB working set"))
    return dict(backend=backend, stream_bytes_per_s=bw)


# ---------------------------------------------------------------------------
# shapes from the registered configs
# ---------------------------------------------------------------------------
def _cnn_params(name: str) -> int:
    from repro.configs.base import get_config
    from repro.models.cnn import build_cnn
    model = build_cnn(get_config(name))
    params = model.init(jax.random.PRNGKey(0))
    return int(sum(x.size for x in jax.tree.leaves(params)))


def _capped(d: int, cap: int):
    """(capped D, truncation note)."""
    if d <= cap:
        return d, ""
    return cap, f"D truncated {d:,} -> {cap:,}"


def kernel_cases(quick: bool):
    """One spec dict per (kernel, shape): deterministic, hash-covered.

    [W, D] stacks: D from the serverless CNN configs (what SPIRT/MLLess
    actually aggregate) plus the smallest registered transformer; W
    from the paper's fleet sizes.  Oversize D (and krum's W^2-memory
    oracle) are capped with the truncation logged.
    """
    from repro.configs.base import get_config
    from repro.costmodel.flops import param_count

    # krum's oracle materializes [W, W, D] fp32, so its cap is tighter
    # (W=16 at 2**18 is already a 268 MB broadcast)
    cap = 2**18 if quick else 2**22
    krum_cap = 2**16 if quick else 2**18
    d_mobile = _cnn_params("mobilenet-cifar")
    d_resnet = _cnn_params("resnet18-cifar")
    d_smollm = param_count(get_config("smollm-135m"))

    cases = []

    def robust(kernel, floors, shapes, note=""):
        for cfg_name, w, d_full in shapes:
            this_cap = krum_cap if kernel == "krum_pairwise" else cap
            d, trunc = _capped(d_full, this_cap)
            cases.append(dict(
                kernel=kernel, config=cfg_name, W=w, D=d,
                trunc=trunc or note, floors=floors,
                cpu_path="fused-jnp-twin"))

    fleets = [("mobilenet-cifar", 8, d_mobile),
              ("resnet18-cifar", 16, d_resnet),
              ("smollm-135m", 12, d_smollm)]
    robust("trimmed_mean", dict(speedup=2.0, roofline_frac=0.05), fleets)
    robust("coordinate_median", dict(speedup=1.2, roofline_frac=0.02),
           fleets)
    robust("krum_pairwise", dict(speedup=2.0, roofline_frac=0.05),
           fleets)
    robust("weiszfeld_step", dict(speedup=1.1, roofline_frac=0.05),
           fleets)

    n, trunc = _capped(d_mobile, cap)
    cases.append(dict(kernel="fused_adamw_flat", config="mobilenet-cifar",
                      n=n, trunc=trunc,
                      floors=dict(speedup=0.5, roofline_frac=0.05),
                      cpu_path="oracle-jit"))

    # chunked attention only beats the naive S x S ref once S is large
    # enough that the full score matrix dominates; below ~1k it loses,
    # so even --quick stays at S=1024
    smollm = get_config("smollm-135m")
    S = 1024 if quick else 2048
    win = min(smollm.window, S // 4)
    cases.append(dict(
        kernel="swa_attention_fwd", config="smollm-135m", B=1, S=S,
        H=smollm.n_heads, KV=smollm.n_kv_heads, hd=smollm.head_dim,
        window=win,
        trunc=f"window capped {smollm.window} -> {win} (S={S})",
        floors=dict(speedup=1.0, roofline_frac=0.002),
        cpu_path="chunked-jnp"))

    rwkv = get_config("rwkv6-7b")
    T = 256 if quick else 1024
    H = 4 if quick else 8
    cases.append(dict(
        kernel="wkv6_chunked", config="rwkv6-7b", B=1, T=T, H=H,
        N=rwkv.head_dim,
        trunc=f"heads capped {rwkv.n_heads} -> {H}",
        floors=dict(speedup=0.5, roofline_frac=0.001),
        cpu_path="oracle-jit"))

    nb = 1024 if quick else 4096
    blk = 1024
    for kernel in ("block_norms", "masked_filter"):
        cases.append(dict(kernel=kernel, config="mobilenet-cifar",
                          n_blocks=nb, block=blk, trunc="",
                          floors=dict(speedup=0.5, roofline_frac=0.05),
                          cpu_path="oracle-jit"))
    return cases


# ---------------------------------------------------------------------------
# per-kernel bench/ref callables + analytic bytes
# ---------------------------------------------------------------------------
def _stack(rng, w, d):
    x = rng.standard_normal((w, d), dtype=np.float32)
    x[0] *= 50.0                        # one outlier row, like an attack
    return jnp.asarray(x)


def _build(case, rng):
    """Returns (bench_fn, ref_fn, args, bytes_touched) — both callables
    un-jitted here; the caller jits uniformly."""
    from repro.kernels import ref, robust_agg
    k = case["kernel"]
    f4 = 4  # fp32
    if k in ("trimmed_mean", "coordinate_median", "krum_pairwise",
             "weiszfeld_step"):
        w, d = case["W"], case["D"]
        x = _stack(rng, w, d)
        if k == "trimmed_mean":
            return (lambda s: robust_agg.trimmed_mean(s, 1),
                    lambda s: ref.trimmed_mean(s, 1), (x,),
                    (w + 1) * d * f4)
        if k == "coordinate_median":
            return (robust_agg.coordinate_median,
                    ref.coordinate_median, (x,), (w + 1) * d * f4)
        if k == "krum_pairwise":
            return (robust_agg.krum_pairwise, ref.krum_pairwise, (x,),
                    w * d * f4)
        z = jnp.asarray(np.median(np.asarray(x), axis=0))
        sq = jnp.sum(x * x, axis=1)
        floor = 1e-12 * float(np.linalg.norm(np.asarray(x), axis=1).max())
        return (lambda s, z_, sq_: robust_agg.weiszfeld_step(
                    s, z_, floor, row_sqnorms=sq_),
                lambda s, z_, sq_: ref.weiszfeld_step(s, z_, floor),
                (x, z, sq), (2 * w + 1) * d * f4)
    if k == "fused_adamw_flat":
        n = case["n"]
        g = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
        m = jnp.asarray(rng.standard_normal(n, dtype=np.float32) * 0.01)
        v = jnp.abs(jnp.asarray(
            rng.standard_normal(n, dtype=np.float32) * 0.01))
        p = jnp.asarray(rng.standard_normal(n, dtype=np.float32))
        kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.01)
        fn = lambda *a: ref.fused_adamw_flat(*a, **kw)
        return (fn, fn, (g, m, v, p, jnp.float32(0.1), jnp.float32(0.05)),
                7 * n * f4)
    if k == "swa_attention_fwd":
        from repro.models.attention import chunked_attention
        from repro.kernels import ref as _r
        B, S, H, KV, hd, win = (case[x] for x in
                                ("B", "S", "H", "KV", "hd", "window"))
        q = jnp.asarray(rng.standard_normal((B, S, H, hd),
                                            dtype=np.float32))
        kk = jnp.asarray(rng.standard_normal((B, S, KV, hd),
                                             dtype=np.float32))
        vv = jnp.asarray(rng.standard_normal((B, S, KV, hd),
                                             dtype=np.float32))
        return (lambda q_, k_, v_: chunked_attention(
                    q_, k_, v_, window=win, causal=True),
                lambda q_, k_, v_: _r.swa_attention(q_, k_, v_,
                                                    window=win),
                (q, kk, vv), (2 * B * S * H * hd
                              + 2 * B * S * KV * hd) * f4)
    if k == "wkv6_chunked":
        B, T, H, N = (case[x] for x in ("B", "T", "H", "N"))
        r_ = jnp.asarray(rng.standard_normal((B, T, H, N),
                                             dtype=np.float32) * 0.5)
        kk = jnp.asarray(rng.standard_normal((B, T, H, N),
                                             dtype=np.float32) * 0.5)
        vv = jnp.asarray(rng.standard_normal((B, T, H, N),
                                             dtype=np.float32) * 0.5)
        lw = -jnp.exp(jnp.asarray(rng.standard_normal(
            (B, T, H, N), dtype=np.float32) * 0.5 - 2.0))
        u = jnp.asarray(rng.standard_normal((H, N),
                                            dtype=np.float32) * 0.5)
        return (ref.wkv6, ref.wkv6, (r_, kk, vv, lw, u),
                5 * B * T * H * N * f4)
    if k == "block_norms":
        nb, blk = case["n_blocks"], case["block"]
        x = jnp.asarray(rng.standard_normal((nb, blk),
                                            dtype=np.float32))
        return ref.block_norms, ref.block_norms, (x,), nb * blk * f4
    if k == "masked_filter":
        nb, blk = case["n_blocks"], case["block"]
        x = jnp.asarray(rng.standard_normal((nb, blk),
                                            dtype=np.float32))
        mask = jnp.asarray(rng.standard_normal(nb) > 0.0)
        return (ref.masked_filter, ref.masked_filter, (x, mask),
                3 * nb * blk * f4)
    raise ValueError(f"unknown kernel case {k!r}")


def bench_kernels(csv_rows, quick: bool, stream_bw: float):
    """Returns (spec_rows, result_rows) — spec is deterministic."""
    from repro.costmodel.hlo_analysis import entry_io_bytes
    spec, results = [], []
    for case in kernel_cases(quick):
        rng = np.random.default_rng(SEED)
        bench_fn, ref_fn, args, touched = _build(case, rng)
        jb, jr = jax.jit(bench_fn), jax.jit(ref_fn)
        pb, rb = entry_io_bytes(jb.lower(*args).compile().as_text())
        t_k = _timed(jb, *args)
        t_r = _timed(jr, *args)
        floor_s = touched / stream_bw
        frac = floor_s / t_k if t_k > 0 else 0.0
        speedup = t_r / t_k if t_k > 0 else 0.0
        floors = case["floors"]
        ok = (frac >= floors["roofline_frac"]
              and speedup >= floors["speedup"])
        label = "/".join(
            str(case[x]) for x in ("kernel", "config") if x in case)
        spec.append({**{k: v for k, v in case.items()},
                     "bytes_touched": touched})
        results.append(dict(
            kernel=case["kernel"], config=case["config"],
            kernel_s=t_k, ref_s=t_r, speedup=speedup,
            roofline_floor_s=floor_s, roofline_frac=frac,
            entry_param_bytes=pb, entry_result_bytes=rb,
            passed=bool(ok)))
        csv_rows.append((f"kernels/{label}/speedup", speedup,
                         f"floor {floors['speedup']}x; "
                         f"path {case['cpu_path']}"))
        csv_rows.append((f"kernels/{label}/roofline_frac", frac,
                         f"floor {floors['roofline_frac']}; "
                         f"kernel {t_k * 1e3:.1f}ms vs "
                         f"stream floor {floor_s * 1e3:.1f}ms"))
        if not ok:
            csv_rows.append((f"kernels/{label}/_FLOOR_MISS", 1,
                             f"speedup {speedup:.2f} "
                             f"frac {frac:.4f}"))
    n_pass = sum(r["passed"] for r in results)
    csv_rows.append(("kernels/rows_passed", n_pass,
                     f"of {len(results)}"))
    return spec, results


# ---------------------------------------------------------------------------
# payload
# ---------------------------------------------------------------------------
def _jsonable(x):
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (bool, np.bool_)):
        return bool(x)
    if isinstance(x, (np.floating, float)):
        f = float(x)
        return f if math.isfinite(f) else None
    if isinstance(x, (np.integer, int)):
        return int(x)
    return x


def _content_hash(payload: dict) -> str:
    """Hash of the deterministic sections (probe + timings excluded) —
    the bit-reproducibility receipt the tests re-derive."""
    det = {k: v for k, v in payload.items()
           if k not in ("probe", "results")}
    blob = json.dumps(_jsonable(det), sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def run(csv_rows, *, quick: bool = False,
        json_path: str = "BENCH_kernels.json", only=None):
    sections = SECTIONS if only is None else (only,)
    payload = {"benchmark": "kernel_bench", "quick": quick,
               "seed": SEED}
    stream_bw = None
    if "probe" in sections or "kernels" in sections:
        payload["probe"] = bench_probe(csv_rows)
        stream_bw = payload["probe"]["stream_bytes_per_s"]
    if "kernels" in sections:
        spec, results = bench_kernels(csv_rows, quick, stream_bw)
        payload["spec"] = spec
        payload["results"] = results
    payload["content_hash"] = _content_hash(payload)
    csv_rows.append(("kernels/_content_hash", payload["content_hash"],
                     "sha256[:16] of the deterministic spec"))
    # only a run of ALL sections may replace the TRACKED
    # BENCH_kernels.json (a --only iteration must not overwrite the
    # record with a partial payload); an explicit non-default --json
    # path is always honoured
    if json_path and (only is None or json_path != "BENCH_kernels.json"):
        with open(json_path, "w") as f:
            json.dump(_jsonable(payload), f, indent=2)
        csv_rows.append(("kernels/_json", 1, json_path))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller shapes (CI)")
    ap.add_argument("--only", default=None, choices=SECTIONS)
    ap.add_argument("--json", default="BENCH_kernels.json",
                    help="payload path; with --only, the tracked "
                         "default is left untouched")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, json_path=args.json, only=args.only)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")


if __name__ == "__main__":
    main()
