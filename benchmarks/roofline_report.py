"""Roofline summary over dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``results/dryrun/*.json`` (produced by ``repro.launch.dryrun``)
and emits one row per (arch × shape × mesh): the three roofline terms,
the dominant bottleneck, and the useful-FLOPs fraction.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run(csv_rows):
    files = sorted(RESULTS.glob("*.json")) if RESULTS.exists() else []
    if not files:
        csv_rows.append(("roofline/NOTE", 0,
                         "no dry-run artifacts yet; run "
                         "python -m repro.launch.dryrun --all"))
        return csv_rows
    for f in files:
        d = json.loads(f.read_text())
        if "skipped" in d:
            csv_rows.append((f"roofline/{f.stem}", 0, d["skipped"]))
            continue
        rf = d["roofline"]
        csv_rows.append((
            f"roofline/{f.stem}",
            rf["step_time_lower_bound_s"],
            f"dom={rf['dominant']} comp={rf['compute_s']:.4f} "
            f"mem={rf['memory_s']:.4f} coll={rf['collective_s']:.4f} "
            f"useful={rf['useful_flops_fraction']:.2f} "
            f"peakGB={d['memory']['peak_estimate_gb']:.1f}"))
    return csv_rows
