"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
results/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.make_tables [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["mixtral-8x22b", "mixtral-8x7b", "gemma3-4b", "pixtral-12b",
              "rwkv6-7b", "recurrentgemma-2b", "phi3-mini-3.8b",
              "qwen1.5-4b", "smollm-135m", "whisper-small"]


def _fmt_bytes(b):
    if b >= 2**30:
        return f"{b / 2**30:.1f}G"
    if b >= 2**20:
        return f"{b / 2**20:.1f}M"
    return f"{b / 2**10:.0f}K"


def load(mesh):
    rows = {}
    for f in RESULTS.glob(f"*__{mesh}.json"):
        d = json.loads(f.read_text())
        rows[(d["arch"], d["shape"])] = d
    return rows


def dryrun_table(rows):
    out = ["| arch | shape | compile s | bytes/dev (arg+out+temp) | "
           "collectives (AR/AG/RS/A2A) | wire B/dev |",
           "|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape))
            if d is None:
                continue
            if "skipped" in d:
                out.append(f"| {arch} | {shape} | — | — | skipped "
                           f"(DESIGN.md §3) | — |")
                continue
            m = d["memory"]
            c = d["collectives"]["counts"]
            out.append(
                f"| {arch} | {shape} | {d['compile_s']} | "
                f"{m['peak_estimate_gb']:.2f} GB | "
                f"{c['all-reduce']}/{c['all-gather']}/"
                f"{c['reduce-scatter']}/{c['all-to-all']} | "
                f"{_fmt_bytes(d['collectives']['wire_bytes_per_device'])} |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | model GFLOPs | useful frac | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = rows.get((arch, shape))
            if d is None or "skipped" in d:
                continue
            r = d["roofline"]
            out.append(
                f"| {arch} | {shape} | {r['compute_s']:.4g} | "
                f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
                f"**{r['dominant']}** | {r['model_flops'] / 1e9:.3g} | "
                f"{r['useful_flops_fraction']:.2f} | "
                f"{r['mfu_upper_bound']:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.section in ("dryrun", "both"):
        print(f"### Dry-run ({args.mesh})\n")
        print(dryrun_table(rows))
        print()
    if args.section in ("roofline", "both"):
        print(f"### Roofline ({args.mesh})\n")
        print(roofline_table(rows))


if __name__ == "__main__":
    main()
