"""Paper Fig. 2 + Fig. 3 + §4.2: communication-overhead techniques.

Fig. 2: AllReduce vs ScatterReduce communication time as workers scale
        (4..16) for MobileNet (4.2M) and ResNet-50 (25.6M) — reproduces
        the crossover the paper reports (AllReduce wins for small models
        at high worker counts; ScatterReduce wins for large models).
Fig. 3: MLLess significant-update filtering — communication volume vs
        threshold, plus the paper's SPIRT in-database win (§4.2).

All numbers come from the serverless simulator (channel model anchored
on EC2-Redis bandwidth); the TPU-collective analogues are measured by
the dry-run HLO analysis (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import numpy as np

from repro.core import get_strategy
from repro.serverless import ServerlessSetup, simulate_epoch


def run(csv_rows):
    from repro.serverless.simulator import S3
    models = {"mobilenet": 4.2e6, "resnet50": 25.6e6}
    # --- Fig 2: comm time vs workers (LambdaML variants use S3)
    for mname, npar in models.items():
        for W in (4, 8, 16):
            setup = ServerlessSetup(n_workers=W, channel=S3)
            for arch in ("allreduce", "scatterreduce"):
                rep = simulate_epoch(arch, n_params=int(npar),
                                     compute_s_per_batch=1.0, setup=setup)
                per_batch_sync = rep.stages.sync / setup.batches_per_worker
                csv_rows.append((f"fig2/{mname}/{arch}/W{W}",
                                 per_batch_sync, "sync_s_per_batch"))
    get = {r[0]: r[1] for r in csv_rows}
    # the paper's two qualitative claims (§4.2, Fig 2):
    #   large model, many workers: ScatterReduce < AllReduce (master
    #   bandwidth bottleneck);
    assert get["fig2/resnet50/scatterreduce/W16"] < \
        get["fig2/resnet50/allreduce/W16"]
    #   small model, many workers: AllReduce < ScatterReduce (chunked
    #   exchange is per-op-latency dominated)
    assert get["fig2/mobilenet/allreduce/W16"] < \
        get["fig2/mobilenet/scatterreduce/W16"]

    # --- Fig 3: MLLess filtering
    for frac in (1.0, 0.5, 0.3, 0.1):
        rep = simulate_epoch("mlless", n_params=int(4.2e6),
                             compute_s_per_batch=1.0,
                             significant_fraction=frac)
        csv_rows.append((f"fig3/mlless/frac{frac}", rep.stages.sync,
                         "sync_s_per_epoch"))
    assert get if True else None
    ml = [r for r in csv_rows if r[0].startswith("fig3/")]
    assert ml[-1][1] < ml[0][1]    # filtering reduces comm time

    # --- §4.2 SPIRT in-database vs naive fetch-update-store
    # naive: fetch grads, average outside, store back (3 transfers);
    # in-db: single in-database op (RedisAI) per the paper
    from repro.serverless.simulator import REDIS
    G = 11.7e6 * 4
    naive_avg = 3 * REDIS.transfer(G, ops=3) * 24
    indb_avg = REDIS.transfer(G, ops=1) * 24
    csv_rows.append(("sec42/spirt/naive_avg_s", naive_avg,
                     "paper: 67.32s"))
    csv_rows.append(("sec42/spirt/indb_avg_s", indb_avg, "paper: 37.41s"))
    assert indb_avg < naive_avg

    # --- strategy logical comm bytes (TPU mapping) per worker
    grads = [np.zeros(int(4.2e6), np.float32)]
    for name in ("allreduce", "scatterreduce", "parameter_server",
                 "spirt", "mlless"):
        b = get_strategy(name).comm_bytes(grads, 16)
        csv_rows.append((f"fig2/tpu_logical_bytes/{name}/W16", b, "bytes"))
    return csv_rows
