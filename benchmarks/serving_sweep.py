"""Serving fleet: latency/cost Pareto fronts + analytic throughput.

The serving twin of ``benchmarks/pareto_sweep.py`` — the training
benchmarks answer "what does an epoch cost"; this one answers the
north star's other half: what do the registered architectures' cost /
latency trade-offs look like under live inference traffic.  Three
sections, recorded in ``BENCH_serving.json``:

  1. *Analytic throughput* — the vectorized M/G/c grid
     (``repro.serving.steady_state.serving_sweep_analytic``) over
     arch x replicas x RAM x arrival-rate, timed; the record is
     simulated requests per wall-clock second (the grid covers
     ``n_points x n_requests`` requests) with a >= 1M/s floor pinned
     by a slow-marked test in ``tests/test_serving_fleet.py``.
  2. *Agreement* — the closed form vs the request-level event engine
     (``repro.serving.fleet.FleetSim``) on overlapping stable grid
     points: max relative error on mean latency, recorded so drift in
     either path shows up in the bench trail.
  3. *Pareto fronts* — per architecture, the non-dominated
     (usd_per_1k_requests, latency) points of the stable grid for each
     of p50/p95/p99, plus a matplotlib-gated chart
     (``serving_pareto.png``).

Everything downstream of ``(grid, SEED)`` is closed-form or seeded, so
``BENCH_serving.json`` is bit-reproducible run over run; the payload
records its own content hash.  Architectures come from
``repro.serverless.archs.list_archs()`` — a newly registered ArchSpec
shows up in every section with no edits here.

Rows: serving/<section>/<name>,value,notes
Usage:
    PYTHONPATH=src python -m benchmarks.serving_sweep [--quick]
        [--only throughput|agreement|pareto]
        [--json BENCH_serving.json] [--chart serving_pareto.png]
    PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import numpy as np

from repro.serverless.archs import list_archs
from repro.serverless.sweep import pareto_front
from repro.serving.fleet import FleetSim
from repro.serving.steady_state import ServingGrid, serving_sweep_analytic
from repro.serving.workload import Workload

SECTIONS = ("throughput", "agreement", "pareto")
SEED = 42
PCTS = ("p50", "p95", "p99")


def _grid(quick: bool) -> ServingGrid:
    if quick:
        return ServingGrid(
            replicas=(1, 2, 4), ram_gb=(2.0, 4.0),
            rate_rps=(0.1, 0.25, 0.5, 1.0, 2.0, 4.0))
    return ServingGrid(
        replicas=(1, 2, 4, 8), ram_gb=(1.0, 2.0, 3.0, 4.0),
        rate_rps=(0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0))


def bench_throughput(csv_rows, quick: bool) -> dict:
    grid = _grid(quick)
    serving_sweep_analytic(grid)                 # warm numpy / imports
    t = min(_timed(lambda: serving_sweep_analytic(grid))
            for _ in range(3))
    sw = serving_sweep_analytic(grid)
    req_per_s = sw.requests_simulated / t
    csv_rows.append(("serving/throughput/points", len(sw),
                     f"{len(grid.resolved_archs())} archs"))
    csv_rows.append(("serving/throughput/requests_simulated",
                     sw.requests_simulated,
                     f"{grid.n_requests} per point"))
    csv_rows.append(("serving/throughput/requests_per_s", req_per_s,
                     "analytic grid; floor 1e6 pinned in tests"))
    return dict(points=len(sw),
                requests_simulated=sw.requests_simulated,
                elapsed_s=t, requests_per_s=req_per_s)


def agreement_cases(quick: bool):
    """Overlapping grid points for the two engines: stable,
    moderately loaded, cold-start-free (steady state has none)."""
    n = 2_000 if quick else 5_000
    wl = Workload(n_requests=n, rate_rps=1.0,
                  prompt_tokens=256, decode_tokens=64)
    return [
        (FleetSim(arch="spirt", replicas=2, batch_size=8,
                  cold_start_s=0.0), wl.with_rate(2.0)),
        (FleetSim(arch="spirt", replicas=1, batch_size=8, ram_gb=4.0,
                  cold_start_s=0.0), wl.with_rate(2.0)),
        (FleetSim(arch="gpu", replicas=2, batch_size=8,
                  cold_start_s=0.0), wl.with_rate(4.0)),
    ]


def bench_agreement(csv_rows, quick: bool) -> dict:
    from repro.serving.steady_state import analytic_point
    rows = []
    worst = 0.0
    for sim, wl in agreement_cases(quick):
        rep = sim.run(wl.generate(SEED))
        ana = analytic_point(sim, wl)
        rel = abs(rep.mean_latency_s - ana["mean_latency_s"]) \
            / rep.mean_latency_s
        worst = max(worst, rel)
        label = f"{sim.arch}/R{sim.replicas}/ram{sim.ram_gb:g}" \
                f"/rps{wl.rate_rps:g}"
        rows.append(dict(label=label, rho=float(ana["rho"]),
                         event_mean_s=rep.mean_latency_s,
                         analytic_mean_s=float(ana["mean_latency_s"]),
                         event_p95_s=rep.latency_p95_s,
                         analytic_p95_s=float(ana["latency_p95_s"]),
                         rel_err_mean=rel))
        csv_rows.append((f"serving/agreement/{label}", rel,
                         f"event={rep.mean_latency_s:.3f}s "
                         f"analytic={ana['mean_latency_s']:.3f}s "
                         f"rho={ana['rho']:.2f}"))
    csv_rows.append(("serving/agreement/max_rel_err_mean", worst,
                     "tolerance pinned in tests"))
    return dict(cases=rows, max_rel_err_mean=worst)


def bench_pareto(csv_rows, quick: bool,
                 chart_path="serving_pareto.png") -> dict:
    grid = _grid(quick)
    sw = serving_sweep_analytic(grid)
    fronts = {}
    for arch in grid.resolved_archs():
        idx = np.flatnonzero((sw.arch == arch) & sw.stable)
        rows = []
        front_sets = {}
        for pct in PCTS:
            lat = getattr(sw, f"latency_{pct}_s")[idx]
            cost = sw.usd_per_1k_requests[idx]
            front_sets[pct] = set(
                int(idx[k]) for k in pareto_front(cost, lat))
        for j in idx:
            on = {pct: int(j) in front_sets[pct] for pct in PCTS}
            if not any(on.values()):
                continue                  # record front points only
            rows.append(dict(
                replicas=int(sw.replicas[j]),
                ram_gb=float(sw.ram_gb[j]),
                rate_rps=float(sw.rate_rps[j]),
                rho=float(sw.rho[j]),
                latency_p50_s=float(sw.latency_p50_s[j]),
                latency_p95_s=float(sw.latency_p95_s[j]),
                latency_p99_s=float(sw.latency_p99_s[j]),
                usd_per_1k_requests=float(sw.usd_per_1k_requests[j]),
                on_front={p: on[p] for p in PCTS}))
        fronts[arch] = dict(stable_points=int(idx.size),
                            swept_points=int((sw.arch == arch).sum()),
                            front=sorted(
                                rows,
                                key=lambda r: r["usd_per_1k_requests"]))
        p95_front = [r for r in fronts[arch]["front"]
                     if r["on_front"]["p95"]]
        # non-dominated by construction: cost strictly up, p95 down
        for a, b in zip(p95_front, p95_front[1:]):
            assert b["usd_per_1k_requests"] >= a["usd_per_1k_requests"]
            assert b["latency_p95_s"] < a["latency_p95_s"]
        csv_rows.append((f"serving/pareto/{arch}/front_size",
                         len(p95_front),
                         f"of {idx.size} stable configs (p95 front)"))
        for r in p95_front:
            csv_rows.append((
                f"serving/pareto/{arch}/R{r['replicas']}"
                f"-ram{r['ram_gb']:g}-rps{r['rate_rps']:g}/usd_per_1k",
                r["usd_per_1k_requests"],
                f"p50={r['latency_p50_s']:.2f}s "
                f"p95={r['latency_p95_s']:.2f}s "
                f"p99={r['latency_p99_s']:.2f}s"))
    chart = _pareto_chart(fronts, chart_path)
    if chart:
        csv_rows.append(("serving/pareto/_chart", 1, chart))
    return dict(grid=dict(replicas=list(grid.replicas),
                          ram_gb=list(grid.ram_gb),
                          rate_rps=list(grid.rate_rps),
                          batch_size=grid.batch_size,
                          n_requests=grid.n_requests),
                fronts=fronts, chart=chart)


# palette shared with the training benches (colorblind-safe order)
_SERIES_COLORS = ("#2a78d6", "#eb6834", "#1baf7a", "#eda100", "#e87ba4",
                  "#008300", "#4a3aa7", "#e34948")
_SURFACE, _INK, _INK2 = "#fcfcfb", "#0b0b0b", "#52514e"


def _pareto_chart(fronts, path):
    """p95-latency-vs-cost fronts, one line per architecture; returns
    the path or None when matplotlib is unavailable."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None
    fig, ax = plt.subplots(figsize=(7.5, 4.5), dpi=144)
    fig.patch.set_facecolor(_SURFACE)
    ax.set_facecolor(_SURFACE)
    for i, (arch, data) in enumerate(fronts.items()):
        pts = [r for r in data["front"] if r["on_front"]["p95"]]
        if not pts:
            continue
        c = _SERIES_COLORS[i % len(_SERIES_COLORS)]
        xs = [r["usd_per_1k_requests"] for r in pts]
        ys = [r["latency_p95_s"] for r in pts]
        ax.plot(xs, ys, "o-", color=c, linewidth=2, markersize=4,
                markeredgecolor=_SURFACE, markeredgewidth=0.8,
                label=arch, zorder=3)
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel("cost (USD per 1k requests)", color=_INK2)
    ax.set_ylabel("p95 latency (s)", color=_INK2)
    ax.set_title("Serving Pareto fronts: p95 latency vs cost per "
                 "architecture", color=_INK, loc="left")
    ax.grid(True, color="#e7e6e3", linewidth=0.8, zorder=0)
    for s in ("top", "right"):
        ax.spines[s].set_visible(False)
    for s in ("left", "bottom"):
        ax.spines[s].set_color("#d7d6d2")
    ax.tick_params(colors=_INK2, which="both")
    ax.legend(frameon=False, fontsize=8, ncol=2, labelcolor=_INK)
    fig.tight_layout()
    fig.savefig(path, facecolor=_SURFACE)
    plt.close(fig)
    return path


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _content_hash(payload: dict) -> str:
    """Hash of the deterministic payload (timings excluded) — the
    bit-reproducibility receipt the tests re-derive."""
    det = {k: v for k, v in payload.items() if k != "throughput"}
    blob = json.dumps(det, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def run(csv_rows, *, quick: bool = False,
        json_path: str = "BENCH_serving.json", only=None,
        chart: str = "serving_pareto.png"):
    sections = SECTIONS if only is None else (only,)
    payload = {"benchmark": "serving_sweep", "quick": quick,
               "seed": SEED}
    if "throughput" in sections:
        payload["throughput"] = bench_throughput(csv_rows, quick)
    if "agreement" in sections:
        payload["agreement"] = bench_agreement(csv_rows, quick)
    if "pareto" in sections:
        payload["pareto"] = bench_pareto(csv_rows, quick,
                                         chart_path=chart)
    payload["content_hash"] = _content_hash(payload)
    csv_rows.append(("serving/_content_hash", payload["content_hash"],
                     "sha256[:16] of the deterministic sections"))
    # only a run of ALL sections may replace the TRACKED
    # BENCH_serving.json (the PR 4 rule: a --only iteration must not
    # overwrite the record with a partial payload); an explicit
    # non-default --json path is always honoured
    if json_path and (only is None or json_path != "BENCH_serving.json"):
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        csv_rows.append(("serving/_json", 1, json_path))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grid / fewer event requests (CI)")
    ap.add_argument("--only", default=None, choices=SECTIONS)
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="payload path; with --only, the tracked "
                         "default is left untouched")
    ap.add_argument("--chart", default="serving_pareto.png")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, json_path=args.json, only=args.only,
        chart=args.chart)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")


if __name__ == "__main__":
    main()
