"""Adversarial-fraction degradation curves (the ROADMAP's last
PR-1-era open item).

Three sections, recorded in ``BENCH_adversarial.json`` (plus a
matplotlib-gated chart):

  1. *sim* — the full byzantine-fraction x attack-model x aggregator
     surface on the deterministic quadratic-loss path
     (``repro.serverless.sweep.adversarial_sweep``), with the paper's
     qualitative claims asserted quantitatively: plain averaging
     degrades monotonically (censored convergence step) as the
     byzantine fraction grows 0 -> (W-1)/2W under every attack, while
     trimmed-mean / coordinate-median / Krum / geometric-median hold a
     bounded robustness floor up to each statistic's theoretical
     breakdown budget — and collapse beyond it (visible for Krum past
     ``f = (W-3)/2`` under the colluding little-is-enough attack).
  2. *arch* — per registered architecture, the degradation curve under
     its :class:`~repro.serverless.archs.ArchSpec.default_aggregator`:
     the SPIRT family's in-database trimmed mean holds the floor where
     every plain-averaging architecture diverges.
  3. *jax* — the real-training rows: MobileNet, 4-way data-parallel,
     worker 0 byzantine for the whole run via the refactored
     ``repro.launch.byzantine_train`` (any attack x any aggregator).
     Reproduces PR 1's converges-under-attack result for at least two
     attack models, with plain averaging under the same attack as the
     diverging control.

Rows: adversarial/<section>/<name>,value,notes
Usage:
    PYTHONPATH=src python -m benchmarks.adversarial_curves [--quick]
        [--only sim|arch|jax] [--skip-jax]
        [--json BENCH_adversarial.json] [--chart adversarial_curves.png]
    PYTHONPATH=src python -m benchmarks.run --only adversarial
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.launch import byzantine_train
from repro.serverless import get_arch, list_archs
from repro.serverless.adversarial import sim_aggregator_max_f
from repro.serverless.sweep import (AdversarialGrid, adversarial_curve,
                                    adversarial_sweep)

SECTIONS = ("sim", "arch", "jax")
#: the strong attacks whose end-of-ladder degradation must dwarf the
#: fraction-0 baseline; little_is_enough is STEALTHY by design (it
#: hides inside the honest spread and only shifts the mean steadily),
#: and zero/sign_flip merely slow plain averaging down
STRONG_ATTACKS = ("scale", "gaussian_noise")
ROBUST = ("trimmed_mean", "coordinate_median", "krum",
          "geometric_median")


def _grid(quick: bool, **overrides) -> AdversarialGrid:
    base = dict(n_workers=8, steps=60) if quick \
        else dict(n_workers=12, steps=80)
    base.update(overrides)
    return AdversarialGrid(**base)


def _censored_steps(cells, grid, aggregator, attack):
    fr, cs = adversarial_curve(cells, aggregator, attack,
                               "converged_step")
    return fr, np.where(cs < 0, grid.steps + 1, cs).astype(int)


def bench_sim(csv_rows, quick: bool) -> dict:
    grid = _grid(quick)
    t0 = time.perf_counter()
    cells = adversarial_sweep(grid, seed=0)
    elapsed = time.perf_counter() - t0
    assert cells == adversarial_sweep(grid, seed=0), \
        "adversarial_sweep is not bit-reproducible from (grid, seed)"
    csv_rows.append(("adversarial/sim/cells", len(cells),
                     f"W={grid.n_workers} steps={grid.steps} "
                     f"{elapsed:.3f}s"))

    curves = {}
    breakdown = {}
    for agg in grid.resolved_aggregators():
        cap = sim_aggregator_max_f(agg, grid.n_workers)
        for attack in sorted({c.attack for c in cells}):
            fr, dist = adversarial_curve(cells, agg, attack)
            _, steps = _censored_steps(cells, grid, agg, attack)
            curves[f"{agg}/{attack}"] = dict(
                fractions=fr.tolist(), final_dist=dist.tolist(),
                converged_step=steps.tolist(), max_f=cap)
            # first swept fraction whose cell left the bounded floor
            broke = next((float(f) for f, d in zip(fr, dist)
                          if d > 2 * grid.converge_tol), None)
            breakdown[f"{agg}/{attack}"] = broke
            csv_rows.append((
                f"adversarial/sim/{agg}/{attack}/final_dist_at_max",
                float(dist[-1]),
                f"frac={fr[-1]:.3f} breakdown_frac={broke}"))

    # the paper's qualitative ordering, asserted quantitatively --------
    floor = 2 * grid.converge_tol
    for attack in sorted({c.attack for c in cells}):
        # plain averaging: monotone degradation along the whole ladder
        _, steps = _censored_steps(cells, grid, "mean", attack)
        assert all(b >= a for a, b in zip(steps, steps[1:])), (
            "mean convergence-step curve must be monotone", attack,
            steps.tolist())
        fr, dist = adversarial_curve(cells, "mean", attack)
        if attack in STRONG_ATTACKS:
            assert dist[-1] > 10 * max(dist[0], grid.converge_tol), (
                "mean must degrade badly under", attack, dist.tolist())
        elif attack == "little_is_enough":
            # stealthy: the mean's floor rises steadily with the
            # colluding fraction even though no single step is wild
            assert dist[-1] > 1.5 * dist[0], (attack, dist.tolist())
        # robust statistics: bounded floor up to their breakdown budget
        for agg in ROBUST:
            cap = sim_aggregator_max_f(agg, grid.n_workers)
            held = [c for c in cells
                    if c.aggregator == agg and c.attack == attack
                    and c.n_byz <= cap]
            assert held and all(not c.diverged
                                and c.final_dist <= floor
                                for c in held), (
                "robustness floor violated within breakdown budget",
                agg, attack,
                [(c.fraction, c.final_dist) for c in held])
    # breakdown contrast at the top of the ladder, strongest attack
    _, mean_scale = adversarial_curve(cells, "mean", "scale")
    for agg in ROBUST:
        _, rob = adversarial_curve(cells, agg, "scale")
        assert mean_scale[-1] > 100 * rob[-1], (agg, mean_scale[-1],
                                                rob[-1])
    csv_rows.append(("adversarial/sim/floor_held", 1,
                     f"robust floor <= {floor:.2f} up to breakdown; "
                     f"mean/scale ends at {mean_scale[-1]:.3g}"))
    return dict(n_workers=grid.n_workers, steps=grid.steps,
                converge_tol=grid.converge_tol, elapsed_s=elapsed,
                curves=curves, breakdown_fractions=breakdown)


def bench_arch(csv_rows, quick: bool) -> dict:
    """Per-architecture vulnerability: every registered ArchSpec swept
    under ITS default aggregation statistic."""
    aggs = tuple(dict.fromkeys(
        get_arch(a).default_aggregator for a in list_archs()))
    grid = _grid(quick, aggregators=aggs,
                 attacks=("scale", "little_is_enough"))
    cells = adversarial_sweep(grid, seed=1)
    out = {}
    for arch in list_archs():
        agg = get_arch(arch).default_aggregator
        out[arch] = {"aggregator": agg}
        for attack in grid.resolved_attacks():
            fr, dist = adversarial_curve(cells, agg, attack)
            _, steps = _censored_steps(cells, grid, agg, attack)
            out[arch][attack] = dict(fractions=fr.tolist(),
                                     final_dist=dist.tolist(),
                                     converged_step=steps.tolist())
            csv_rows.append((
                f"adversarial/arch/{arch}/{attack}/final_dist_at_max",
                float(dist[-1]), f"aggregator={agg}"))
    # the paper's per-arch story: in-DB robust archs survive the attack
    # ladder that blows up every plain-averaging architecture
    for arch in list_archs():
        spec = get_arch(arch)
        _, dist = adversarial_curve(
            cells, spec.default_aggregator, "scale")
        if spec.default_aggregator == "mean":
            assert dist[-1] > 10 * grid.init_dist, (arch, dist[-1])
        else:
            assert dist[-1] <= 2 * grid.converge_tol, (arch, dist[-1])
    return out


def bench_jax(csv_rows, quick: bool) -> dict:
    """Real-training rows: robust aggregation converges through an
    active byzantine worker under >= 2 attack models; plain averaging
    under the same attack is the diverging control."""
    steps = 40 if quick else 120
    data = 2048 if quick else 4096
    rows = {}
    for inner, attack in (("trimmed_mean", "scale"),
                          ("trimmed_mean", "sign_flip")):
        r = byzantine_train.run_in_subprocess(
            inner, attack=attack, steps=steps, data_size=data)
        rows[f"{inner}/{attack}"] = r
        csv_rows.append((
            f"adversarial/jax/{inner}/{attack}/tail_loss",
            r["tail_loss"],
            f"head={r['head_loss']:.3f} acc={r['acc']:.3f} "
            f"steps={steps}"))
        # PR 1's converges-under-attack result, per attack model
        assert r["max_loss"] < 4.0, (inner, attack, r)
        assert r["tail_loss"] < r["head_loss"], (inner, attack, r)
    plain = byzantine_train.run_in_subprocess(
        "allreduce", attack="scale", steps=max(steps // 3, 10),
        data_size=data)
    rows["allreduce/scale"] = plain
    csv_rows.append(("adversarial/jax/allreduce/scale/final_loss",
                     plain["final_loss"], "diverging control"))
    robust_final = rows["trimmed_mean/scale"]["final_loss"]
    # a long enough control overflows clean through inf to nan — any
    # non-finite loss IS the divergence this row exists to show
    assert not np.isfinite(plain["final_loss"]) \
        or plain["final_loss"] > 10.0 * robust_final, (plain,
                                                       robust_final)
    return rows


# categorical line palette + chart styling, shared with the knee chart
# so the two benchmark figures stay one system
from benchmarks.pareto_sweep import (_INK, _INK2,  # noqa: E402
                                     _SERIES_COLORS, _SURFACE)


def _chart(sim: dict, path):
    """One panel per attack model, final distance (log) vs byzantine
    fraction, a line per aggregator; returns the path or None when
    matplotlib is unavailable."""
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None
    attacks = sorted({k.split("/", 1)[1] for k in sim["curves"]})
    aggs = list(dict.fromkeys(k.split("/", 1)[0]
                              for k in sim["curves"]))
    ncol = 3
    nrow = (len(attacks) + ncol - 1) // ncol
    fig, axes = plt.subplots(nrow, ncol, figsize=(4.1 * ncol,
                                                  3.2 * nrow),
                             dpi=144, sharex=True)
    fig.patch.set_facecolor(_SURFACE)
    axes = np.atleast_1d(axes).ravel()
    for ax in axes[len(attacks):]:
        ax.set_visible(False)
    for ax, attack in zip(axes, attacks):
        ax.set_facecolor(_SURFACE)
        for i, agg in enumerate(aggs):
            c = sim["curves"][f"{agg}/{attack}"]
            ax.plot(c["fractions"], np.maximum(c["final_dist"], 1e-3),
                    color=_SERIES_COLORS[i % len(_SERIES_COLORS)],
                    linewidth=2, label=agg, zorder=3)
        ax.set_yscale("log")
        ax.axhline(2 * sim["converge_tol"], color=_INK2, linewidth=0.8,
                   linestyle="--", zorder=2)
        ax.set_title(attack, color=_INK, loc="left", fontsize=10)
        ax.grid(True, color="#e7e6e3", linewidth=0.8, zorder=0)
        for s in ("top", "right"):
            ax.spines[s].set_visible(False)
        for s in ("left", "bottom"):
            ax.spines[s].set_color("#d7d6d2")
        ax.tick_params(colors=_INK2, labelsize=8)
    axes[0].set_ylabel("final |theta - theta*| (log)", color=_INK2,
                       fontsize=9)
    for ax in axes[max(len(attacks) - ncol, 0):len(attacks)]:
        ax.set_xlabel("byzantine fraction", color=_INK2, fontsize=9)
    axes[0].legend(frameon=False, fontsize=8, labelcolor=_INK)
    fig.suptitle("Byzantine-fraction degradation per aggregator "
                 "(dashed = robustness floor)", color=_INK, x=0.01,
                 ha="left", fontsize=11)
    fig.tight_layout(rect=(0, 0, 1, 0.95))
    fig.savefig(path, facecolor=_SURFACE)
    plt.close(fig)
    return path


def run(csv_rows, *, quick: bool = False, only=None, skip_jax=False,
        json_path: str = "BENCH_adversarial.json",
        chart_path: str = "adversarial_curves.png"):
    sections = SECTIONS if only is None else (only,)
    payload = {"benchmark": "adversarial_curves", "quick": quick}
    if "sim" in sections:
        payload["sim"] = bench_sim(csv_rows, quick)
        chart = _chart(payload["sim"], chart_path)
        if chart:
            csv_rows.append(("adversarial/sim/_chart", 1, chart))
            payload["chart"] = chart
    if "arch" in sections:
        payload["arch"] = bench_arch(csv_rows, quick)
    if "jax" in sections and not skip_jax:
        payload["jax"] = bench_jax(csv_rows, quick)
    # a --only / --skip-jax iteration must not overwrite the TRACKED
    # record with a partial payload (same guard as pareto_sweep's); an
    # explicit non-default --json path is always honoured
    partial = only is not None or skip_jax
    if json_path and (not partial or json_path
                      != "BENCH_adversarial.json"):
        with open(json_path, "w") as f:
            json.dump(_jsonable(payload), f, indent=2, allow_nan=False)
        csv_rows.append(("adversarial/_json", 1, json_path))
    return csv_rows


def _jsonable(obj):
    """Strict-JSON-safe copy: the diverging control's loss overflows to
    inf/NaN, which bare ``json.dump`` would emit as RFC-8259-invalid
    tokens — non-finite floats become null in the tracked record."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller fleet / fewer steps / short jax rows")
    ap.add_argument("--only", default=None, choices=SECTIONS)
    ap.add_argument("--skip-jax", action="store_true",
                    help="skip the real-training rows (fast local "
                         "iteration on the simulated surface)")
    ap.add_argument("--json", default="BENCH_adversarial.json")
    ap.add_argument("--chart", default="adversarial_curves.png")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, only=args.only, skip_jax=args.skip_jax,
        json_path=args.json, chart_path=args.chart)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")


if __name__ == "__main__":
    main()
