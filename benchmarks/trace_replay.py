"""Pareto fronts under measured fault tails vs synthetic Poisson rates.

The fault-rate charts so far draw faults from ``FaultPlan.random`` —
Poisson-thinned occurrences with uniform magnitudes.  Trace-driven
replay (``repro.serverless.traces``) swaps those synthetic draws for
the heavy cold-start/straggler tails measured by arXiv 2105.07806, and
this benchmark quantifies what that substitution does to every
cost-vs-makespan conclusion.  Three sections, recorded in
``BENCH_trace.json``:

  1. *Trace summary* — quantiles of the bundled Lambda-like trace, plus
     a bit-reproducibility check: two ``sweep_events(..., trace=...)``
     runs with equal seeds must agree exactly.
  2. *Tail inflation* — per architecture, one fixed-fleet config swept
     under the trace and under the Poisson defaults: p95/p50 makespan
     ratios side by side (the measured tail's signature is a much
     fatter p95).
  3. *Pareto fronts* — the elastic pricing sweep (RAM tiers x channel x
     autoscaler bounds) re-drawn under measured tails, with the Poisson
     fronts alongside; both arms share crash draws (same seeds, same
     crash sub-stream), so the delta isolates tail behaviour.

Rows: trace/<section>/<name>,value,notes
Usage:
    PYTHONPATH=src python -m benchmarks.trace_replay [--quick]
        [--json BENCH_trace.json] [--processes N]
    PYTHONPATH=src python -m benchmarks.run --only trace
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.pareto_sweep import elastic_pricing_points
from repro.serverless import lambda_default, list_archs
from repro.serverless.simulator import (paper_compute_anchor
                                        as _compute_anchor)
from repro.serverless.sweep import (EventSweepPoint, FaultRates,
                                    pareto_front, sweep_events)

N_PARAMS = int(4.2e6)            # MobileNet
CRASH_RATE = 0.1                 # shared by both arms (not trace-measured)

# Poisson baseline: the synthetic defaults the trace replaces — the
# straggler rate matches the trace's occurrence probability so the two
# arms differ in *tails*, not in how often faults happen
_TRACE = lambda_default()
POISSON = FaultRates(crash_rate=CRASH_RATE,
                     straggler_rate=_TRACE.straggler_prob,
                     storm_prob=0.3)
TRACED = FaultRates(crash_rate=CRASH_RATE)


def _stats_fingerprint(stats):
    return [(s.makespan_mean_s, s.makespan_p95_s, s.cost_mean,
             s.ttr_p95_s) for s in stats]


def bench_trace_summary(csv_rows, processes) -> dict:
    tr = _TRACE
    for field in ("cold_start_s", "straggler_slowdown",
                  "straggler_duration_s"):
        lo, hi = tr.support(field)
        p50, p95 = tr.quantile(field, 0.5), tr.quantile(field, 0.95)
        csv_rows.append((f"trace/summary/{field}_p50", p50,
                         f"support [{lo:g}; {hi:g}] p95={p95:g}"))
    csv_rows.append(("trace/summary/straggler_prob", tr.straggler_prob,
                     tr.name))
    point = [EventSweepPoint(arch="allreduce", n_params=N_PARAMS,
                             compute_s_per_batch=0.9)]
    a = sweep_events(point, rates=TRACED, trace=tr, n_replicates=4,
                     seed=13, processes=processes)
    b = sweep_events(point, rates=TRACED, trace=tr, n_replicates=4,
                     seed=13, processes=processes)
    reproducible = _stats_fingerprint(a) == _stats_fingerprint(b)
    assert reproducible, "trace replay must be bit-reproducible"
    csv_rows.append(("trace/summary/bit_reproducible", int(reproducible),
                     "two equal-seed trace sweeps agree exactly"))
    return dict(name=tr.name, straggler_prob=tr.straggler_prob,
                cold_start_p50_s=tr.quantile("cold_start_s", 0.5),
                cold_start_p95_s=tr.quantile("cold_start_s", 0.95),
                bit_reproducible=reproducible)


def bench_tail_inflation(csv_rows, quick: bool, processes) -> dict:
    """p95/p50 makespan per arch: measured tails vs Poisson defaults."""
    reps = 8 if quick else 16
    points = [EventSweepPoint(arch=arch, n_params=N_PARAMS,
                              compute_s_per_batch=_compute_anchor(arch),
                              label=arch)
              for arch in list_archs()]
    traced = sweep_events(points, rates=TRACED, trace=_TRACE,
                          n_replicates=reps, seed=42, processes=processes)
    poisson = sweep_events(points, rates=POISSON, n_replicates=reps,
                           seed=42, processes=processes)
    out = {}
    for t, p in zip(traced, poisson):
        arch = t.point.arch
        infl_t = t.makespan_p95_s / t.makespan_p50_s
        infl_p = p.makespan_p95_s / p.makespan_p50_s
        csv_rows.append((f"trace/tail/{arch}/p95_over_p50", infl_t,
                         f"poisson={infl_p:.3f} reps={reps}"))
        out[arch] = dict(
            traced=dict(p50=t.makespan_p50_s, p95=t.makespan_p95_s,
                        cost_mean=t.cost_mean,
                        cost_overhead_p95=t.cost_overhead_p95),
            poisson=dict(p50=p.makespan_p50_s, p95=p.makespan_p95_s,
                         cost_mean=p.cost_mean,
                         cost_overhead_p95=p.cost_overhead_p95))
    return out


def _pareto_points(quick: bool):
    """The pareto_sweep grid (shared builder), trimmed for the 2-arm
    sweep this benchmark runs."""
    rams = (1.0, 2.0) if quick else (1.0, 2.0, 3.0)
    scalers = ((0, 0), (1, 8)) if quick else ((0, 0), (1, 8), (2, 16))
    return elastic_pricing_points(rams, scalers)


def bench_pareto(csv_rows, quick: bool, processes) -> dict:
    points = _pareto_points(quick)
    reps = 3 if quick else 8
    t0 = time.perf_counter()
    traced = sweep_events(points, rates=TRACED, trace=_TRACE,
                          n_replicates=reps, seed=42, processes=processes)
    poisson = sweep_events(points, rates=POISSON, n_replicates=reps,
                           seed=42, processes=processes)
    elapsed = time.perf_counter() - t0
    csv_rows.append(("trace/pareto/points", len(points),
                     f"replicates={reps} x 2 arms"))
    csv_rows.append(("trace/pareto/sims_per_s",
                     2 * len(points) * reps / elapsed,
                     f"{2 * len(points) * reps} epochs in {elapsed:.2f}s"))

    fronts = {}
    for arch in list_archs():
        arms = {}
        for arm, stats in (("traced", traced), ("poisson", poisson)):
            rows = [s for s in stats if s.point.arch == arch]
            front = set(pareto_front(
                [s.cost_mean for s in rows],
                [s.makespan_mean_s for s in rows]).tolist())
            arms[arm] = [
                dict(label=s.point.label, ram_gb=s.point.setup.ram_gb,
                     channel=s.point.setup.channel.name,
                     autoscale_max=s.point.autoscale_max,
                     cost_mean=s.cost_mean,
                     makespan_mean_s=s.makespan_mean_s,
                     makespan_p95_s=s.makespan_p95_s,
                     cost_overhead_mean=s.cost_overhead_mean,
                     on_front=i in front)
                for i, s in enumerate(rows)]
        fronts[arch] = arms
        on_t = sorted((r["label"] for r in arms["traced"] if r["on_front"]))
        on_p = sorted((r["label"] for r in arms["poisson"]
                       if r["on_front"]))
        csv_rows.append((f"trace/pareto/{arch}/front_size", len(on_t),
                         f"poisson_front={len(on_p)}"))
        csv_rows.append((f"trace/pareto/{arch}/front_agreement",
                         len(set(on_t) & set(on_p))
                         / max(len(set(on_t) | set(on_p)), 1),
                         "Jaccard overlap of traced vs poisson fronts"))
    return dict(points=len(points), replicates=reps, elapsed_s=elapsed,
                fronts=fronts)


def run(csv_rows, *, quick: bool = False, processes=None,
        json_path: str = "BENCH_trace.json"):
    payload = {
        "benchmark": "trace_replay",
        "quick": quick,
        "trace": bench_trace_summary(csv_rows, processes),
        "tail_inflation": bench_tail_inflation(csv_rows, quick, processes),
        "pareto": bench_pareto(csv_rows, quick, processes),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        csv_rows.append(("trace/_json", 1, json_path))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller grid / fewer replicates (CI)")
    ap.add_argument("--json", default="BENCH_trace.json")
    ap.add_argument("--processes", type=int, default=None,
                    help="0/1 inline; default cpu count (<=8)")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, processes=args.processes,
        json_path=args.json)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")


if __name__ == "__main__":
    main()
