"""Paper Table 3 / Fig. 4: convergence & accuracy per strategy.

REAL training (no simulation): the reduced MobileNet on the synthetic
CIFAR-like set, trained with each of the five sync strategies under the
same global batch, recording accuracy-vs-step curves and the simulated
wall-clock each strategy would take per the serverless timing model —
reproducing Fig. 4's time axis (log scale in the paper) and Table 3's
ordering:

  GPU fastest; SPIRT best serverless trade-off; MLLess slower-but-equal
  accuracy; Scatter/AllReduce slowest wall-clock (per-minibatch sync).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy, losses
from repro.data import cifar_like
from repro.models import build_cnn
from repro.serverless import ARCHS, get_arch, simulate_epoch

# each ArchSpec names its real-training strategy (gpu = ring allreduce,
# spirt = K-step accumulation, allreduce = the λML master as a
# parameter server, ...) — the sim arch and the trained arch are one
# registry object
STRATS = {name: (get_arch(name).jax_strategy,
                 dict(get_arch(name).jax_strategy_kwargs))
          for name in ARCHS}


def run(csv_rows, steps=50, batch=96):
    imgs, labels = cifar_like(4096, seed=0)
    test_imgs, test_labels = cifar_like(512, seed=99)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("mobilenet-cifar").reduced()

    def loss_fn(params, b):
        logits, _ = model.apply(params, b)
        return losses.classification_loss(logits, b["labels"])

    results = {}
    for name, (sname, kw) in STRATS.items():
        model = build_cnn(cfg)
        ts = build_train_step(model, optim.sgd(0.05, momentum=0.9),
                              get_strategy(sname, **kw), mesh,
                              loss_fn=loss_fn)
        state = ts.init_state(jax.random.PRNGKey(0))
        rs = np.random.RandomState(0)
        acc_curve = []
        for step in range(steps):
            idx = rs.randint(0, len(imgs), batch)
            b = {"images": jnp.asarray(imgs[idx]),
                 "labels": jnp.asarray(labels[idx])}
            state, metrics = ts.step_fn(state, b)
            if (step + 1) % 25 == 0:
                logits, _ = jax.jit(model.apply)(
                    state["params"], {"images": jnp.asarray(test_imgs)})
                acc = float(losses.accuracy(logits,
                                            jnp.asarray(test_labels)))
                acc_curve.append(acc)
        # simulated wall-clock per epoch for this strategy; GPU compute
        # per batch is ~4x faster than a Lambda vCPU (paper: 92s/24
        # batches vs 14-15s per serverless batch)
        rep = simulate_epoch(name, n_params=int(4.2e6),
                             compute_s_per_batch=0.25 if name == "gpu"
                             else 1.0)
        results[name] = (acc_curve[-1], rep.per_worker_s)
        csv_rows.append((f"table3/{name}/final_acc", acc_curve[-1],
                         f"curve={['%.3f' % a for a in acc_curve]}"))
        csv_rows.append((f"table3/{name}/sim_epoch_s", rep.per_worker_s,
                         "serverless timing model"))

    # Table 3 orderings the paper reports (time axis):
    assert results["gpu"][1] <= min(r[1] for r in results.values()) + 1e-9
    assert results["spirt"][1] < results["allreduce"][1]
    # all strategies learn (well above 10-class chance)
    for name, (acc, _) in results.items():
        assert acc > 0.25, (name, acc)
    return csv_rows
