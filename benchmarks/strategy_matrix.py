"""Strategy × sharding-profile matrix (EXPERIMENTS.md §Perf pair 3),
read from the dry-run artifacts — the paper's framework comparison
expressed as TPU collective schedules.

  PYTHONPATH=src python -m benchmarks.strategy_matrix
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run(csv_rows):
    patterns = {
        "baseline_allreduce": "phi3-mini-3.8b__train_4k__16x16",
        "baseline_scatterreduce":
            "phi3-mini-3.8b__train_4k__16x16__strat_scatterreduce",
        "dp_allreduce":
            "phi3-mini-3.8b__train_4k__16x16__dp_strat_allreduce",
        "dp_parameter_server":
            "phi3-mini-3.8b__train_4k__16x16__dp_strat_parameter_server",
        "dp_spirt": "phi3-mini-3.8b__train_4k__16x16__dp_strat_spirt",
        "dp_mlless": "phi3-mini-3.8b__train_4k__16x16__dp_strat_mlless",
        "dp_quantized":
            "phi3-mini-3.8b__train_4k__16x16__dp_strat_"
            "quantized_scatterreduce",
        "zero3": "phi3-mini-3.8b__train_4k__16x16__zero3",
    }
    found = 0
    for label, stem in patterns.items():
        f = RESULTS / f"{stem}.json"
        if not f.exists():
            csv_rows.append((f"strategy_matrix/{label}", -1, "missing — "
                             "run scripts/dryrun_all.sh"))
            continue
        d = json.loads(f.read_text())
        rf = d["roofline"]
        csv_rows.append((
            f"strategy_matrix/{label}",
            rf["step_time_lower_bound_s"],
            f"coll={rf['collective_s']:.3f}s wireGB="
            f"{d['collectives']['wire_bytes_per_device'] / 2**30:.1f}"))
        found += 1
    if found >= 4:
        get = {r[0].split("/")[-1]: r[1] for r in csv_rows
               if r[0].startswith("strategy_matrix/") and r[1] > 0}
        # the paper's §4.2 master bottleneck must be visible under dp
        if "dp_parameter_server" in get and "dp_allreduce" in get:
            assert get["dp_parameter_server"] > 10 * get["dp_allreduce"]
    return csv_rows
