"""Real recovery replay: checkpoint-restore vs peer-takeover, measured.

The event runtime prices the two recovery policies analytically
(``RuntimeReport.time_to_recover_s``); the resilience harness
(``repro.resilience``) pays them for real — a sharded transformer
config trained data-parallel on host devices, a worker killed mid-step,
and the run recovered through the same policy objects.  This benchmark
replays a grid of (config x kill-step) chaos scenarios, one subprocess
per scenario (baseline + restore + takeover share the process and its
XLA compile cache), and records in ``BENCH_recovery.json``:

  1. *Scenario rows* — per (config x policy x kill-step): lost/replayed
     steps, recovery wall seconds, bytes moved (full checkpoint vs the
     dead peer's in-DB partition) and final loss.
  2. *Bit-exactness* — the killed-then-restored run's loss trace must
     equal the uninterrupted same-seed baseline exactly (and the replay
     itself must reproduce its pre-kill losses bit-for-bit).
  3. *Simulator validation* — the event runtime's TTR prediction for
     the same scenario (measured step time + real state bytes fed in):
     the sign of (restore wall - takeover wall) must agree with the
     sign of (TTR_restore - TTR_takeover), asserted per scenario.

Running ``python -m benchmarks.run --only recovery`` executes just this
suite — each suite writes only its own ``BENCH_*.json``, so a partial
run never clobbers the other tracked benchmark files.

Rows: recovery/<arch>/k<step>/<name>,value,notes
Usage:
    PYTHONPATH=src python -m benchmarks.recovery_replay [--quick]
        [--json BENCH_recovery.json]
    PYTHONPATH=src python -m benchmarks.run --only recovery
"""
from __future__ import annotations

import argparse
import json

#: (arch, sim_arch, kill steps) — smollm is the primary chaos target,
#: qwen1.5-4b (reduced) confirms the harness generalizes across
#: transformer configs; kill steps probe early/mid/late checkpoints
SCENARIOS = (
    ("smollm-135m", "spirt", (3, 6, 9)),
    ("qwen1.5-4b", "spirt", (6,)),
)
QUICK_SCENARIOS = (("smollm-135m", "spirt", (6,)),)

STEPS = 12
N_WORKERS = 4
CHECKPOINT_EVERY = 4
KILL_WORKER = 1


def _sim_ttr(sim_arch: str, *, n_params: int, step_s: float,
             state_bytes: int, kill_step: int, recovery: str) -> float:
    """Event-runtime TTR for the matching scenario: measured per-round
    compute and real serialized state bytes go in; the crash lands at
    the same epoch fraction as the real kill step."""
    from repro.serverless.faults import FaultPlan, WorkerCrash
    from repro.serverless.runtime import run_event_epoch
    from repro.serverless.simulator import ServerlessSetup

    setup = ServerlessSetup(n_workers=N_WORKERS,
                            batches_per_worker=STEPS,
                            model_bytes=float(state_bytes))
    kw = dict(n_params=n_params, compute_s_per_batch=step_s,
              setup=setup)
    base = run_event_epoch(sim_arch, faults=FaultPlan(),
                           recovery=recovery, **kw)
    crash_t = base.makespan_s * kill_step / STEPS
    rep = run_event_epoch(
        sim_arch,
        faults=FaultPlan(crashes=(WorkerCrash(KILL_WORKER, crash_t),)),
        recovery=recovery, **kw)
    return rep.time_to_recover_s


def bench_scenario(csv_rows, arch: str, sim_arch: str,
                   kill_step: int) -> dict:
    """One chaos scenario end to end: real runs + simulator twin."""
    from repro.launch.resilient_train import run_in_subprocess

    payload = run_in_subprocess(
        arch=arch, sim_arch=sim_arch, steps=STEPS,
        kill_step=kill_step, kill_worker=KILL_WORKER,
        n_workers=N_WORKERS, checkpoint_every=CHECKPOINT_EVERY)
    runs = payload["runs"]
    base, rest, take = (runs["baseline"], runs["restore"],
                        runs["takeover"])
    tag = f"recovery/{arch}/k{kill_step}"

    # --- bit-exactness (acceptance criterion: restore replays the
    # uninterrupted trace exactly)
    bitexact = rest["bitexact_vs_baseline"] and rest["replay_exact"]
    assert bitexact, (
        f"{arch} k{kill_step}: killed-then-restored run must replay "
        f"the baseline loss trace bit-exactly")
    csv_rows.append((f"{tag}/bitexact", int(bitexact),
                     "restore trace == uninterrupted baseline"))

    out = {"arch": arch, "sim_arch": sim_arch, "kill_step": kill_step,
           "n_params": base["n_params"],
           "state_bytes": base["state_bytes"],
           "step_s": base["step_s"], "bitexact": bitexact,
           "policies": {}}
    for mode, row in (("restore", rest), ("takeover", take)):
        rec = row["recoveries"][0]
        lost = kill_step - (rec["ckpt_step"] if mode == "restore"
                            else kill_step)
        sim = _sim_ttr(sim_arch, n_params=base["n_params"],
                       step_s=base["step_s"],
                       state_bytes=base["state_bytes"],
                       kill_step=kill_step, recovery=mode)
        csv_rows.append((f"{tag}/{mode}/wall_s", rec["wall_s"],
                         f"sim_ttr={sim:.3f}s "
                         f"replayed={rec['replayed_steps']}"))
        csv_rows.append((f"{tag}/{mode}/bytes_moved",
                         rec["bytes_moved"],
                         "full ckpt" if mode == "restore"
                         else "dead peer's in-DB partition"))
        out["policies"][mode] = {
            "lost_steps": lost,
            "replayed_steps": rec["replayed_steps"],
            "recovery_wall_s": rec["wall_s"],
            "bytes_moved": rec["bytes_moved"],
            "final_loss": row["final_loss"],
            "n_workers_after": rec["n_workers_after"],
            "sim_ttr_s": sim,
        }

    # --- simulator validation: real and simulated policy orderings
    # must agree in sign (acceptance criterion)
    real_d = (out["policies"]["restore"]["recovery_wall_s"]
              - out["policies"]["takeover"]["recovery_wall_s"])
    sim_d = (out["policies"]["restore"]["sim_ttr_s"]
             - out["policies"]["takeover"]["sim_ttr_s"])
    consistent = (real_d > 0) == (sim_d > 0)
    assert consistent, (
        f"{arch} k{kill_step}: real restore-takeover wall delta "
        f"({real_d:+.3f}s) disagrees in sign with the event runtime's "
        f"TTR delta ({sim_d:+.3f}s)")
    csv_rows.append((f"{tag}/sim_sign_consistent", int(consistent),
                     f"real_delta={real_d:+.3f}s sim_delta={sim_d:+.3f}s"))
    out["real_delta_s"] = real_d
    out["sim_delta_s"] = sim_d
    out["takeover_loss_gap"] = take["final_loss_gap"]
    return out


def run(csv_rows, *, quick: bool = False,
        json_path: str = "BENCH_recovery.json"):
    scenarios = QUICK_SCENARIOS if quick else SCENARIOS
    results = []
    for arch, sim_arch, kill_steps in scenarios:
        for k in kill_steps:
            results.append(bench_scenario(csv_rows, arch, sim_arch, k))
    payload = {
        "benchmark": "recovery_replay",
        "quick": quick,
        "steps": STEPS,
        "n_workers": N_WORKERS,
        "checkpoint_every": CHECKPOINT_EVERY,
        "scenarios": results,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        csv_rows.append(("recovery/_json", 1, json_path))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="single scenario (CI)")
    ap.add_argument("--json", default="BENCH_recovery.json")
    args = ap.parse_args()
    rows = []
    run(rows, quick=args.quick, json_path=args.json)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")


if __name__ == "__main__":
    main()
