"""Fault-tolerance comparison (the paper's headline robustness claims).

For every architecture in the matrix x every fault class the
discrete-event runtime measures

  time-to-recover  how long the fleet is impaired (crash: stall until
                   the gradient stream is whole again; straggler/storm:
                   makespan inflation over the fault-free baseline)
  cost overhead    billed-dollar inflation over the fault-free epoch
                   (Lambda GB-seconds keep accruing while workers stall
                   at the barrier — stalls are never free)

plus the paper's qualitative orderings as assertions: SPIRT's
in-database peer takeover recovers faster than checkpoint-restore
re-invocation, and robust aggregation masks byzantine updates that
plain averaging applies.

The byzantine row is then grounded in *real* JAX training: MobileNet on
the synthetic CIFAR set, 4-way data-parallel, worker 0 shipping
gradients scaled by -8, SPIRT-style accumulation + trimmed-mean
aggregation (subprocess: needs its own XLA_FLAGS device count).  The
run must converge; the same run under plain allreduce must not.

Rows: fault/<arch>/<fault>/<metric>,value,notes
Usage: PYTHONPATH=src python -m benchmarks.run --only fault_tolerance
"""
from __future__ import annotations

from repro.launch import byzantine_train
from repro.serverless import (ColdStartStorm, FaultPlan,
                              ReactiveAutoscaler, ServerlessSetup,
                              Straggler, WorkerCrash, ByzantineWorker,
                              default_recovery, run_event_epoch,
                              simulate_epoch)
from repro.serverless.simulator import (ARCHS,
                                        paper_compute_anchor
                                        as _compute_anchor)

N_PARAMS = int(4.2e6)            # MobileNet


def _epoch(arch, **kw):
    return run_event_epoch(arch, n_params=N_PARAMS,
                           compute_s_per_batch=_compute_anchor(arch),
                           setup=ServerlessSetup(), **kw)


def run(csv_rows):
    ttr_crash = {}
    for arch in ARCHS:
        base = _epoch(arch)
        ana = simulate_epoch(arch, n_params=N_PARAMS,
                             compute_s_per_batch=_compute_anchor(arch),
                             setup=ServerlessSetup())
        # fault-free event run must agree with the analytic fast path
        rel = abs(base.makespan_s - ana.per_worker_s) / ana.per_worker_s
        csv_rows.append((f"fault/{arch}/none/makespan_s", base.makespan_s,
                         f"analytic={ana.per_worker_s:.2f} rel={rel:.1e}"))
        assert rel < 1e-6, (arch, base.makespan_s, ana.per_worker_s)

        faults = {
            "crash": FaultPlan(crashes=(
                WorkerCrash(1, 0.4 * base.makespan_s),)),
            "straggler": FaultPlan(stragglers=(
                Straggler(2, slowdown=4.0),)),
            "byzantine": FaultPlan(byzantine=(ByzantineWorker(0),)),
            "coldstart_storm": FaultPlan(
                storm=ColdStartStorm(extra_s=8.0, fraction=0.5), seed=7),
        }
        for fname, plan in faults.items():
            # each spec names its own recovery design: in-DB archs
            # (SPIRT family) take over from peers, everyone else must
            # re-invoke and replay from a checkpoint
            recovery = default_recovery(arch, checkpoint_every=4)
            rep = _epoch(arch, faults=plan, recovery=recovery,
                         robust_trim=1 if fname == "byzantine" else 0)
            ttr = (rep.time_to_recover_s if fname == "crash"
                   else max(rep.makespan_s - base.makespan_s, 0.0))
            overhead = rep.total_cost / base.total_cost - 1.0
            csv_rows.append((f"fault/{arch}/{fname}/ttr_s", ttr,
                             f"makespan={rep.makespan_s:.2f} "
                             f"recovery={recovery.__class__.__name__}"))
            csv_rows.append((f"fault/{arch}/{fname}/cost_overhead",
                             overhead,
                             f"cost={rep.total_cost:.5f} "
                             f"base={base.total_cost:.5f}"))
            if fname == "crash":
                ttr_crash[arch] = ttr
            if fname == "byzantine":
                csv_rows.append((
                    f"fault/{arch}/byzantine/masked_updates",
                    rep.masked_updates,
                    f"poisoned={rep.poisoned_updates} robust_trim=1"))
                assert rep.masked_updates > 0 and rep.poisoned_updates == 0

        # elasticity: the straggler epoch again, with a reactive scaler
        el = _epoch(arch, faults=faults["straggler"],
                    autoscaler=ReactiveAutoscaler(max_workers=8))
        strag = next(v for n, v, _ in csv_rows
                     if n == f"fault/{arch}/straggler/ttr_s")
        csv_rows.append((f"fault/{arch}/straggler/autoscaled_makespan_s",
                         el.makespan_s,
                         f"peak_workers={el.n_workers_peak} "
                         f"unscaled={base.makespan_s + strag:.2f}"))

    # the paper's fault-tolerance ordering: SPIRT's takeover beats every
    # checkpoint-restore architecture on recovery time
    for arch in ("mlless", "scatterreduce", "allreduce", "gpu"):
        assert ttr_crash["spirt"] < ttr_crash[arch], ttr_crash

    # ---- real-training byzantine robustness (MobileNet / CIFAR-like) ----
    # SPIRT accumulation + trimmed-mean aggregation, worker 0 byzantine
    # for the WHOLE run, vs plain averaging under the same attack (which
    # blows up within a few steps — short run suffices)
    robust = byzantine_train.run_in_subprocess("trimmed_mean", steps=150)
    plain = byzantine_train.run_in_subprocess("allreduce", steps=30)
    csv_rows.append(("fault/byzantine_training/trimmed_mean_acc",
                     robust["acc"],
                     f"final_loss={robust['final_loss']:.3f} steps=150 "
                     f"byz_workers=1"))
    csv_rows.append(("fault/byzantine_training/plain_allreduce_acc",
                     plain["acc"],
                     f"final_loss={plain['final_loss']:.3g} steps=30 "
                     f"byz_workers=1"))
    assert robust["acc"] > 0.3, robust            # converges under attack
    assert robust["acc"] > plain["acc"], (robust, plain)
    return csv_rows


if __name__ == "__main__":
    rows = []
    run(rows)
    print("name,value,derived")
    for name, value, notes in rows:
        print(f"{name},{value},{str(notes).replace(',', ';')}")
