"""Divisibility-aware automatic sharding for parameter/cache pytrees.

Rules (DESIGN.md §4):
  * 'model' goes on the widest eligible dim of each leaf (tensor
    parallelism); stacked-block leading dims (the ``lax.scan`` axis) are
    never sharded.
  * with ``fsdp=True``, block/tail leaves additionally shard their widest
    remaining dim over the data axes (ZeRO-3); the train step all-gathers
    per block inside the scan and autodiff transposes that into a
    reduce-scatter of the gradients.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def leaf_pspec(shape: Sequence[int], mesh, *, model_axis="model",
               data_axes=None, skip_leading=False, fsdp=False) -> P:
    """Assign mesh axes to tensor dims by divisibility, widest-first.
    ``model_axis=None`` disables tensor parallelism (pure-DP profile)."""
    ndim = len(shape)
    assign: list = [None] * ndim
    start = 1 if (skip_leading and ndim > 1) else 0
    order = sorted(range(start, ndim), key=lambda i: -shape[i])
    if model_axis is not None:
        msize = _axis_size(mesh, model_axis)
        for i in order:
            if shape[i] % msize == 0 and shape[i] >= msize:
                assign[i] = model_axis
                break
    if fsdp and data_axes is not None:
        dsize = _axis_size(mesh, data_axes)
        for i in order:
            if assign[i] is None and shape[i] % dsize == 0 \
                    and shape[i] >= dsize:
                assign[i] = data_axes
                break
    return P(*assign)


def param_pspecs(params, mesh, *, fsdp=False, data_axes=("data",),
                 model_axis="model"):
    """PartitionSpec pytree for a Model params tree."""
    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        in_blocks = any(k in ("blocks", "tail", "encoder") for k in keys)
        return leaf_pspec(
            leaf.shape, mesh, model_axis=model_axis,
            data_axes=data_axes if in_blocks else None,
            skip_leading=in_blocks, fsdp=fsdp and in_blocks)
    return jax.tree_util.tree_map_with_path(one, params)


def cache_pspecs(cache, mesh, *, batch_axes=("data",), model_axis="model",
                 shard_seq=False):
    """KV caches: batch over data axes when divisible; for batch=1
    (long_500k) optionally shard the sequence dim instead (context
    parallelism for decode)."""
    bsize = _axis_size(mesh, batch_axes)

    def one(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        stacked = any(k in ("blocks", "tail") for k in keys) or \
            "enc_kv" in keys
        shape = leaf.shape
        bdim = 1 if stacked else 0
        assign: list = [None] * len(shape)
        if shape[bdim] % bsize == 0 and shape[bdim] >= bsize:
            assign[bdim] = batch_axes
        elif shard_seq and len(shape) > bdim + 1:
            # ring-buffer/seq dim
            sdim = bdim + 1
            if shape[sdim] % bsize == 0 and shape[sdim] >= bsize:
                assign[sdim] = batch_axes
        # model axis on a head/width dim if divisible; prefer the KV-heads
        # dim (-2) so int8 payloads and their (.., KV, 1) scale tensors
        # shard identically (no resharding between them at dequant)
        if model_axis is not None:
            msize = _axis_size(mesh, model_axis)
            ndim = len(shape)
            prefer = [ndim - 2, ndim - 1] + list(range(ndim - 3, bdim, -1))
            for i in prefer:
                if i <= bdim or i >= ndim:
                    continue
                if assign[i] is None and shape[i] % msize == 0 \
                        and shape[i] >= msize:
                    assign[i] = model_axis
                    break
        return P(*assign)
    return jax.tree_util.tree_map_with_path(one, cache)


def survivor_mesh(mesh, dead: int, *, data_axis: str = "data"):
    """Mesh with the ``dead`` data-parallel slice removed.

    The surviving devices keep their original order (so the collective
    reduction order over survivors is stable) and every other mesh axis
    is untouched.  Used by the resilience harness
    (``repro.resilience``) to re-mesh the fleet after a mid-step worker
    loss; ``param_pspecs`` evaluated on the survivor mesh degrades any
    dim that is no longer divisible to replication, so restoring a
    checkpoint — or adopting a dead peer's in-DB partition — onto the
    smaller mesh is always well-defined.
    """
    if data_axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {data_axis!r}; axes are "
                         f"{tuple(mesh.axis_names)}")
    axis = list(mesh.axis_names).index(data_axis)
    devs = np.asarray(mesh.devices)
    n = devs.shape[axis]
    if not 0 <= dead < n:
        raise ValueError(
            f"dead worker {dead} out of range for {data_axis}={n}")
    if n < 2:
        raise ValueError(
            f"cannot remove the last {data_axis!r} shard (size {n}); "
            "a one-worker fleet has no survivors to re-mesh")
    keep = np.delete(devs, dead, axis=axis)
    return jax.sharding.Mesh(keep, mesh.axis_names)


def shardings(tree_pspecs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def make_gather_hook(pspecs_blocks, data_axes):
    """Per-block FSDP all-gather hook for Model block params.

    ``pspecs_blocks``: pspec pytree for ONE block's params (leading stack
    dim removed).  Returns fn(block_params) -> gathered block params.
    """
    def hook(block_params, block_pspecs):
        def one(g, spec):
            for dim, ax in enumerate(spec):
                if ax == data_axes or (isinstance(ax, tuple)
                                       and set(ax) == set(data_axes)):
                    return jax.lax.all_gather(g, axis_name=data_axes,
                                              axis=dim, tiled=True)
            return g
        return jax.tree.map(one, block_params, block_pspecs,
                            is_leaf=lambda x: isinstance(x, P))
    return hook
