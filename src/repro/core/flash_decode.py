"""Context-parallel decode attention (flash-decoding) — beyond paper.

For ``long_500k`` (batch 1) the KV cache is sharded over the data axes
on its *sequence* dimension.  The baseline path lets GSPMD handle the
softmax over the sharded axis (it all-gathers the cache); this module
computes the numerically-exact distributed softmax instead:

    per shard:   local scores  -> local max m_i, sum l_i, weighted acc_i
    combine:     m = pmax(m_i);  l = psum(l_i * exp(m_i - m))
                 out = psum(acc_i * exp(m_i - m)) / l

Wire bytes: O(B * H * hd) per step instead of O(L * KV * hd) — for a
524k cache over 16 shards that is ~5 orders of magnitude less traffic.

Use inside a ``jax.shard_map`` whose manual axes include ``axis_name``;
slot positions are reconstructed from ``jax.lax.axis_index``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_attention(q, k_shard, v_shard, pos, *, axis_name,
                           total_len, window=None):
    """q: (B, 1, H, hd) replicated; k/v_shard: (B, L_loc, KV, hd) — the
    local slice of a ring buffer of global length ``total_len`` laid out
    contiguously over ``axis_name``.  Returns (B, 1, H, hd) replicated.
    """
    B, L_loc, KV, hd = k_shard.shape
    H = q.shape[2]
    G = H // KV
    shard = jax.lax.axis_index(axis_name)
    base = shard * L_loc

    pos = jnp.asarray(pos)
    pos_b = jnp.broadcast_to(pos, (B,))
    slots = base + jnp.arange(L_loc)                     # global slot ids
    L = total_len
    slot_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - slots[None, :], L)
    valid = slot_pos >= 0
    if window is not None:
        valid = valid & (slot_pos > pos_b[:, None] - window)

    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,blkh->bgkl", qg, k_shard,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m_loc = jnp.max(s, axis=-1)                          # (B,G,KV)
    p = jnp.exp(s - m_loc[..., None])
    l_loc = jnp.sum(p, axis=-1)
    acc_loc = jnp.einsum("bgkl,blkh->bkgh", p.astype(v_shard.dtype),
                         v_shard, preferred_element_type=jnp.float32)

    m = jax.lax.pmax(m_loc, axis_name)
    corr = jnp.exp(m_loc - m)
    l = jax.lax.psum(l_loc * corr, axis_name)
    acc = jax.lax.psum(acc_loc * corr[..., None].transpose(0, 2, 1, 3),
                       axis_name)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.reshape(B, 1, H, hd).astype(q.dtype)
