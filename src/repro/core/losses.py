"""Losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_cross_entropy(logits, labels):
    """logits: (B, S, V) any float dtype; labels: (B, S) int32.

    Computed in fp32; mean over all tokens.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def classification_loss(logits, labels):
    """logits: (B, C); labels: (B,) int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(
        jnp.float32))
