"""Serving-step builders: prefill and single-token decode under pjit.

Serving has no gradient sync, so steps run in pure auto (GSPMD) mode
with explicit input/output shardings.  For ``long_500k`` (batch 1) the
KV cache is sharded over the data axes on its *sequence* dim (context
parallelism for decode); the optimized flash-decode path with an
explicit log-sum-exp combine lives in ``repro.core.flash_decode``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sharding


@dataclasses.dataclass
class ServeStep:
    prefill_fn: Callable
    decode_fn: Callable
    param_shardings: Any
    make_inputs: Callable


def build_serve_step(model, mesh, *, data_axes=("data",),
                     model_axis="model", batch_size: int,
                     cache_len: int, swa_variant: bool = False):
    cfg = model.cfg
    model.param_hook = None
    example_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sharding.param_pspecs(example_params, mesh, fsdp=False,
                                   data_axes=data_axes,
                                   model_axis=model_axis)
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    dp = data_axes if len(data_axes) > 1 else data_axes[0]
    W = int(np.prod([mesh.shape[a] for a in data_axes]))
    batch_shardable = batch_size % W == 0
    bspec = P(dp) if batch_shardable else P()

    example_cache = jax.eval_shape(
        lambda: model.init_cache(batch_size, cache_len,
                                 swa_variant=swa_variant))
    # kvquant caches nest {"q","scale"} one level deeper; the path-based
    # pspec assignment handles both layouts
    cache_specs = sharding.cache_pspecs(
        example_cache, mesh, batch_axes=dp, model_axis=model_axis,
        shard_seq=not batch_shardable)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs,
                            is_leaf=lambda x: isinstance(x, P))

    prefill = jax.jit(
        functools.partial(model.prefill, cache_len=cache_len,
                          swa_variant=swa_variant),
        out_shardings=(NamedSharding(mesh, P(bspec[0] if batch_shardable
                                             else None, None, model_axis)),
                       cache_sh))

    def _decode(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos,
                                 swa_variant=swa_variant)

    decode = jax.jit(
        _decode,
        out_shardings=(
            NamedSharding(mesh, P(bspec[0] if batch_shardable else None,
                                  None, model_axis)),
            cache_sh),
        donate_argnums=(2,))

    def make_inputs(shape_kind: str, seq_len: int):
        """ShapeDtypeStructs for dry-run lowering (no allocation)."""
        B = batch_size
        tok_sh = NamedSharding(mesh, bspec)
        extras = {}
        if cfg.family == "vlm":
            extras["patch_emb"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype),
                sharding=NamedSharding(mesh, P(bspec[0] if batch_shardable
                                               else None, None, None)))
        if cfg.is_encoder_decoder:
            extras["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype),
                sharding=NamedSharding(mesh, P(bspec[0] if batch_shardable
                                               else None, None, None)))
        if shape_kind == "prefill":
            batch = {"tokens": jax.ShapeDtypeStruct((B, seq_len), jnp.int32,
                                                    sharding=tok_sh)}
            batch.update(extras)
            return batch
        token = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh)
        cache_sds = jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                               sharding=sh),
            example_cache, cache_sh)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return token, cache_sds, pos

    return ServeStep(prefill_fn=prefill, decode_fn=decode,
                     param_shardings=param_sh, make_inputs=make_inputs)
