"""Gradient-synchronization strategies — the paper's core contribution as
a composable JAX module.

Each of the paper's five architectures becomes a ``Strategy`` whose
``sync`` runs inside a ``jax.shard_map`` manual region over the
data-parallel mesh axes and emits that architecture's collective
schedule (DESIGN.md §5 maps serverless mechanism -> TPU collective):

  allreduce        ring all-reduce (`psum`)           [GPU baseline / ideal]
  parameter_server all-gather-to-all + local reduce   [λML AllReduce master]
  scatterreduce    psum_scatter + all_gather (tiled)  [λML ScatterReduce]
  spirt            K-step on-device grad accumulation + psum
                   (in-database accumulation -> HBM-resident accumulator)
  mlless           block-significance filtering w/ error feedback + psum
                   (significant-update filtering; effective-bytes model)

``comm_bytes`` gives the per-step logical communication volume used by
the serverless simulator and the cost model (Fig. 2/3 reproduction).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import axis_size as _axis_size


def _leaf_bytes(tree) -> int:
    return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


@dataclasses.dataclass(frozen=True)
class Strategy:
    """Base: subclasses override ``sync`` (and optionally state hooks)."""
    name: str = "base"
    microbatches: int = 1          # >1 => train_step accumulates (SPIRT)

    def init_state(self, grads_like) -> Any:
        return ()

    def sync(self, grads, state, axis_names) -> Tuple[Any, Any, Dict]:
        raise NotImplementedError

    def comm_bytes(self, grads_like, n_workers: int) -> int:
        """Logical bytes moved per sync per worker (serverless channel)."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# AllReduce (ring) — the idealized / GPU-baseline schedule
# ---------------------------------------------------------------------------
def _pmean32(g, axis_names):
    """fp32 ring all-reduce (fp32 grad reduction is standard practice;
    also works around an XLA:CPU AllReducePromotion crash on bf16 —
    DESIGN.md §6)."""
    return jax.lax.pmean(g.astype(jnp.float32),
                         axis_name=axis_names).astype(g.dtype)


@dataclasses.dataclass(frozen=True)
class AllReduce(Strategy):
    name: str = "allreduce"

    def sync(self, grads, state, axis_names):
        out = jax.tree.map(lambda g: _pmean32(g, axis_names), grads)
        return out, state, {}

    def comm_bytes(self, grads_like, n_workers):
        # ring: 2 * G * (W-1)/W  per worker
        G = _leaf_bytes(grads_like)
        return int(2 * G * (n_workers - 1) / n_workers)


# ---------------------------------------------------------------------------
# ParameterServer — the paper's λML "AllReduce" (master aggregates)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParameterServer(Strategy):
    """Master-worker aggregation.  On SPMD hardware every worker receives
    every other worker's full gradient (all_gather) and reduces locally —
    the W× byte blowup IS the master bottleneck the paper measures."""
    name: str = "parameter_server"

    def sync(self, grads, state, axis_names):
        def one(g):
            stacked = jax.lax.all_gather(g, axis_name=axis_names, axis=0,
                                         tiled=False)
            return jnp.mean(stacked.astype(jnp.float32),
                            axis=0).astype(g.dtype)
        return jax.tree.map(one, grads), state, {}

    def comm_bytes(self, grads_like, n_workers):
        # every worker uploads G and downloads (W-1) gradients
        G = _leaf_bytes(grads_like)
        return int(G * n_workers)


# ---------------------------------------------------------------------------
# ScatterReduce — chunked ownership (λML ScatterReduce)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScatterReduce(Strategy):
    name: str = "scatterreduce"

    def sync(self, grads, state, axis_names):
        axes = (axis_names,) if isinstance(axis_names, str) else axis_names
        W = np.prod([_axis_size(a) for a in axes])

        def one(g):
            flat = g.reshape(-1).astype(jnp.float32)
            pad = (-flat.shape[0]) % W
            flat = jnp.pad(flat, (0, pad))
            chunk = jax.lax.psum_scatter(flat, axis_name=axis_names,
                                         scatter_dimension=0, tiled=True)
            full = jax.lax.all_gather(chunk, axis_name=axis_names, axis=0,
                                      tiled=True)
            out = full[:flat.shape[0] - pad] if pad else full
            return (out / W).reshape(g.shape).astype(g.dtype)
        return jax.tree.map(one, grads), state, {}

    def comm_bytes(self, grads_like, n_workers):
        # each worker sends (W-1)/W chunks twice (reduce phase + gather)
        G = _leaf_bytes(grads_like)
        return int(2 * G * (n_workers - 1) / n_workers)


# ---------------------------------------------------------------------------
# SPIRT — P2P with in-database (on-device) gradient accumulation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Spirt(Strategy):
    """K-microbatch accumulation handled by the train-step builder (the
    accumulator lives in HBM next to compute — the in-database analogue);
    the cross-worker sync is a single psum per K microbatches."""
    name: str = "spirt"
    microbatches: int = 4

    def sync(self, grads, state, axis_names):
        out = jax.tree.map(lambda g: _pmean32(g, axis_names), grads)
        return out, state, {}

    def comm_bytes(self, grads_like, n_workers):
        # same ring volume, amortized over K local minibatches
        G = _leaf_bytes(grads_like)
        return int(2 * G * (n_workers - 1) / n_workers / self.microbatches)


# ---------------------------------------------------------------------------
# MLLess — significance-driven update filtering with error feedback
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLLess(Strategy):
    """Block-wise significance filter: only gradient blocks whose L2 norm
    (including the error-feedback residual) exceeds ``threshold`` times
    the leaf RMS-norm-per-block are synchronized; the rest accumulate in
    the residual (error feedback => convergence is preserved).

    On TPU a dense psum moves the same wire bytes regardless of masking,
    so ``info["significant_fraction"]`` reports the *effective* (semantic)
    communication volume — the quantity MLLess bills for — while the
    quantized variant (``repro.core.compression``) realizes actual byte
    savings (beyond-paper).
    """
    name: str = "mlless"
    threshold: float = 0.5
    block: int = 256
    # None -> auto-detect like recovery.py's robust statistics: the
    # Pallas block_significance kernel on TPU (where Mosaic lowers it
    # natively), the bit-exact inline jnp path everywhere else
    use_kernel: Optional[bool] = None

    def _kernel_enabled(self) -> bool:
        if self.use_kernel is not None:
            return self.use_kernel
        from repro.kernels import ops as kops
        return not kops.default_interpret()

    def init_state(self, grads_like):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                            grads_like)

    def sync(self, grads, state, axis_names):
        use_kernel = self._kernel_enabled()
        if use_kernel:
            from repro.kernels import ops as kops
        sig_count = jnp.zeros((), jnp.float32)
        tot_count = jnp.zeros((), jnp.float32)
        new_resid = []
        filtered = []
        for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(state)):
            acc = g.astype(jnp.float32) + r
            flat = acc.reshape(-1)
            pad = (-flat.shape[0]) % self.block
            flat = jnp.pad(flat, (0, pad))
            blocks = flat.reshape(-1, self.block)
            if use_kernel:
                mask = kops.block_significance(blocks, self.threshold)
            else:
                bn = jnp.sqrt(jnp.sum(blocks * blocks, axis=1))
                rms = jnp.sqrt(jnp.mean(bn * bn) + 1e-20)
                mask = bn > self.threshold * rms
            keep = blocks * mask[:, None]
            kept = keep.reshape(-1)[:flat.shape[0] - pad] if pad \
                else keep.reshape(-1)
            kept = kept.reshape(g.shape)
            filtered.append(kept)
            new_resid.append(acc - kept)
            sig_count = sig_count + jnp.sum(mask)
            tot_count = tot_count + mask.shape[0]
        treedef = jax.tree.structure(grads)
        filtered = jax.tree.unflatten(treedef, filtered)
        new_resid = jax.tree.unflatten(treedef, new_resid)
        out = jax.tree.map(
            lambda g: jax.lax.pmean(g, axis_name=axis_names).astype(g.dtype),
            filtered)
        frac = sig_count / jnp.maximum(tot_count, 1)
        return out, new_resid, {"significant_fraction": frac}

    def comm_bytes(self, grads_like, n_workers, significant_fraction=0.3):
        G = _leaf_bytes(grads_like)
        return int(2 * G * (n_workers - 1) / n_workers
                   * significant_fraction)


STRATEGIES = {
    "allreduce": AllReduce,
    "parameter_server": ParameterServer,
    "scatterreduce": ScatterReduce,
    "spirt": Spirt,
    "mlless": MLLess,
}


def get_strategy(name: str, **kw) -> Strategy:
    if name == "quantized_scatterreduce":    # beyond-paper (lazy import)
        from repro.core.compression import QuantizedScatterReduce
        return QuantizedScatterReduce(**kw)
    if name in ("trimmed_mean", "coordinate_median", "krum",
                "geometric_median"):
        # byzantine-robust aggregation (SPIRT §5 / Blanchard et al. /
        # Weiszfeld) — lazy import to keep core free of a hard
        # serverless dependency
        from repro.serverless.recovery import (CoordinateMedian,
                                               GeometricMedian, Krum,
                                               TrimmedMean)
        cls = {"trimmed_mean": TrimmedMean,
               "coordinate_median": CoordinateMedian,
               "krum": Krum,
               "geometric_median": GeometricMedian}[name]
        return cls(**kw)
    if name == "byzantine":
        # fault-injection wrapper: get_strategy("byzantine",
        #   inner=get_strategy("trimmed_mean"), workers=(0,))
        from repro.serverless.faults import ByzantineGradients
        return ByzantineGradients(**kw)
    if name in STRATEGIES:
        return STRATEGIES[name](**kw)
    # simulated architecture names resolve through the ArchSpec registry
    # (sim-arch and real-training-arch are one object): e.g. "gpu" is a
    # ring allreduce, "hier_spirt"/"spirt_s3" ride SPIRT accumulation.
    # Lazy import keeps core usable without the serverless package.
    from repro.serverless.archs import _REGISTRY
    spec = _REGISTRY.get(name)
    if spec is not None and spec.jax_strategy is not None:
        return spec.make_strategy(**kw)
    raise KeyError(name)
