"""Distributed train-step builder.

Composes model × optimizer × gradient-sync strategy × mesh into a jit'd
step.  The whole step runs inside one ``jax.shard_map`` whose *manual*
axes are the data-parallel mesh axes ('pod', 'data'); the 'model' axis
stays *auto* so GSPMD provides tensor parallelism inside the body.  Local
(per-data-shard) gradients therefore exist explicitly, and the strategy's
collective schedule is exactly what appears in the lowered HLO — this is
what makes the paper's AllReduce/ScatterReduce/SPIRT/MLLess comparison
real on a TPU mesh (DESIGN.md §4/§5).

FSDP (ZeRO-3): block/tail params shard over the data axes; a per-block
all-gather hook runs inside the layer scan, and autodiff transposes it
into a reduce-scatter — those leaves arrive pre-reduced and are excluded
from the strategy sync (divided by W to turn the sum into a mean).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import losses, sharding
from repro.core.strategies import Strategy
from repro.optim.optimizers import Optimizer, apply_updates


def _strip_auto(spec: P, manual_axes) -> P:
    """Keep only manual-axis entries of a PartitionSpec."""
    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in manual_axes)
            return kept if kept else None
        return entry if entry in manual_axes else None
    return P(*[keep(e) for e in spec])


def _make_fsdp_gather(data_axes, gdim, rs_dtype=jnp.float32):
    """all_gather with a custom transpose: bf16 gather on the forward
    wire, ``rs_dtype`` psum_scatter backward.  fp32 reduce-scatter is the
    numerically safe default (and works around an XLA:CPU
    AllReducePromotion crash on bf16 reduce-scatter under partial-manual
    meshes — DESIGN.md §6); bf16 halves the backward wire bytes
    (EXPERIMENTS.md §Perf iteration HC2b)."""
    @jax.custom_vjp
    def gather(w):
        return jax.lax.all_gather(w, axis_name=data_axes, axis=gdim,
                                  tiled=True)

    def fwd(w):
        return gather(w), None

    def bwd(_, g):
        gs = jax.lax.psum_scatter(g.astype(rs_dtype),
                                  axis_name=data_axes,
                                  scatter_dimension=gdim, tiled=True)
        return (gs.astype(g.dtype),)

    gather.defvjp(fwd, bwd)

    def named(w):
        # checkpoint_name lets a remat policy SAVE gathered params so the
        # backward does not re-gather (EXPERIMENTS.md §Perf HC3f)
        from jax.ad_checkpoint import checkpoint_name
        return checkpoint_name(gather(w), "fsdp_gather")
    return named


def _fsdp_dims(spec: P, data_axes) -> Optional[int]:
    dset = set(data_axes) if isinstance(data_axes, tuple) else {data_axes}
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        eset = set(entry) if isinstance(entry, tuple) else {entry}
        if eset == dset:
            return dim
    return None


@dataclasses.dataclass
class TrainStep:
    step_fn: Callable            # jit'd (state, batch) -> (state, metrics)
    init_state: Callable         # (rng, batch_like) -> state
    state_shardings: Any
    batch_shardings: Any
    mesh: Any
    lower_kwargs: Dict
    state_sds: Callable = None   # () -> ShapeDtypeStruct state pytree
    batch_sds: Callable = None   # (batch_shape_dict) -> SDS batch pytree


def build_train_step(model, optimizer: Optimizer, strategy: Strategy,
                     mesh, *, data_axes: Tuple[str, ...] = ("data",),
                     model_axis: Optional[str] = "model",
                     fsdp: bool = False, loss_fn=None,
                     fsdp_rs_dtype=jnp.float32) -> TrainStep:
    """``model_axis=None`` disables tensor parallelism (pure-DP/ZeRO
    profiles — the mesh axes named in ``data_axes`` all become
    data-parallel)."""
    manual_axes = set(data_axes)
    W = int(np.prod([mesh.shape[a] for a in data_axes]))
    K = strategy.microbatches

    if loss_fn is None:
        def loss_fn(params, batch):
            logits, aux = model.apply(params, batch)
            return losses.softmax_cross_entropy(
                logits, batch["labels"]) + aux

    # ---------------- parameter pspecs / fsdp bookkeeping ----------------
    example_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = sharding.param_pspecs(example_params, mesh, fsdp=fsdp,
                                   data_axes=data_axes,
                                   model_axis=model_axis)

    if fsdp:
        blocks_specs = [jax.tree.map(lambda s: s,
                                     pspecs["blocks"][j])
                        for j in range(len(pspecs.get("blocks", [])))]
        tail_specs = list(pspecs.get("tail", []))

        def param_hook(tree, kind, idx):
            specs = (blocks_specs[idx] if kind == "block"
                     else tail_specs[idx])

            def one(g, spec):
                dim = _fsdp_dims(spec, data_axes)
                if dim is None:
                    return g
                gdim = dim - 1 if kind == "block" else dim  # scan slice
                return _make_fsdp_gather(data_axes, gdim,
                                         fsdp_rs_dtype)(g)
            return jax.tree.map(one, tree, specs,
                                is_leaf=lambda x: isinstance(x, P))
        model.param_hook = param_hook
    else:
        model.param_hook = None

    flat_specs, spec_treedef = jax.tree.flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    fsdp_mask = [(_fsdp_dims(s, data_axes) is not None) for s in flat_specs]

    # ---------------- the shard_map body ----------------
    def step_body(state, batch):
        params, opt_state, strat_state, step = (
            state["params"], state["opt"], state["strat"], state["step"])
        # strat state carries a leading dp dim (worker-local state)
        strat_local = jax.tree.map(lambda x: x[0], strat_state)

        # microbatch over the local batch dim, clamped to what it
        # supports (SPIRT's accumulation needs >= K local minibatches —
        # a single local sample cannot be split without changing the
        # loss's attention-context semantics, so K degrades gracefully
        # to 1 under pure-DP meshes with B_local=1)
        B_local = jax.tree.leaves(batch)[0].shape[0]
        Ke = int(np.gcd(K, B_local)) if K > 1 else 1

        if Ke > 1:
            def mb_slice(i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // Ke), x.shape[0] // Ke,
                        axis=0),
                    batch)

            def acc_body(i, carry):
                acc, _ = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb_slice(i))
                return (jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g), l)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, loss = jax.lax.fori_loop(
                0, Ke, acc_body, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / Ke, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        # --- split FSDP (pre-reduced) leaves from strategy-synced leaves
        gleaves, gdef = jax.tree.flatten(grads)
        sync_leaves = [g for g, m in zip(gleaves, fsdp_mask) if not m]
        synced, new_strat_local, info = strategy.sync(
            sync_leaves, strat_local, data_axes if len(data_axes) > 1
            else data_axes[0])
        out_leaves, si = [], 0
        for g, m in zip(gleaves, fsdp_mask):
            if m:
                out_leaves.append(g / W)   # reduce-scatter sum -> mean
            else:
                out_leaves.append(synced[si])
                si += 1
        grads = jax.tree.unflatten(gdef, out_leaves)

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": jax.lax.pmean(loss, data_axes if
                                         len(data_axes) > 1
                                         else data_axes[0]),
                   "step": step + 1}
        metrics.update({k: jax.lax.pmean(
            v, data_axes if len(data_axes) > 1 else data_axes[0])
            for k, v in info.items()})
        new_state = {"params": params, "opt": opt_state,
                     "strat": jax.tree.map(lambda x: x[None],
                                           new_strat_local),
                     "step": step + 1}
        return new_state, metrics

    # ---------------- spec plumbing ----------------
    manual_pspecs = jax.tree.map(lambda s: _strip_auto(s, manual_axes),
                                 pspecs, is_leaf=lambda x: isinstance(x, P))

    def opt_specs_like(opt_state):
        def one(path, leaf):
            # m/v follow their param's spec; scalars replicated
            return P()
        # build by matching structure: m and v mirror params
        specs = {}
        for k, v in opt_state.items():
            if k in ("m", "v", "mu"):
                specs[k] = manual_pspecs
            else:
                specs[k] = P()
        return specs

    example_opt = jax.eval_shape(optimizer.init, example_params)
    opt_manual = opt_specs_like(example_opt)

    sync_like = [l for l, m in zip(jax.tree.leaves(example_params),
                                   fsdp_mask) if not m]
    example_strat = jax.eval_shape(
        functools.partial(strategy.init_state), sync_like)
    strat_manual = jax.tree.map(
        lambda _: P(data_axes if len(data_axes) > 1 else data_axes[0]),
        example_strat)

    state_manual = {"params": manual_pspecs, "opt": opt_manual,
                    "strat": strat_manual, "step": P()}
    dp = data_axes if len(data_axes) > 1 else data_axes[0]
    dp_spec = dp
    batch_manual = {"tokens": P(dp), "labels": P(dp)}
    # optional modality inputs share the batch-dim sharding
    metrics_manual = {"loss": P(), "step": P()}
    if hasattr(strategy, "threshold"):
        metrics_manual["significant_fraction"] = P()

    def make_sm(batch_keys):
        bspec = {k: P(dp) for k in batch_keys}
        return shard_map(
            step_body, mesh=mesh,
            in_specs=(state_manual, bspec),
            out_specs=(state_manual, metrics_manual),
            axis_names=manual_axes, check_vma=False)

    @functools.partial(jax.jit, static_argnames=())
    def step_fn(state, batch):
        return make_sm(tuple(sorted(batch)))(state, batch)

    # ---------------- full (auto+manual) shardings for placement -------
    full_pspecs = pspecs
    state_full = {
        "params": full_pspecs,
        "opt": {k: (full_pspecs if k in ("m", "v", "mu") else P())
                for k in example_opt},
        "strat": strat_manual,
        "step": P(),
    }
    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_full,
        is_leaf=lambda x: isinstance(x, P))

    def init_state(rng, dtype_params=None):
        params = model.init(rng) if dtype_params is None else dtype_params
        params = jax.tree.map(
            lambda p, s: jax.device_put(p, NamedSharding(mesh, s)),
            params, pspecs, is_leaf=lambda x: isinstance(x, P))
        opt_state = optimizer.init(params)
        sync_like_r = [l for l, m in zip(jax.tree.leaves(params), fsdp_mask)
                       if not m]
        # worker-local strategy state: leading dim = dp world size,
        # sharded one slice per data shard
        strat_state = jax.tree.map(
            lambda x: jax.device_put(
                jnp.zeros((W,) + x.shape, x.dtype),
                NamedSharding(mesh, P(dp_spec))),
            strategy.init_state(sync_like_r))
        return {"params": params, "opt": opt_state, "strat": strat_state,
                "step": jnp.zeros((), jnp.int32)}

    batch_shardings = {k: NamedSharding(mesh, P(dp))
                       for k in ("tokens", "labels")}

    def state_sds():
        """ShapeDtypeStruct state pytree (no allocation) for dry-runs."""
        def sds(tree, shard_tree):
            return jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                  sharding=s),
                tree, shard_tree)
        strat_like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((W,) + x.shape, x.dtype),
            jax.eval_shape(strategy.init_state, sync_like))
        strat_sh = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, x.dtype,
                sharding=NamedSharding(mesh, P(dp))), strat_like)
        return {
            "params": sds(example_params, state_shardings["params"]),
            "opt": sds(example_opt, state_shardings["opt"]),
            "strat": strat_sh,
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def batch_sds(extra_shapes: Optional[Dict] = None):
        """SDS batch: tokens/labels (B, S) + optional modality inputs."""
        out = {}
        for k, (shape, dtype) in (extra_shapes or {}).items():
            out[k] = jax.ShapeDtypeStruct(
                shape, dtype, sharding=NamedSharding(mesh, P(dp)))
        return out

    return TrainStep(step_fn=step_fn, init_state=init_state,
                     state_shardings=state_shardings,
                     batch_shardings=batch_shardings, mesh=mesh,
                     lower_kwargs={}, state_sds=state_sds,
                     batch_sds=batch_sds)
