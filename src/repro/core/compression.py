"""Beyond-paper: int8-quantized gradient synchronization.

MLLess reduces *semantic* communication (send only significant updates);
on a TPU mesh a dense psum moves the same wire bytes regardless.  This
module realizes actual byte savings with the standard compressed
all-reduce decomposition:

    quantize (int8, per-chunk scale) -> all_to_all (1/4 wire bytes)
    -> local dequant + reduce -> requantize -> all_gather (1/4 bytes)

with input-side error feedback (EF-SGD) so convergence is preserved.
Wire bytes: 2·G/4·(W-1)/W versus the fp32-ring 2·G·(W-1)/W — a 4x
reduction visible in the dry-run HLO (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import Strategy, _leaf_bytes


def _quant(x, axis=-1):
    """Symmetric int8 quantization with per-row fp32 scales."""
    scale = jnp.max(jnp.abs(x), axis=axis, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class QuantizedScatterReduce(Strategy):
    """int8 compressed scatter-reduce + all-gather with error feedback."""
    name: str = "quantized_scatterreduce"
    chunk: int = 512

    def init_state(self, grads_like):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                            grads_like)

    def sync(self, grads, state, axis_names):
        # normalize to a tuple once and hand the SAME normalized axes
        # to every collective: W (the row count of the scatter layout)
        # and the all_to_all/all_gather device ordering must agree, or
        # chunks reassemble permuted.  jax collectives accept a tuple
        # of mesh axis names and treat it as the combined axis, so a
        # multi-axis data mesh (e.g. ("data", "fsdp")) reduces over the
        # full product — pinned by the 4-device parity test.
        axes = (axis_names,) if isinstance(axis_names, str) \
            else tuple(axis_names)
        if not axes:
            raise ValueError("QuantizedScatterReduce.sync needs at "
                             "least one mesh axis name")
        axis_names = axes if len(axes) > 1 else axes[0]
        from repro.compat import axis_size as _axis_size
        W = int(np.prod([_axis_size(a) for a in axes]))

        new_resid, out = [], []
        for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(state)):
            acc = g.astype(jnp.float32) + r
            flat = acc.reshape(-1)
            per = W * self.chunk
            pad = (-flat.shape[0]) % per
            flat = jnp.pad(flat, (0, pad))
            rows = flat.reshape(W, -1, self.chunk)        # (W, nc, c)

            q, scale = _quant(rows)                       # int8 + fp32/row
            # input-side error feedback
            deq = _dequant(q, scale).reshape(-1)
            resid = (flat - deq)[:flat.shape[0] - pad] if pad \
                else flat - deq
            new_resid.append(resid.reshape(g.shape))

            # exchange: device i receives every peer's row i
            qx = jax.lax.all_to_all(q, axis_names, split_axis=0,
                                    concat_axis=0, tiled=True)
            sx = jax.lax.all_to_all(scale, axis_names, split_axis=0,
                                    concat_axis=0, tiled=True)
            part = jnp.sum(_dequant(qx, sx), axis=0) / W  # (nc, c)

            q2, s2 = _quant(part)
            qg = jax.lax.all_gather(q2, axis_names, axis=0, tiled=False)
            sg = jax.lax.all_gather(s2, axis_names, axis=0, tiled=False)
            full = _dequant(qg, sg).reshape(-1)
            full = full[:flat.shape[0] - pad] if pad else full
            out.append(full.reshape(g.shape).astype(jnp.float32))
        treedef = jax.tree.structure(grads)
        return (jax.tree.unflatten(treedef, out),
                jax.tree.unflatten(treedef, new_resid), {})

    def comm_bytes(self, grads_like, n_workers):
        G = _leaf_bytes(grads_like)
        # int8 payload both phases + fp32 scales (1/chunk overhead)
        payload = G / 4 * (1 + 4.0 / self.chunk)
        return int(2 * payload * (n_workers - 1) / n_workers)
