from repro.core.strategies import (  # noqa: F401
    AllReduce, MLLess, ParameterServer, ScatterReduce, Spirt, Strategy,
    get_strategy,
)
from repro.core.train_step import TrainStep, build_train_step  # noqa: F401
from repro.core.serve_step import ServeStep, build_serve_step  # noqa: F401
