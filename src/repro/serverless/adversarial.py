"""Adversarial robustness lab: the attack-model registry + the batched
robust-aggregation statistics behind the byzantine-fraction sweeps.

The paper's fault-tolerance claim is qualitative — SPIRT-style
in-database robust aggregation survives adversarial workers while plain
averaging degrades — and PR 1 demonstrated it at exactly one point: one
worker, one attack (a -8x gradient scale).  The ROADMAP's open
*adversarial-fraction curves* item needs the whole surface: byzantine
fraction 0 -> (W-1)/2W x attack model x aggregator.  This module holds
the two registries that surface is swept over:

  **Attack models** — one frozen :class:`AttackSpec` per way a
  colluding or independent byzantine worker corrupts its gradient,
  following the ``archs.ArchSpec`` pattern (``register_attack`` /
  ``get_attack`` / ``list_attacks``; unknown names raise with the
  registered list).  Each spec carries BOTH realizations of the attack:

    ``apply_rows``  batched numpy — corrupts a ``[..., W, D]`` stack of
                    per-worker gradients under a boolean byzantine mask
                    ``[..., W]``; drives the vectorized quadratic-loss
                    simulated path (``sweep.adversarial_sweep``) and
                    the breakdown-point property tests.
    ``jax_apply``   the same corruption inside a ``shard_map`` body,
                    dispatched by ``faults.ByzantineGradients`` before
                    the inner strategy's collective — real training
                    sees exactly what the simulated stack saw.

  Registered attacks (SPIRT §5 / Baruch et al. "A Little Is Enough"):

    sign_flip          g -> -g
    scale              g -> scale * g           (default -10, PR 1's attack)
    gaussian_noise     g -> g + scale * N(0, I) (seeded, per worker)
    little_is_enough   all byzantine workers collude on
                       honest_mean - scale * honest_std — small enough
                       per coordinate to hide inside the honest spread
                       (for small ``scale``), yet identical across
                       attackers so selection rules that trust tight
                       clusters (Krum) are the explicit target
    zero               g -> 0                   (dropped contribution)

  **Simulated aggregators** — batched numpy twins of the
  :mod:`repro.serverless.recovery` JAX statistics, operating on
  ``[..., W, D]`` stacks with a (possibly per-batch-row) byzantine
  budget ``f``: ``mean``, ``trimmed_mean``, ``coordinate_median``,
  ``krum`` (multi-Krum), ``geometric_median`` (Weiszfeld).  Exactness
  against the JAX implementations is pinned by
  ``tests/test_adversarial.py``; the vectorized sweep uses these so a
  2,000-cell fraction grid costs milliseconds, not jit compiles.

Import-light by design (numpy only at module scope; ``jax_apply``
closures lazy-import jax) so analytic sweeps and property tests never
pay accelerator start-up.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Attack-model registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttackSpec:
    """One byzantine gradient-corruption model.

    ``apply_rows(stacked, byz_mask, rng, scale)`` — batched numpy:
    ``stacked`` is ``[..., W, D]``, ``byz_mask`` a boolean ``[..., W]``
    broadcastable against it, ``rng`` a seeded generator (only the
    stochastic attacks draw from it), ``scale`` the attack magnitude.
    Returns a corrupted copy; honest rows are bit-unchanged.

    ``jax_apply(grads, bad, axis_names, scale, seed, step)`` — the
    same corruption for one worker inside a ``shard_map`` body:
    ``grads`` is the gradient pytree, ``bad`` a traced boolean scalar
    (is THIS worker byzantine), collectives over ``axis_names`` are
    available (the colluding attack reads fleet statistics through
    them), and ``step`` is the traced sync-step counter
    ``ByzantineGradients`` threads through its strategy state — the
    stochastic attacks fold it into their PRNG key so every step draws
    FRESH noise, exactly like the numpy twin redraws per step.
    """
    name: str
    apply_rows: Callable
    jax_apply: Callable
    description: str = ""
    colluding: bool = False            # needs fleet statistics (LIE)
    default_scale: float = 1.0

    def rows(self, stacked, byz_mask, rng, scale=None):
        """``apply_rows`` with the spec's own default magnitude."""
        return self.apply_rows(
            np.asarray(stacked, float), np.asarray(byz_mask, bool), rng,
            self.default_scale if scale is None else float(scale))


_ATTACKS: Dict[str, AttackSpec] = {}


def register_attack(spec: AttackSpec, *,
                    overwrite: bool = False) -> AttackSpec:
    """Add an attack model (returns it).  Re-registering a name is an
    error unless ``overwrite`` — same contract as ``register_arch``."""
    if not overwrite and spec.name in _ATTACKS:
        raise ValueError(f"attack model {spec.name!r} is already "
                         "registered (pass overwrite=True to replace)")
    _ATTACKS[spec.name] = spec
    return spec


def unregister_attack(name: str) -> None:
    _ATTACKS.pop(name, None)


def get_attack(name: str) -> AttackSpec:
    try:
        return _ATTACKS[name]
    except KeyError:
        raise ValueError(
            f"unknown attack model {name!r}; registered: "
            f"{', '.join(_ATTACKS)}") from None


def list_attacks() -> Tuple[str, ...]:
    """All registered attack names, in registration order."""
    return tuple(_ATTACKS)


# ---- numpy realizations (batched) -----------------------------------------
def _rows_sign_flip(stacked, byz, rng, scale):
    return np.where(byz[..., None], -stacked, stacked)


def _rows_scale(stacked, byz, rng, scale):
    return np.where(byz[..., None], scale * stacked, stacked)


def _rows_gaussian(stacked, byz, rng, scale):
    # ONE noise field over the trailing (W, D) axes, broadcast across
    # any batch dims: cells that share a draw (e.g. the fraction axis of
    # a sweep) stay comparable — growing the byzantine set adds noise
    # terms instead of redrawing the whole field
    noise = rng.standard_normal(stacked.shape[-2:])
    return np.where(byz[..., None], stacked + scale * noise, stacked)


def _rows_lie(stacked, byz, rng, scale):
    # colluding: every byzantine worker ships the SAME vector, placed
    # `scale` standard deviations below the per-coordinate mean of the
    # WHOLE pre-corruption stack — every row is still honestly computed
    # at this point, so fleet statistics ARE the honest distribution
    # the attackers are assumed to know.  Matches _jax_lie's pmean
    # collectives exactly (same stack, same statistic).
    mu = stacked.mean(axis=-2, keepdims=True)
    sd = stacked.std(axis=-2, keepdims=True)
    return np.where(byz[..., None], mu - scale * sd, stacked)


def _rows_zero(stacked, byz, rng, scale):
    return np.where(byz[..., None], 0.0, stacked)


# ---- jax realizations (inside shard_map; lazy imports) --------------------
def _jax_sign_flip(grads, bad, axis_names, scale, seed, step):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(lambda g: jnp.where(bad, -g, g), grads)


def _jax_scale(grads, bad, axis_names, scale, seed, step):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(
        lambda g: jnp.where(bad, g * jnp.asarray(scale, g.dtype), g),
        grads)


def _jax_gaussian(grads, bad, axis_names, scale, seed, step):
    import jax
    import jax.numpy as jnp

    from repro.serverless.faults import _linear_axis_index
    # per-(worker, step) noise stream: fold the (traced) data-parallel
    # index into the seed so no two attackers collude by accident, and
    # the sync-step counter so every step draws FRESH noise (a frozen
    # draw would be a constant-bias attack, not gaussian noise); one
    # more fold per leaf so leaves draw independently
    key = jax.random.fold_in(jax.random.PRNGKey(seed),
                             _linear_axis_index(axis_names))
    key = jax.random.fold_in(key, step)
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        noise = jax.random.normal(jax.random.fold_in(key, i), g.shape,
                                  jnp.float32).astype(g.dtype)
        out.append(jnp.where(bad, g + jnp.asarray(scale, g.dtype) * noise,
                             g))
    return jax.tree.unflatten(treedef, out)


def _jax_lie(grads, bad, axis_names, scale, seed, step):
    import jax
    import jax.numpy as jnp

    def one(g):
        g32 = g.astype(jnp.float32)
        # fleet statistics through the same collective fabric the inner
        # strategy will use; computed from PRE-corruption gradients —
        # the attackers know the honest distribution (their own locally
        # computed gradients are honest until this corruption step)
        mu = jax.lax.pmean(g32, axis_name=axis_names)
        var = jax.lax.pmean(g32 * g32, axis_name=axis_names) - mu * mu
        evil = (mu - scale * jnp.sqrt(jnp.maximum(var, 0.0))).astype(
            g.dtype)
        return jnp.where(bad, evil, g)
    return jax.tree.map(one, grads)


def _jax_zero(grads, bad, axis_names, scale, seed, step):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(
        lambda g: jnp.where(bad, jnp.zeros_like(g), g), grads)


register_attack(AttackSpec(
    name="sign_flip", apply_rows=_rows_sign_flip,
    jax_apply=_jax_sign_flip,
    description="g -> -g (gradient ascent on the honest objective)"))

register_attack(AttackSpec(
    name="scale", apply_rows=_rows_scale, jax_apply=_jax_scale,
    default_scale=-10.0,
    description="g -> scale * g (PR 1's -kx poisoned gradient)"))

register_attack(AttackSpec(
    name="gaussian_noise", apply_rows=_rows_gaussian,
    jax_apply=_jax_gaussian, default_scale=10.0,
    description="g -> g + scale * N(0, I), seeded per worker"))

register_attack(AttackSpec(
    name="little_is_enough", apply_rows=_rows_lie, jax_apply=_jax_lie,
    colluding=True, default_scale=1.5,
    description="colluding: honest_mean - scale * honest_std "
                "(Baruch et al.; hides inside the honest spread)"))

register_attack(AttackSpec(
    name="zero", apply_rows=_rows_zero, jax_apply=_jax_zero,
    description="g -> 0 (silently dropped contribution)"))


# ---------------------------------------------------------------------------
# Batched numpy robust aggregators (simulated-path twins of recovery.py)
# ---------------------------------------------------------------------------
def np_mean(stacked, f=0):
    """Plain averaging — breakdown point 0; the degradation baseline."""
    return np.asarray(stacked, float).mean(axis=-2)


def np_trimmed_mean(stacked, f=1):
    """Per-coordinate mean after dropping the ``f`` smallest and ``f``
    largest values.  ``f`` may be an int or an int array broadcasting
    over the batch dims (one budget per sweep row); needs ``W > 2f``."""
    stacked = np.asarray(stacked, float)
    W = stacked.shape[-2]
    f = np.asarray(f, int)
    if np.any(2 * f >= W):
        raise ValueError(f"trimmed_mean needs W > 2*f, got W={W}, "
                         f"f={f.max()}")
    s = np.sort(stacked, axis=-2)
    pos = np.arange(W)
    keep = (pos >= f[..., None]) & (pos < W - f[..., None])
    return np.sum(s * keep[..., None], axis=-2) \
        / (W - 2 * f)[..., None]


def np_coordinate_median(stacked, f=0):
    """Per-coordinate median — breakdown point (W-1)/2W."""
    return np.median(np.asarray(stacked, float), axis=-2)


def np_krum(stacked, f=1, m=1):
    """(Multi-)Krum (Blanchard et al.): score every row by the summed
    squared distance to its ``W - f - 2`` nearest neighbours, average
    the ``m`` lowest-scoring rows.  Needs ``W >= 2f + 3``; ``f`` may be
    batched like :func:`np_trimmed_mean`'s."""
    stacked = np.asarray(stacked, float)
    W = stacked.shape[-2]
    f = np.asarray(f, int)
    if np.any(f < 0):
        raise ValueError(f"krum needs f >= 0, got {f.min()}")
    if np.any(W < 2 * f + 3):
        raise ValueError(
            f"krum needs W >= 2f + 3 to out-vote f byzantine rows, got "
            f"W={W}, f={f.max()} (max feasible f is {(W - 3) // 2})")
    if not 1 <= int(m) <= W:
        raise ValueError(f"krum needs 1 <= m <= W, got m={m}")
    d = ((stacked[..., :, None, :] - stacked[..., None, :, :]) ** 2) \
        .sum(axis=-1)                          # [..., W, W]
    ds = np.sort(d, axis=-1)                   # col 0 is self (0.0)
    pos = np.arange(W)
    # neighbours 1 .. W-f-2 inclusive == W-f-2 nearest non-self rows;
    # [..., 1, W] so one row-axis mask broadcasts over every scored row
    nb = (pos >= 1) & (pos <= (W - 2 - f)[..., None, None])
    scores = (ds * nb).sum(axis=-1)            # [..., W]
    sel = np.argsort(scores, axis=-1, kind="stable")[..., :int(m)]
    return np.take_along_axis(stacked, sel[..., None],
                              axis=-2).mean(axis=-2)


def np_geometric_median(stacked, f=0, *, tol=1e-8, max_iter=200):
    """Geometric median over the worker axis by Weiszfeld iteration,
    batched; breakdown point (W-1)/2W.  Initialized at the coordinate
    median; stops when the relative step falls below ``tol``."""
    stacked = np.asarray(stacked, float)
    if tol <= 0 or max_iter < 1:
        raise ValueError(f"geometric_median needs tol > 0 and "
                         f"max_iter >= 1, got tol={tol}, "
                         f"max_iter={max_iter}")
    z = np.median(stacked, axis=-2)            # [..., D]
    scale = np.maximum(np.linalg.norm(stacked, axis=-1).max(axis=-1),
                       1e-12)                  # [...]
    # rows freeze individually once their own step converges, so a
    # batched call returns bit-identical results per row regardless of
    # what else shares the batch (sweep cells stay independent)
    frozen = np.zeros(z.shape[:-1], bool)
    for _ in range(max_iter):
        dist = np.linalg.norm(stacked - z[..., None, :], axis=-1)
        w = 1.0 / np.maximum(dist, 1e-12 * scale[..., None])
        z_new = np.sum(w[..., None] * stacked, axis=-2) \
            / np.sum(w, axis=-1)[..., None]
        step = np.linalg.norm(z_new - z, axis=-1)
        z = np.where(frozen[..., None], z, z_new)
        frozen |= step <= tol * scale
        if frozen.all():
            break
    return z


SIM_AGGREGATORS: Dict[str, Callable] = {
    "mean": np_mean,
    "trimmed_mean": np_trimmed_mean,
    "coordinate_median": np_coordinate_median,
    "krum": np_krum,
    "geometric_median": np_geometric_median,
}


def sim_aggregator_max_f(name: str, n_workers: int) -> int:
    """The largest byzantine budget ``f`` the aggregator can be
    configured with at fleet size ``n_workers`` — its theoretical
    breakdown point on the fraction axis.  Plain averaging breaks at
    the first adversary."""
    if name not in SIM_AGGREGATORS:
        raise ValueError(f"unknown simulated aggregator {name!r}; "
                         f"registered: {', '.join(SIM_AGGREGATORS)}")
    if name == "mean":
        return 0
    if name == "krum":
        return max((n_workers - 3) // 2, 0)
    return (n_workers - 1) // 2                # median family / trimmed


def byzantine_fractions(n_workers: int) -> Tuple[float, ...]:
    """The fraction ladder 0 -> (W-1)/2W in integer-worker steps: every
    k/W with 0 <= k <= (W-1)//2 — the whole sub-majority range."""
    if n_workers < 3:
        raise ValueError(f"need n_workers >= 3, got {n_workers}")
    return tuple(k / n_workers for k in range((n_workers - 1) // 2 + 1))
