"""Reactive autoscaling policies for the serverless event runtime.

Serverless training's elasticity story — the reason the paper cares
about Lambda at all — is that the fleet can grow mid-epoch for the cost
of a cold start, and shrink to zero the moment work runs out.  The
policies here observe each barrier (round duration, fleet size,
remaining work) and return a worker delta; the runtime charges every
added worker its cold start + state load and bills all workers
per-second through ``repro.costmodel.pricing``, so scale decisions
show up in both the makespan and the cost column of
``benchmarks/fault_tolerance.py``.

``ReactiveAutoscaler`` is deliberately boring: EMA of round durations,
scale out when the current round blows past the EMA (straggler or
storm), scale in when the remaining pool no longer needs the fleet.
Deterministic — no RNG — so runs are replayable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple


@dataclasses.dataclass
class ReactiveAutoscaler:
    """Scale out on slow rounds, scale in when work runs short.

    observe() contract (called by the runtime at every barrier):
      round_idx          1-based index of the round that just finished
      now_s              barrier release time
      active_workers     workers that contributed to this round
      remaining_batches  work left in the shared pool
      batches_per_round  per-worker round quantum
    returns an int delta: >0 spawn, <0 retire after their next round.
    """
    min_workers: int = 1
    max_workers: int = 16
    scale_out_ratio: float = 1.4    # round_s > ratio * EMA  -> +step
    scale_in_headroom: float = 2.0  # fleet could finish remaining work
                                    # with this many fewer rounds -> -step
    step: int = 1
    cooldown_rounds: int = 2
    ema_alpha: float = 0.5
    _ema_s: Optional[float] = dataclasses.field(default=None, repr=False)
    _last_scale_round: int = dataclasses.field(default=-10, repr=False)
    _last_t: float = dataclasses.field(default=0.0, repr=False)
    decisions: List[Tuple[int, int, str]] = dataclasses.field(
        default_factory=list, repr=False)

    def observe(self, *, round_idx: int, now_s: float, active_workers: int,
                remaining_batches: float, batches_per_round: float,
                ideal_round_s: Optional[float] = None) -> int:
        round_s = now_s - self._last_t
        self._last_t = now_s
        prev_ema = self._ema_s
        self._ema_s = (round_s if prev_ema is None else
                       self.ema_alpha * round_s
                       + (1 - self.ema_alpha) * prev_ema)
        if round_idx <= 1:              # round 1 embeds the cold start
            return 0
        if round_idx - self._last_scale_round < self.cooldown_rounds:
            return 0
        if remaining_batches <= 0:
            return 0

        rounds_left = math.ceil(
            remaining_batches / max(active_workers * batches_per_round,
                                    1e-9))
        # reference round time: the plan's fault-free ideal when the
        # runtime provides it (catches a from-the-start straggler the
        # EMA would normalize away), else the trailing EMA.  `is not
        # None`, not truthiness: a legitimate 0.0 ideal must not fall
        # back to the EMA and mute the scale-out signal
        ref = ideal_round_s if ideal_round_s is not None else prev_ema
        # scale OUT: this round was anomalously slow and there is enough
        # remaining work to amortize a cold start
        if (ref is not None and round_s > self.scale_out_ratio * ref
                and active_workers < self.max_workers
                and rounds_left >= 2):
            self._last_scale_round = round_idx
            # log the APPLIED delta, not the configured step: near the
            # fleet cap the clamp below bites, and a replayed decision
            # log must match the scale events that actually happened
            applied = min(self.step, self.max_workers - active_workers)
            self.decisions.append((round_idx, applied,
                                   f"slow round {round_s:.2f}s vs ref "
                                   f"{ref:.2f}s"))
            return applied
        # scale IN: fewer workers would still finish in the same number
        # of rounds (tail of the pool)
        smaller = active_workers - self.step
        if smaller >= self.min_workers:
            rounds_smaller = math.ceil(
                remaining_batches / max(smaller * batches_per_round, 1e-9))
            if rounds_smaller <= rounds_left + self.scale_in_headroom - 2:
                self._last_scale_round = round_idx
                self.decisions.append((round_idx, -self.step,
                                       f"{rounds_smaller} rounds suffice"))
                return -self.step
        return 0


@dataclasses.dataclass
class ScheduledScaler:
    """Fixed (round -> delta) schedule; useful for tests and sweeps."""
    schedule: Tuple[Tuple[int, int], ...] = ()

    def observe(self, *, round_idx: int, **_) -> int:
        return sum(d for r, d in self.schedule if r == round_idx)
