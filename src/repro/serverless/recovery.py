"""Recovery semantics for the serverless event runtime + robust
aggregation strategies for real JAX training.

Two recovery policies for a crashed worker, matching the designs the
paper compares:

  CheckpointRestore  the λML / MLLess model: the supervisor detects the
                     dead invocation after ``detection_s``, re-invokes
                     it (cold start + state load) and the worker
                     *replays* every round since its last checkpoint
                     (checkpoints every ``checkpoint_every`` rounds).
                     All surviving workers stall at the barrier until
                     the replay catches up — the stall is the measured
                     time-to-recover.

  PeerTakeover       SPIRT (arXiv 2309.14148): per-worker state lives in
                     the database, so nothing replays.  After
                     ``detection_s`` the survivors fetch the dead
                     worker's in-DB partition (one model-sized
                     transfer) and absorb its remaining minibatches;
                     the fleet continues with W-1 workers.

Robust aggregators — SPIRT's defense against poisoned gradients — are
ordinary :class:`~repro.core.strategies.Strategy` objects: every worker
all-gathers the fleet's gradients and reduces with a byzantine-robust
statistic instead of the mean.  They compose with
``faults.ByzantineGradients`` (corrupt-then-aggregate) and with SPIRT's
microbatch accumulation (``microbatches=K``), and are reachable through
``repro.core.get_strategy("trimmed_mean" | "coordinate_median")``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import Strategy, _leaf_bytes


# ---------------------------------------------------------------------------
# Recovery policies (consumed by runtime.EventRuntime)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    detection_s: float = 1.0


@dataclasses.dataclass(frozen=True)
class CheckpointRestore(RecoveryPolicy):
    """Re-invoke the crashed worker; replay since the last checkpoint."""
    checkpoint_every: int = 4          # rounds between checkpoints

    def replay_rounds(self, crashed_round: int) -> int:
        return crashed_round % self.checkpoint_every


@dataclasses.dataclass(frozen=True)
class PeerTakeover(RecoveryPolicy):
    """SPIRT-style: survivors absorb the dead worker's partition."""
    detection_s: float = 0.5


@dataclasses.dataclass
class RecoveryEvent:
    worker: int
    crash_time_s: float
    rejoined_time_s: float
    mode: str                          # "restore" | "takeover"

    @property
    def time_to_recover_s(self) -> float:
        return self.rejoined_time_s - self.crash_time_s


# ---------------------------------------------------------------------------
# Robust reduction statistics (pure functions, unit-testable on CPU)
# ---------------------------------------------------------------------------
def trimmed_mean_sort(stacked, trim: int):
    """Reference implementation: full sort over the worker axis, then
    mean of the interior slice.  O(W log W) per coordinate; kept as the
    semantic reference for the ``trim=1`` fast path below."""
    W = stacked.shape[0]
    if W <= 2 * trim:
        raise ValueError(f"trimmed_mean needs W > 2*trim, got W={W}, "
                         f"trim={trim}")
    s = jnp.sort(stacked, axis=0)
    return jnp.mean(jax.lax.slice_in_dim(s, trim, W - trim, axis=0), axis=0)


def trimmed_mean(stacked, trim: int):
    """Mean over axis 0 after dropping the ``trim`` smallest and largest
    values per coordinate.  ``stacked``: [W, ...]; needs W > 2*trim.

    ``trim=1`` — the common SPIRT setting — avoids the full sort by
    masking out one min and one max entry per coordinate and summing
    only the middle values: O(W) reductions instead of an O(W log W)
    sort.  NOT computed as ``(sum - min - max)/(W-2)``: a byzantine
    worker shipping a hugely scaled gradient would absorb the honest
    mass into the grand total and cancellation would destroy it on the
    subtraction — the exact attack this aggregator defends against
    (``tests/test_robust_agg.py`` checks equivalence against
    :func:`trimmed_mean_sort`, including that adversarial case)."""
    W = stacked.shape[0]
    if W <= 2 * trim:
        raise ValueError(f"trimmed_mean needs W > 2*trim, got W={W}, "
                         f"trim={trim}")
    if trim == 1:
        imin = jnp.argmin(stacked, axis=0)
        imax = jnp.argmax(stacked, axis=0)
        idx = jnp.arange(W).reshape((W,) + (1,) * (stacked.ndim - 1))
        keep = (idx != imin) & (idx != imax)
        mid = jnp.sum(stacked * keep, axis=0) / (W - 2)
        # argmin == argmax only when all W values at that coordinate
        # are equal; the mask then dropped a single entry, so patch in
        # the (trivially robust) common value instead
        return jnp.where(imin == imax, stacked[0], mid)
    return trimmed_mean_sort(stacked, trim)


def coordinate_median(stacked):
    """Per-coordinate median over axis 0 of a [W, ...] stack."""
    return jnp.median(stacked, axis=0)


# ---------------------------------------------------------------------------
# Robust aggregation strategies
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _RobustAggregate(Strategy):
    """all-gather + robust reduce.  Wire volume matches ParameterServer
    (every worker sees every gradient) — robustness is bought with the
    same W x byte blowup the paper charges the λML master with.

    The gradient pytree is flattened into ONE contiguous fp32 buffer
    before the all-gather: a model with L leaves dispatches a single
    collective + a single robust reduction instead of L of each
    (per-leaf dispatch was the hot cost at SPIRT's per-minibatch sync
    cadence).  ``sync_per_leaf`` keeps the original per-leaf path as
    the semantic reference; ``tests/test_robust_agg.py`` checks the
    two agree."""
    name: str = "robust"

    def _reduce(self, stacked):
        raise NotImplementedError

    def sync(self, grads, state, axis_names):
        leaves, treedef = jax.tree.flatten(grads)
        if not leaves:
            return grads, state, {}
        flat = (leaves[0].astype(jnp.float32).reshape(-1)
                if len(leaves) == 1 else
                jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                 for l in leaves]))
        stacked = jax.lax.all_gather(flat, axis_name=axis_names, axis=0,
                                     tiled=False)
        red = self._reduce(stacked)
        out, off = [], 0
        for l in leaves:
            size = int(np.prod(l.shape))
            out.append(red[off:off + size].reshape(l.shape)
                       .astype(l.dtype))
            off += size
        return jax.tree.unflatten(treedef, out), state, {}

    def sync_per_leaf(self, grads, state, axis_names):
        """Reference path: one all-gather + reduce per pytree leaf."""
        def one(g):
            stacked = jax.lax.all_gather(g.astype(jnp.float32),
                                         axis_name=axis_names, axis=0,
                                         tiled=False)
            return self._reduce(stacked).astype(g.dtype)
        return jax.tree.map(one, grads), state, {}

    def comm_bytes(self, grads_like, n_workers):
        return int(_leaf_bytes(grads_like) * n_workers)


@dataclasses.dataclass(frozen=True)
class TrimmedMean(_RobustAggregate):
    """Tolerates up to ``trim`` byzantine workers per coordinate side."""
    name: str = "trimmed_mean"
    trim: int = 1

    def _reduce(self, stacked):
        return trimmed_mean(stacked, self.trim)


@dataclasses.dataclass(frozen=True)
class CoordinateMedian(_RobustAggregate):
    """Tolerates a byzantine minority (< W/2) per coordinate."""
    name: str = "coordinate_median"

    def _reduce(self, stacked):
        return coordinate_median(stacked)
