"""Recovery semantics for the serverless event runtime + robust
aggregation strategies for real JAX training.

Two recovery policies for a crashed worker, matching the designs the
paper compares:

  CheckpointRestore  the λML / MLLess model: the supervisor detects the
                     dead invocation after ``detection_s``, re-invokes
                     it (cold start + state load) and the worker
                     *replays* every round since its last checkpoint
                     (checkpoints every ``checkpoint_every`` rounds).
                     All surviving workers stall at the barrier until
                     the replay catches up — the stall is the measured
                     time-to-recover.

  PeerTakeover       SPIRT (arXiv 2309.14148): per-worker state lives in
                     the database, so nothing replays.  After
                     ``detection_s`` the survivors fetch the dead
                     worker's in-DB partition (one model-sized
                     transfer) and absorb its remaining minibatches;
                     the fleet continues with W-1 workers.

Robust aggregators — SPIRT's defense against poisoned gradients — are
ordinary :class:`~repro.core.strategies.Strategy` objects: every worker
all-gathers the fleet's gradients and reduces with a byzantine-robust
statistic instead of the mean.  They compose with
``faults.ByzantineGradients`` (corrupt-then-aggregate) and with SPIRT's
microbatch accumulation (``microbatches=K``), and are reachable through
``repro.core.get_strategy("trimmed_mean" | "coordinate_median")``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import Strategy, _leaf_bytes


# ---------------------------------------------------------------------------
# Recovery policies (consumed by runtime.EventRuntime)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    detection_s: float = 1.0


@dataclasses.dataclass(frozen=True)
class CheckpointRestore(RecoveryPolicy):
    """Re-invoke the crashed worker; replay since the last checkpoint."""
    checkpoint_every: int = 4          # rounds between checkpoints

    def replay_rounds(self, crashed_round: int) -> int:
        return crashed_round % self.checkpoint_every


@dataclasses.dataclass(frozen=True)
class PeerTakeover(RecoveryPolicy):
    """SPIRT-style: survivors absorb the dead worker's partition."""
    detection_s: float = 0.5


@dataclasses.dataclass
class RecoveryEvent:
    worker: int
    crash_time_s: float
    rejoined_time_s: float
    mode: str                          # "restore" | "takeover"

    @property
    def time_to_recover_s(self) -> float:
        return self.rejoined_time_s - self.crash_time_s


# ---------------------------------------------------------------------------
# Robust reduction statistics (pure functions, unit-testable on CPU)
# ---------------------------------------------------------------------------
def trimmed_mean(stacked, trim: int):
    """Mean over axis 0 after dropping the ``trim`` smallest and largest
    values per coordinate.  ``stacked``: [W, ...]; needs W > 2*trim."""
    W = stacked.shape[0]
    if W <= 2 * trim:
        raise ValueError(f"trimmed_mean needs W > 2*trim, got W={W}, "
                         f"trim={trim}")
    s = jnp.sort(stacked, axis=0)
    return jnp.mean(jax.lax.slice_in_dim(s, trim, W - trim, axis=0), axis=0)


def coordinate_median(stacked):
    """Per-coordinate median over axis 0 of a [W, ...] stack."""
    return jnp.median(stacked, axis=0)


# ---------------------------------------------------------------------------
# Robust aggregation strategies
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _RobustAggregate(Strategy):
    """all-gather + robust reduce.  Wire volume matches ParameterServer
    (every worker sees every gradient) — robustness is bought with the
    same W x byte blowup the paper charges the λML master with."""
    name: str = "robust"

    def _reduce(self, stacked):
        raise NotImplementedError

    def sync(self, grads, state, axis_names):
        def one(g):
            stacked = jax.lax.all_gather(g.astype(jnp.float32),
                                         axis_name=axis_names, axis=0,
                                         tiled=False)
            return self._reduce(stacked).astype(g.dtype)
        return jax.tree.map(one, grads), state, {}

    def comm_bytes(self, grads_like, n_workers):
        return int(_leaf_bytes(grads_like) * n_workers)


@dataclasses.dataclass(frozen=True)
class TrimmedMean(_RobustAggregate):
    """Tolerates up to ``trim`` byzantine workers per coordinate side."""
    name: str = "trimmed_mean"
    trim: int = 1

    def _reduce(self, stacked):
        return trimmed_mean(stacked, self.trim)


@dataclasses.dataclass(frozen=True)
class CoordinateMedian(_RobustAggregate):
    """Tolerates a byzantine minority (< W/2) per coordinate."""
    name: str = "coordinate_median"

    def _reduce(self, stacked):
        return coordinate_median(stacked)
