"""Recovery semantics for the serverless event runtime + robust
aggregation strategies for real JAX training.

Two recovery policies for a crashed worker, matching the designs the
paper compares:

  CheckpointRestore  the λML / MLLess model: the supervisor detects the
                     dead invocation after ``detection_s``, re-invokes
                     it (cold start + state load) and the worker
                     *replays* every round since its last checkpoint
                     (checkpoints every ``checkpoint_every`` rounds).
                     All surviving workers stall at the barrier until
                     the replay catches up — the stall is the measured
                     time-to-recover.

  PeerTakeover       SPIRT (arXiv 2309.14148): per-worker state lives in
                     the database, so nothing replays.  After
                     ``detection_s`` the survivors fetch the dead
                     worker's in-DB partition (one model-sized
                     transfer) and absorb its remaining minibatches;
                     the fleet continues with W-1 workers.

Robust aggregators — SPIRT's defense against poisoned gradients — are
ordinary :class:`~repro.core.strategies.Strategy` objects: every worker
all-gathers the fleet's gradients and reduces with a byzantine-robust
statistic instead of the mean.  They compose with
``faults.ByzantineGradients`` (corrupt-then-aggregate) and with SPIRT's
microbatch accumulation (``microbatches=K``), and are reachable through
``repro.core.get_strategy("trimmed_mean" | "coordinate_median" |
"krum" | "geometric_median")``.  The batched numpy twins the
vectorized adversarial sweep uses live in
``repro.serverless.adversarial`` (exactness pinned by
``tests/test_adversarial.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import Strategy, _leaf_bytes


# ---------------------------------------------------------------------------
# Recovery policies (consumed by runtime.EventRuntime)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    detection_s: float = 1.0

    def real_apply(self, trainer, worker: int):
        """Recover a *real* sharded training run (ISSUE 7).

        ``trainer`` is a :class:`repro.resilience.ResilientTrainer`
        whose worker ``worker`` was just lost mid-step.  The same policy
        object the event runtime scores drives the real harness, so the
        simulated and measured recovery claims share one definition.
        Returns the trainer's :class:`~repro.resilience.harness.
        RecoveryOutcome` (replayed steps, wall time, bytes moved).
        """
        raise NotImplementedError(
            f"{type(self).__name__} has no real-training recovery hook")


@dataclasses.dataclass(frozen=True)
class CheckpointRestore(RecoveryPolicy):
    """Re-invoke the crashed worker; replay since the last checkpoint."""
    checkpoint_every: int = 4          # rounds between checkpoints

    def replay_rounds(self, crashed_round: int) -> int:
        return crashed_round % self.checkpoint_every

    def real_apply(self, trainer, worker: int):
        return trainer.recover_restore(worker)


@dataclasses.dataclass(frozen=True)
class PeerTakeover(RecoveryPolicy):
    """SPIRT-style: survivors absorb the dead worker's partition."""
    detection_s: float = 0.5

    def real_apply(self, trainer, worker: int):
        return trainer.recover_takeover(worker)


@dataclasses.dataclass
class RecoveryEvent:
    worker: int
    crash_time_s: float
    rejoined_time_s: float
    mode: str                          # "restore" | "takeover"

    @property
    def time_to_recover_s(self) -> float:
        return self.rejoined_time_s - self.crash_time_s


# ---------------------------------------------------------------------------
# Robust reduction statistics (pure functions, unit-testable on CPU)
#
# Each statistic has a ``use_pallas`` switch routing the hot reduction
# through the tiled kernels in ``repro.kernels.robust_agg`` (Gram-
# accumulated Krum distances, masked/sorting-network trimmed mean,
# fused Weiszfeld step).  The default ``False`` keeps the original jnp
# formulations bit-exact — golden snapshots, BENCH_adversarial.json and
# the numpy twins in ``adversarial.py`` pin those paths; the kernels
# are parity-tested against them in ``tests/test_robust_agg.py``.
# ---------------------------------------------------------------------------
def trimmed_mean_sort(stacked, trim: int):
    """Reference implementation: full sort over the worker axis, then
    mean of the interior slice.  O(W log W) per coordinate; kept as the
    semantic reference for the ``trim=1`` fast path below."""
    W = stacked.shape[0]
    if W <= 2 * trim:
        raise ValueError(f"trimmed_mean needs W > 2*trim, got W={W}, "
                         f"trim={trim}")
    s = jnp.sort(stacked, axis=0)
    return jnp.mean(jax.lax.slice_in_dim(s, trim, W - trim, axis=0), axis=0)


def trimmed_mean(stacked, trim: int, use_pallas: bool = False):
    """Mean over axis 0 after dropping the ``trim`` smallest and largest
    values per coordinate.  ``stacked``: [W, ...]; needs W > 2*trim.

    ``trim=1`` — the common SPIRT setting — avoids the full sort by
    masking out one min and one max entry per coordinate and summing
    only the middle values: O(W) reductions instead of an O(W log W)
    sort.  NOT computed as ``(sum - min - max)/(W-2)``: a byzantine
    worker shipping a hugely scaled gradient would absorb the honest
    mass into the grand total and cancellation would destroy it on the
    subtraction — the exact attack this aggregator defends against
    (``tests/test_robust_agg.py`` checks equivalence against
    :func:`trimmed_mean_sort`, including that adversarial case).

    ``use_pallas`` routes through the D-tiled kernel
    (:func:`repro.kernels.robust_agg.trimmed_mean`, fp32 out)."""
    W = stacked.shape[0]
    if W <= 2 * trim:
        raise ValueError(f"trimmed_mean needs W > 2*trim, got W={W}, "
                         f"trim={trim}")
    if use_pallas:
        from repro.kernels import robust_agg
        return robust_agg.trimmed_mean(stacked, trim)
    if trim == 1:
        imin = jnp.argmin(stacked, axis=0)
        imax = jnp.argmax(stacked, axis=0)
        idx = jnp.arange(W).reshape((W,) + (1,) * (stacked.ndim - 1))
        keep = (idx != imin) & (idx != imax)
        mid = jnp.sum(stacked * keep, axis=0) / (W - 2)
        # argmin == argmax only when all W values at that coordinate
        # are equal; the mask then dropped a single entry, so patch in
        # the (trivially robust) common value instead
        return jnp.where(imin == imax, stacked[0], mid)
    return trimmed_mean_sort(stacked, trim)


def coordinate_median(stacked, use_pallas: bool = False):
    """Per-coordinate median over axis 0 of a [W, ...] stack."""
    if use_pallas:
        from repro.kernels import robust_agg
        return robust_agg.coordinate_median(stacked)
    return jnp.median(stacked, axis=0)


def krum(stacked, f: int = 1, m: int = 1, use_pallas: bool = False):
    """(Multi-)Krum (Blanchard et al., NeurIPS 2017) over axis 0 of a
    ``[W, ...]`` stack: score every row by the summed squared distance
    to its ``W - f - 2`` nearest neighbours (closer neighbourhoods =
    more corroborated), then average the ``m`` lowest-scoring rows
    (``m=1`` is classic Krum, ``m>1`` multi-Krum).  Selection needs an
    honest majority with margin: ``W >= 2f + 3``."""
    W = stacked.shape[0]
    if f < 0:
        raise ValueError(f"krum needs f >= 0, got f={f}")
    if W < 2 * f + 3:
        raise ValueError(
            f"krum needs W >= 2f + 3 to out-vote f byzantine rows, got "
            f"W={W}, f={f} (max feasible f is {(W - 3) // 2})")
    if not 1 <= m <= W:
        raise ValueError(f"krum needs 1 <= m <= W, got m={m}")
    flat = stacked.reshape(W, -1).astype(jnp.float32)
    if use_pallas:
        # Gram-accumulated [W, W] distances over D-tiles: never
        # materializes the [W, W, D] broadcast in HBM.
        from repro.kernels import robust_agg
        d = robust_agg.krum_pairwise(stacked)
    else:
        d = jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    ds = jnp.sort(d, axis=-1)                  # col 0 is self (0.0)
    scores = jnp.sum(ds[:, 1:W - f - 1], axis=-1)
    sel = jnp.argsort(scores, stable=True)[:m]
    return jnp.mean(stacked[sel].astype(jnp.float32), axis=0)


def geometric_median(stacked, tol: float = 1e-6, max_iter: int = 100,
                     use_pallas: bool = False):
    """Geometric median over axis 0 of a ``[W, ...]`` stack by
    Weiszfeld iteration — the point minimizing the summed Euclidean
    distance to every row; breakdown point (W-1)/2W.  Initialized at
    the coordinate median; iterates until the step shrinks below
    ``tol`` relative to the stack's largest row norm (tolerance-bounded)
    or ``max_iter`` passes.

    ``use_pallas`` swaps the loop body for the fused distance+reweight
    kernel (:func:`repro.kernels.robust_agg.weiszfeld_step`) with the
    per-row squared norms hoisted out of the loop."""
    if tol <= 0 or max_iter < 1:
        raise ValueError(f"geometric_median needs tol > 0 and "
                         f"max_iter >= 1, got tol={tol}, "
                         f"max_iter={max_iter}")
    W = stacked.shape[0]
    flat = stacked.reshape(W, -1).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.linalg.norm(flat, axis=-1)), 1e-12)
    floor = 1e-12 * scale
    if use_pallas:
        from repro.kernels import robust_agg
        sqnorms = jnp.sum(flat * flat, axis=1)

        def body(carry):
            z, _, i = carry
            z_new = robust_agg.weiszfeld_step(flat, z, floor,
                                              row_sqnorms=sqnorms)
            return z_new, jnp.linalg.norm(z_new - z), i + 1
    else:
        def body(carry):
            z, _, i = carry
            dist = jnp.linalg.norm(flat - z[None, :], axis=-1)
            w = 1.0 / jnp.maximum(dist, floor)
            z_new = jnp.sum(w[:, None] * flat, axis=0) / jnp.sum(w)
            return z_new, jnp.linalg.norm(z_new - z), i + 1

    def cond(carry):
        _, step, i = carry
        return jnp.logical_and(i < max_iter, step > tol * scale)

    z0 = jnp.median(flat, axis=0)
    carry0 = (z0, jnp.asarray(jnp.inf, jnp.float32),
              jnp.asarray(0, jnp.int32))
    z, _, _ = jax.lax.while_loop(cond, body, carry0)
    return z.reshape(stacked.shape[1:])


# ---------------------------------------------------------------------------
# Robust aggregation strategies
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _RobustAggregate(Strategy):
    """all-gather + robust reduce.  Wire volume matches ParameterServer
    (every worker sees every gradient) — robustness is bought with the
    same W x byte blowup the paper charges the λML master with.

    The gradient pytree is flattened into ONE contiguous fp32 buffer
    before the all-gather: a model with L leaves dispatches a single
    collective + a single robust reduction instead of L of each
    (per-leaf dispatch was the hot cost at SPIRT's per-minibatch sync
    cadence).  ``sync_per_leaf`` keeps the original per-leaf path as
    the semantic reference; ``tests/test_robust_agg.py`` checks the
    two agree.

    ``use_pallas`` selects the tiled kernels in
    ``repro.kernels.robust_agg`` for the reduction.  ``None`` (the
    default) auto-detects: kernels on TPU, the original jnp
    formulations elsewhere — so CPU golden snapshots and
    BENCH_adversarial.json stay bit-identical.  ``True``/``False``
    force the choice (parity tests pin the two paths against each
    other)."""
    name: str = "robust"
    use_pallas: Optional[bool] = None

    def _kernels_enabled(self) -> bool:
        if self.use_pallas is None:
            from repro.kernels.ops import default_interpret
            return not default_interpret()      # kernels only on TPU
        return bool(self.use_pallas)

    def _reduce(self, stacked):
        raise NotImplementedError

    def sync(self, grads, state, axis_names):
        leaves, treedef = jax.tree.flatten(grads)
        if not leaves:
            return grads, state, {}
        flat = (leaves[0].astype(jnp.float32).reshape(-1)
                if len(leaves) == 1 else
                jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                 for l in leaves]))
        stacked = jax.lax.all_gather(flat, axis_name=axis_names, axis=0,
                                     tiled=False)
        red = self._reduce(stacked)
        out, off = [], 0
        for l in leaves:
            size = int(np.prod(l.shape))
            out.append(red[off:off + size].reshape(l.shape)
                       .astype(l.dtype))
            off += size
        return jax.tree.unflatten(treedef, out), state, {}

    def sync_per_leaf(self, grads, state, axis_names):
        """Reference path: one all-gather + reduce per pytree leaf."""
        def one(g):
            stacked = jax.lax.all_gather(g.astype(jnp.float32),
                                         axis_name=axis_names, axis=0,
                                         tiled=False)
            return self._reduce(stacked).astype(g.dtype)
        return jax.tree.map(one, grads), state, {}

    def comm_bytes(self, grads_like, n_workers):
        return int(_leaf_bytes(grads_like) * n_workers)


@dataclasses.dataclass(frozen=True)
class TrimmedMean(_RobustAggregate):
    """Tolerates up to ``trim`` byzantine workers per coordinate side."""
    name: str = "trimmed_mean"
    trim: int = 1

    def _reduce(self, stacked):
        return trimmed_mean(stacked, self.trim,
                            use_pallas=self._kernels_enabled())


@dataclasses.dataclass(frozen=True)
class CoordinateMedian(_RobustAggregate):
    """Tolerates a byzantine minority (< W/2) per coordinate."""
    name: str = "coordinate_median"

    def _reduce(self, stacked):
        return coordinate_median(stacked,
                                 use_pallas=self._kernels_enabled())


@dataclasses.dataclass(frozen=True)
class Krum(_RobustAggregate):
    """(Multi-)Krum selection: tolerates ``f`` byzantine workers given
    ``W >= 2f + 3``; ``m`` selects multi-Krum averaging breadth.
    Bounds are validated eagerly where possible (``f``/``m`` here, the
    fleet-size condition when the first gradient stack arrives).

    NOTE: Krum is a JOINT rule over the whole flattened gradient — the
    flat-buffer ``sync`` (one selection for the full model) is the
    semantics; ``sync_per_leaf`` would select per leaf independently,
    a different (weaker) statistic."""
    name: str = "krum"
    f: int = 1
    m: int = 1

    def __post_init__(self):
        if self.f < 0:
            raise ValueError(f"krum needs f >= 0, got f={self.f}")
        if self.m < 1:
            raise ValueError(f"krum needs m >= 1, got m={self.m}")

    def _reduce(self, stacked):
        return krum(stacked, self.f, self.m,
                    use_pallas=self._kernels_enabled())


@dataclasses.dataclass(frozen=True)
class GeometricMedian(_RobustAggregate):
    """Weiszfeld geometric median: tolerates any byzantine minority
    (< W/2) regardless of attack geometry, at the price of an
    iterative reduce (``max_iter`` capped, ``tol``-bounded).  Like
    Krum, a joint rule — the flat-buffer ``sync`` is the semantics."""
    name: str = "geometric_median"
    tol: float = 1e-6
    max_iter: int = 100

    def __post_init__(self):
        if self.tol <= 0 or self.max_iter < 1:
            raise ValueError(
                f"geometric_median needs tol > 0 and max_iter >= 1, "
                f"got tol={self.tol}, max_iter={self.max_iter}")

    def _reduce(self, stacked):
        return geometric_median(stacked, self.tol, self.max_iter,
                                use_pallas=self._kernels_enabled())
