"""Empirical fault traces: measured cold-start / straggler tails.

The synthetic :meth:`FaultPlan.random` draws Poisson-thinned events with
uniform magnitudes, but the serverless-training literature the paper
builds on measures *heavy* tails: Towards Demystifying Serverless
Machine Learning Training (arXiv 2105.07806) reports cold-start
latencies whose p95 is an order of magnitude above the median once the
deployment package carries an ML runtime, and straggler slowdowns with
a long right tail from noisy-neighbour vCPU throttling.  This module is
the trace-driven replay subsystem the ROADMAP queued: a :class:`Trace`
holds empirical samples of those distributions, and
:meth:`repro.serverless.faults.FaultPlan.from_trace` resamples them
into replayable per-worker fault plans via inverse CDF over seeded
sub-streams, so every (trace, seed) pair is bit-reproducible.

The same machinery backs the *serving* side: :class:`RequestTrace`
holds measured request-level traffic (inter-arrival gaps, prompt and
decode token counts — the bundled default digitizes the Splitwise /
Azure LLM-inference distributions, arXiv 2311.18677) and
``repro.serving.workload.Workload`` resamples it into seeded open-loop
request streams for the continuous-batching fleet simulator.

Trace schema
------------
JSON — one object with the three sample arrays plus the per-epoch
straggler occurrence probability::

    {
      "name": "lambda-2105.07806",
      "straggler_prob": 0.12,
      "cold_start_s": [1.7, 1.9, ...],        # absolute seconds
      "straggler_slowdown": [1.3, 1.5, ...],  # multiplicative, >= 1
      "straggler_duration_s": [4.0, 6.0, ...] # window length, seconds
    }

CSV — long format with header ``field,value``; one row per sample, the
``field`` column naming one of the three arrays above, plus a single
``straggler_prob`` row::

    field,value
    cold_start_s,1.7
    cold_start_s,1.9
    straggler_slowdown,1.3
    straggler_duration_s,4.0
    straggler_prob,0.12

Semantics: ``cold_start_s`` samples are *absolute* measured cold-start
latencies (a worker's extra over the simulator's plan-level base is
``max(0, sample - base)``, resolved by ``FaultPlan.from_trace`` so the
base is never double counted); ``straggler_slowdown`` multiplies
compute time inside a window whose length is drawn from
``straggler_duration_s``; ``straggler_prob`` is the probability that a
given worker straggles at all during one epoch.

Bundled default
---------------
:func:`lambda_default` ships a Lambda-like trace digitized from the
measurements reported in arXiv 2105.07806 (cold-start §5.2 /
communication-straggler discussion): ~2 s warm-package median cold
start with a heavy right tail to ~30 s (large ML deployment packages +
concurrent-invocation bursts), straggler slowdowns 1.3-7.5x with
minutes-long windows, ~12% of workers straggling per epoch.  The
digitization is a quantile-grid approximation of the published curves,
not a copy of raw data — it exists so the Pareto benchmarks can compare
measured-tail behaviour against the synthetic Poisson defaults without
network access.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import math
from typing import Optional, Sequence, Tuple

import numpy as np

_FIELDS = ("cold_start_s", "straggler_slowdown", "straggler_duration_s")


# ---------------------------------------------------------------------------
# Shared empirical-distribution machinery (fault + request traces)
# ---------------------------------------------------------------------------
def _sorted_samples(owner: str, field: str, values) -> Tuple[float, ...]:
    """Validate and sort one sample array (finite, >= 0)."""
    vals = tuple(sorted(float(v) for v in values))
    if any(not math.isfinite(v) or v < 0 for v in vals):
        raise ValueError(f"{owner}.{field}: samples must be finite and "
                         f">= 0, got {vals}")
    return vals


def _inverse_cdf(samples: Tuple[float, ...], u, *, trace_name: str,
                 field: str):
    """Inverse empirical CDF: map uniforms ``u`` in [0, 1) to observed
    samples (pure bootstrap — no interpolation, so every resampled value
    is a member of the trace's support).  u is clipped at BOTH ends: a
    negative u must not wrap to the top of the distribution through
    negative indexing."""
    s = np.asarray(samples, float)             # sorted tuple
    if s.size == 0:
        raise ValueError(f"trace {trace_name!r}: no {field} samples")
    idx = np.clip((np.asarray(u) * s.size).astype(int), 0, s.size - 1)
    return s[idx]


def _long_csv_fields(path: str, field_names: Tuple[str, ...],
                     scalars: Tuple[str, ...] = ()) -> Tuple[dict, dict]:
    """Parse a long-format ``field,value`` CSV into sample lists (one
    per entry of ``field_names``) plus scalar rows (``scalars``)."""
    fields = {f: [] for f in field_names}
    scalar_vals: dict = {}
    with open(path) as f:
        for row in csv.DictReader(f):
            key, val = row["field"], float(row["value"])
            if key in scalars:
                scalar_vals[key] = val
            elif key in fields:
                fields[key].append(val)
            else:
                raise ValueError(f"unknown trace field {key!r}")
    return fields, scalar_vals


@dataclasses.dataclass(frozen=True)
class Trace:
    """Empirical distributions for trace-driven fault replay.

    Samples are stored sorted (the inverse CDF is then a single index),
    as plain float tuples so a ``Trace`` hashes, compares, and pickles
    across the sweep engine's spawned worker processes.
    """
    cold_start_s: Tuple[float, ...]
    straggler_slowdown: Tuple[float, ...] = ()
    straggler_duration_s: Tuple[float, ...] = ()
    straggler_prob: float = 0.0
    name: str = "custom"

    def __post_init__(self):
        for field in _FIELDS:
            object.__setattr__(self, field, _sorted_samples(
                "Trace", field, getattr(self, field)))
        if not self.cold_start_s:
            raise ValueError("cold_start_s needs at least one sample")
        if not 0.0 <= self.straggler_prob <= 1.0:
            raise ValueError(f"straggler_prob must be a probability, "
                             f"got {self.straggler_prob}")
        if self.straggler_prob > 0:
            if not (self.straggler_slowdown and self.straggler_duration_s):
                raise ValueError("straggler_prob > 0 needs slowdown and "
                                 "duration samples")
            if self.straggler_slowdown[0] < 1.0:
                raise ValueError("straggler slowdowns are multiplicative "
                                 "and must be >= 1")

    # ---------------------------------------------------------- sampling
    def sample(self, field: str, u):
        """Inverse empirical CDF over ``field`` (see
        :func:`_inverse_cdf`: bootstrap resampling, both ends of u
        clipped)."""
        if field not in _FIELDS:
            raise KeyError(field)
        return _inverse_cdf(getattr(self, field), u,
                            trace_name=self.name, field=field)

    def support(self, field: str) -> Tuple[float, float]:
        vals = getattr(self, field)
        return (vals[0], vals[-1])

    def quantile(self, field: str, q: float) -> float:
        return float(self.sample(field, q))

    # ---------------------------------------------------------- file I/O
    def to_json(self, path: str) -> None:
        payload = dict(name=self.name, straggler_prob=self.straggler_prob,
                       **{f: list(getattr(self, f)) for f in _FIELDS})
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)

    @classmethod
    def from_json(cls, path: str) -> "Trace":
        with open(path) as f:
            payload = json.load(f)
        unknown = set(payload) - set(_FIELDS) - {"name", "straggler_prob"}
        if unknown:
            raise ValueError(f"unknown trace fields: {sorted(unknown)}")
        return cls(**payload)

    @classmethod
    def from_csv(cls, path: str, *, name: Optional[str] = None) -> "Trace":
        """Long-format ``field,value`` CSV (see module docstring)."""
        fields, scalars = _long_csv_fields(path, _FIELDS,
                                           scalars=("straggler_prob",))
        return cls(name=name or path,
                   straggler_prob=scalars.get("straggler_prob", 0.0),
                   **{k: tuple(v) for k, v in fields.items()})


# ---------------------------------------------------------------------------
# Bundled Lambda-like default (see module docstring for provenance)
# ---------------------------------------------------------------------------
_LAMBDA_COLD_START_S = (
    1.7, 1.9, 2.0, 2.1, 2.2, 2.3, 2.4, 2.5, 2.6, 2.8,
    3.0, 3.3, 3.7, 4.2, 5.0, 6.3, 8.5, 12.0, 19.0, 31.0)
_LAMBDA_STRAGGLER_SLOWDOWN = (
    1.3, 1.5, 1.7, 1.9, 2.2, 2.6, 3.2, 4.1, 5.5, 7.5)
_LAMBDA_STRAGGLER_DURATION_S = (
    4.0, 6.0, 8.0, 11.0, 15.0, 21.0, 30.0, 45.0, 70.0, 110.0)

LAMBDA_2105_07806 = Trace(
    name="lambda-2105.07806",
    cold_start_s=_LAMBDA_COLD_START_S,
    straggler_slowdown=_LAMBDA_STRAGGLER_SLOWDOWN,
    straggler_duration_s=_LAMBDA_STRAGGLER_DURATION_S,
    straggler_prob=0.12)


def lambda_default() -> Trace:
    """The bundled Lambda-like trace digitized from arXiv 2105.07806."""
    return LAMBDA_2105_07806


# ---------------------------------------------------------------------------
# Request traces: the serving twin of the fault trace
# ---------------------------------------------------------------------------
_REQUEST_FIELDS = ("inter_arrival_s", "prompt_tokens", "decode_tokens")


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """Empirical request-level distributions for serving workloads.

    The inference-side twin of :class:`Trace`: where the fault trace
    holds measured cold-start/straggler tails, a request trace holds
    measured *traffic* — request inter-arrival gaps plus prompt and
    decode token counts — and ``repro.serving.workload.Workload``
    resamples it into seeded, replayable open-loop request streams by
    the same inverse-CDF-over-sub-streams discipline.

    Schema mirrors :class:`Trace`: JSON is one object with the three
    sample arrays plus ``name``; CSV is long-format ``field,value``
    rows.  ``inter_arrival_s`` samples are absolute gaps in seconds
    (their mean is the trace's native arrival rate — ``Workload`` can
    rescale them to sweep rates without touching the burstiness shape);
    token counts are positive integers.
    """
    inter_arrival_s: Tuple[float, ...]
    prompt_tokens: Tuple[float, ...] = ()
    decode_tokens: Tuple[float, ...] = ()
    name: str = "custom"

    def __post_init__(self):
        for field in _REQUEST_FIELDS:
            object.__setattr__(self, field, _sorted_samples(
                "RequestTrace", field, getattr(self, field)))
        if not self.inter_arrival_s:
            raise ValueError("inter_arrival_s needs at least one sample")
        for field in ("prompt_tokens", "decode_tokens"):
            vals = getattr(self, field)
            if any(v < 1 or v != int(v) for v in vals):
                raise ValueError(f"{field}: token counts must be "
                                 f"positive integers, got {vals}")

    # ---------------------------------------------------------- sampling
    def sample(self, field: str, u):
        """Inverse empirical CDF over ``field`` (bootstrap resampling;
        see :func:`_inverse_cdf`)."""
        if field not in _REQUEST_FIELDS:
            raise KeyError(field)
        return _inverse_cdf(getattr(self, field), u,
                            trace_name=self.name, field=field)

    def support(self, field: str) -> Tuple[float, float]:
        vals = getattr(self, field)
        return (vals[0], vals[-1])

    def quantile(self, field: str, q: float) -> float:
        return float(self.sample(field, q))

    def mean_rate_rps(self) -> float:
        """The trace's native arrival rate (1 / mean inter-arrival)."""
        return 1.0 / float(np.mean(self.inter_arrival_s))

    # ---------------------------------------------------------- file I/O
    def to_json(self, path: str) -> None:
        payload = dict(name=self.name,
                       **{f: list(getattr(self, f))
                          for f in _REQUEST_FIELDS})
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)

    @classmethod
    def from_json(cls, path: str) -> "RequestTrace":
        with open(path) as f:
            payload = json.load(f)
        unknown = set(payload) - set(_REQUEST_FIELDS) - {"name"}
        if unknown:
            raise ValueError(f"unknown trace fields: {sorted(unknown)}")
        return cls(**payload)

    @classmethod
    def from_csv(cls, path: str, *,
                 name: Optional[str] = None) -> "RequestTrace":
        """Long-format ``field,value`` CSV (same shape as
        :meth:`Trace.from_csv`)."""
        fields, _ = _long_csv_fields(path, _REQUEST_FIELDS)
        return cls(name=name or path,
                   **{k: tuple(v) for k, v in fields.items()})


# ---------------------------------------------------------------------------
# Bundled default request trace.  Quantile-grid approximation of the
# production LLM-inference traffic shape reported by Splitwise (arXiv
# 2311.18677, Azure conversation workload): prompts with a ~1k-token
# median and a long right tail, decode lengths with a ~100-token median
# and a heavy tail to ~1k, and bursty arrivals (inter-arrival p95 an
# order of magnitude above the median — NOT exponential).  Digitized
# from the published distribution curves, not copied from raw data; it
# exists so the serving benchmarks can compare measured-burstiness
# behaviour against Poisson arrivals without network access.  The
# native rate is ~1 req/s; ``Workload.with_rate`` rescales gaps to any
# target rate while preserving the burstiness shape.
# ---------------------------------------------------------------------------
_LLM_INTER_ARRIVAL_S = (
    0.02, 0.05, 0.09, 0.14, 0.20, 0.27, 0.35, 0.44, 0.55, 0.68,
    0.83, 1.00, 1.20, 1.45, 1.80, 2.30, 3.10, 4.50, 7.00, 12.0)
_LLM_PROMPT_TOKENS = (
    64, 128, 256, 384, 512, 640, 768, 896, 1024, 1152,
    1280, 1472, 1664, 1920, 2304, 2816, 3584, 4608, 6144, 8192)
_LLM_DECODE_TOKENS = (
    8, 16, 24, 36, 48, 64, 80, 96, 112, 128,
    148, 172, 200, 240, 296, 376, 496, 672, 896, 1024)

AZURE_LLM_2311_18677 = RequestTrace(
    name="azure-llm-2311.18677",
    inter_arrival_s=_LLM_INTER_ARRIVAL_S,
    prompt_tokens=_LLM_PROMPT_TOKENS,
    decode_tokens=_LLM_DECODE_TOKENS)


def request_default() -> RequestTrace:
    """The bundled LLM-serving request trace digitized from the
    Splitwise (arXiv 2311.18677) conversation-workload distributions."""
    return AZURE_LLM_2311_18677
