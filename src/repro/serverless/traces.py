"""Empirical fault traces: measured cold-start / straggler tails.

The synthetic :meth:`FaultPlan.random` draws Poisson-thinned events with
uniform magnitudes, but the serverless-training literature the paper
builds on measures *heavy* tails: Towards Demystifying Serverless
Machine Learning Training (arXiv 2105.07806) reports cold-start
latencies whose p95 is an order of magnitude above the median once the
deployment package carries an ML runtime, and straggler slowdowns with
a long right tail from noisy-neighbour vCPU throttling.  This module is
the trace-driven replay subsystem the ROADMAP queued: a :class:`Trace`
holds empirical samples of those distributions, and
:meth:`repro.serverless.faults.FaultPlan.from_trace` resamples them
into replayable per-worker fault plans via inverse CDF over seeded
sub-streams, so every (trace, seed) pair is bit-reproducible.

Trace schema
------------
JSON — one object with the three sample arrays plus the per-epoch
straggler occurrence probability::

    {
      "name": "lambda-2105.07806",
      "straggler_prob": 0.12,
      "cold_start_s": [1.7, 1.9, ...],        # absolute seconds
      "straggler_slowdown": [1.3, 1.5, ...],  # multiplicative, >= 1
      "straggler_duration_s": [4.0, 6.0, ...] # window length, seconds
    }

CSV — long format with header ``field,value``; one row per sample, the
``field`` column naming one of the three arrays above, plus a single
``straggler_prob`` row::

    field,value
    cold_start_s,1.7
    cold_start_s,1.9
    straggler_slowdown,1.3
    straggler_duration_s,4.0
    straggler_prob,0.12

Semantics: ``cold_start_s`` samples are *absolute* measured cold-start
latencies (a worker's extra over the simulator's plan-level base is
``max(0, sample - base)``, resolved by ``FaultPlan.from_trace`` so the
base is never double counted); ``straggler_slowdown`` multiplies
compute time inside a window whose length is drawn from
``straggler_duration_s``; ``straggler_prob`` is the probability that a
given worker straggles at all during one epoch.

Bundled default
---------------
:func:`lambda_default` ships a Lambda-like trace digitized from the
measurements reported in arXiv 2105.07806 (cold-start §5.2 /
communication-straggler discussion): ~2 s warm-package median cold
start with a heavy right tail to ~30 s (large ML deployment packages +
concurrent-invocation bursts), straggler slowdowns 1.3-7.5x with
minutes-long windows, ~12% of workers straggling per epoch.  The
digitization is a quantile-grid approximation of the published curves,
not a copy of raw data — it exists so the Pareto benchmarks can compare
measured-tail behaviour against the synthetic Poisson defaults without
network access.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import math
from typing import Optional, Sequence, Tuple

import numpy as np

_FIELDS = ("cold_start_s", "straggler_slowdown", "straggler_duration_s")


@dataclasses.dataclass(frozen=True)
class Trace:
    """Empirical distributions for trace-driven fault replay.

    Samples are stored sorted (the inverse CDF is then a single index),
    as plain float tuples so a ``Trace`` hashes, compares, and pickles
    across the sweep engine's spawned worker processes.
    """
    cold_start_s: Tuple[float, ...]
    straggler_slowdown: Tuple[float, ...] = ()
    straggler_duration_s: Tuple[float, ...] = ()
    straggler_prob: float = 0.0
    name: str = "custom"

    def __post_init__(self):
        for field in _FIELDS:
            vals = tuple(sorted(float(v) for v in getattr(self, field)))
            if any(not math.isfinite(v) or v < 0 for v in vals):
                raise ValueError(f"{field}: samples must be finite and "
                                 f">= 0, got {vals}")
            object.__setattr__(self, field, vals)
        if not self.cold_start_s:
            raise ValueError("cold_start_s needs at least one sample")
        if not 0.0 <= self.straggler_prob <= 1.0:
            raise ValueError(f"straggler_prob must be a probability, "
                             f"got {self.straggler_prob}")
        if self.straggler_prob > 0:
            if not (self.straggler_slowdown and self.straggler_duration_s):
                raise ValueError("straggler_prob > 0 needs slowdown and "
                                 "duration samples")
            if self.straggler_slowdown[0] < 1.0:
                raise ValueError("straggler slowdowns are multiplicative "
                                 "and must be >= 1")

    # ---------------------------------------------------------- sampling
    def sample(self, field: str, u):
        """Inverse empirical CDF: map uniforms ``u`` in [0, 1) to
        observed samples (pure bootstrap — no interpolation, so every
        resampled value is a member of the trace's support)."""
        if field not in _FIELDS:
            raise KeyError(field)
        s = np.asarray(getattr(self, field), float)   # sorted tuple
        if s.size == 0:
            raise ValueError(f"trace {self.name!r}: no {field} samples")
        # clip both ends: u < 0 must not wrap to the top of the
        # distribution through negative indexing
        idx = np.clip((np.asarray(u) * s.size).astype(int), 0, s.size - 1)
        return s[idx]

    def support(self, field: str) -> Tuple[float, float]:
        vals = getattr(self, field)
        return (vals[0], vals[-1])

    def quantile(self, field: str, q: float) -> float:
        return float(self.sample(field, q))

    # ---------------------------------------------------------- file I/O
    def to_json(self, path: str) -> None:
        payload = dict(name=self.name, straggler_prob=self.straggler_prob,
                       **{f: list(getattr(self, f)) for f in _FIELDS})
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)

    @classmethod
    def from_json(cls, path: str) -> "Trace":
        with open(path) as f:
            payload = json.load(f)
        unknown = set(payload) - set(_FIELDS) - {"name", "straggler_prob"}
        if unknown:
            raise ValueError(f"unknown trace fields: {sorted(unknown)}")
        return cls(**payload)

    @classmethod
    def from_csv(cls, path: str, *, name: Optional[str] = None) -> "Trace":
        """Long-format ``field,value`` CSV (see module docstring)."""
        fields = {f: [] for f in _FIELDS}
        prob = 0.0
        with open(path) as f:
            for row in csv.DictReader(f):
                key, val = row["field"], float(row["value"])
                if key == "straggler_prob":
                    prob = val
                elif key in fields:
                    fields[key].append(val)
                else:
                    raise ValueError(f"unknown trace field {key!r}")
        return cls(name=name or path, straggler_prob=prob,
                   **{k: tuple(v) for k, v in fields.items()})


# ---------------------------------------------------------------------------
# Bundled Lambda-like default (see module docstring for provenance)
# ---------------------------------------------------------------------------
_LAMBDA_COLD_START_S = (
    1.7, 1.9, 2.0, 2.1, 2.2, 2.3, 2.4, 2.5, 2.6, 2.8,
    3.0, 3.3, 3.7, 4.2, 5.0, 6.3, 8.5, 12.0, 19.0, 31.0)
_LAMBDA_STRAGGLER_SLOWDOWN = (
    1.3, 1.5, 1.7, 1.9, 2.2, 2.6, 3.2, 4.1, 5.5, 7.5)
_LAMBDA_STRAGGLER_DURATION_S = (
    4.0, 6.0, 8.0, 11.0, 15.0, 21.0, 30.0, 45.0, 70.0, 110.0)

LAMBDA_2105_07806 = Trace(
    name="lambda-2105.07806",
    cold_start_s=_LAMBDA_COLD_START_S,
    straggler_slowdown=_LAMBDA_STRAGGLER_SLOWDOWN,
    straggler_duration_s=_LAMBDA_STRAGGLER_DURATION_S,
    straggler_prob=0.12)


def lambda_default() -> Trace:
    """The bundled Lambda-like trace digitized from arXiv 2105.07806."""
    return LAMBDA_2105_07806
