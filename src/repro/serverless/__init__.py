from repro.serverless.simulator import (  # noqa: F401
    Channel, EpochReport, PAPER_TABLE2, REDIS, S3, ServerlessSetup,
    paper_cost_check, simulate_epoch,
)
