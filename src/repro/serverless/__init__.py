from repro.serverless.archs import (  # noqa: F401
    ArchSpec, get_arch, list_archs, paper_archs, register_arch,
    unregister_arch,
)
from repro.serverless.adversarial import (  # noqa: F401
    AttackSpec, SIM_AGGREGATORS, byzantine_fractions, get_attack,
    list_attacks, register_attack, sim_aggregator_max_f,
    unregister_attack,
)
from repro.serverless.simulator import (  # noqa: F401
    ARCHS, Channel, EpochReport, PAPER_TABLE2, REDIS, RoundPlan, S3,
    ServerlessSetup, paper_cost_check, round_plan, simulate_epoch,
)
from repro.serverless.runtime import (  # noqa: F401
    EventRuntime, RuntimeReport, default_recovery, resolve_recovery,
    run_event_epoch,
)
from repro.serverless.faults import (  # noqa: F401
    ByzantineGradients, ByzantineWorker, ColdStartStorm, FaultPlan,
    Straggler, WorkerCrash,
)
from repro.serverless.recovery import (  # noqa: F401
    CheckpointRestore, CoordinateMedian, GeometricMedian, Krum,
    PeerTakeover, RecoveryEvent, RecoveryPolicy, TrimmedMean,
    coordinate_median, geometric_median, krum, trimmed_mean,
    trimmed_mean_sort,
)
from repro.serverless.autoscale import (  # noqa: F401
    ReactiveAutoscaler, ScheduledScaler,
)
from repro.serverless.traces import (  # noqa: F401
    AZURE_LLM_2311_18677, LAMBDA_2105_07806, RequestTrace, Trace,
    lambda_default, request_default,
)
from repro.serverless.sweep import (  # noqa: F401
    AdversarialCell, AdversarialGrid, AnalyticSweep, EventPointStats,
    EventSweepPoint, FaultRates, SweepGrid, adversarial_curve,
    adversarial_sweep, iter_grid, knee_point, pareto_front,
    ram_scaled_compute, scalar_sweep, sweep_analytic, sweep_events,
)
