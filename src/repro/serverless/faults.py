"""Fault taxonomy + seeded injection for the serverless event runtime.

Four fault classes, matching the failure modes the paper's
fault-tolerance comparison (and SPIRT's §5 / MLLess's §6 evaluations)
is about:

  WorkerCrash     a Lambda invocation dies mid-epoch; its in-flight
                  round is lost.  What happens next is the recovery
                  policy's job (``recovery.py``): checkpoint-restore
                  re-invokes and replays, SPIRT peer takeover reassigns
                  the partition because state lives in the database.
  Straggler       a worker computes ``slowdown`` x slower inside a time
                  window (noisy neighbour / throttled vCPU).  Under
                  synchronous training every barrier inherits the
                  straggler's finish time.
  ColdStartStorm  a fraction of the fleet pays ``extra_s`` additional
                  cold start (concurrent-invocation burst, arXiv
                  2105.07806's dominant serverless overhead).
  ByzantineWorker a worker ships poisoned gradients.  Timing is
                  unaffected; correctness bookkeeping flows through
                  the runtime's robust-aggregation accounting, and the
                  *real-training* analogue is :class:`ByzantineGradients`
                  below — which now corrupts via any attack model in
                  the ``repro.serverless.adversarial`` registry
                  (sign_flip / scale / gaussian_noise /
                  little_is_enough / zero) instead of only scaling.

``FaultPlan`` bundles specs; ``FaultPlan.random`` draws a reproducible
plan from per-class rates, and ``FaultPlan.from_trace`` resamples one
from measured empirical distributions (``traces.py``) — either way
every experiment is replayable from (seed, rates | trace).

All randomness flows through *disjoint per-class sub-streams* derived
from the plan seed (``np.random.SeedSequence`` spawn keys): crash,
straggler, byzantine, storm, storm-victim, and trace-resampling draws
each own a stream, so no fault class's outcome can perturb — or
correlate with — another's.  (The original implementation re-seeded one
``RandomState(seed)`` for everything, which made storm victims a
function of the same uniforms that decided which workers crashed.)
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Optional, Tuple

import numpy as np

if TYPE_CHECKING:                      # avoid a runtime import cycle
    from repro.serverless.traces import Trace

# per-class sub-stream keys; appending is fine, reordering breaks replay
(_STREAM_CRASH, _STREAM_STRAGGLER, _STREAM_BYZANTINE, _STREAM_STORM,
 _STREAM_STORM_VICTIMS, _STREAM_COLD_START,
 _STREAM_TRACE_STRAGGLER) = range(7)


def _stream_rng(seed: int, stream: int) -> np.random.Generator:
    """Seeded generator on a sub-stream statistically disjoint from
    every other (seed, stream) pair."""
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(stream,)))


@dataclasses.dataclass(frozen=True)
class WorkerCrash:
    worker: int
    time_s: float


@dataclasses.dataclass(frozen=True)
class Straggler:
    worker: int
    slowdown: float = 4.0
    start_s: float = 0.0
    end_s: float = math.inf


@dataclasses.dataclass(frozen=True)
class ColdStartStorm:
    extra_s: float = 10.0
    fraction: float = 0.5


@dataclasses.dataclass(frozen=True)
class ByzantineWorker:
    worker: int
    scale: float = -10.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, fully-resolved set of faults for one epoch run.

    ``cold_start_extra_s`` is the per-worker cold-start heterogeneity
    vector (index = worker id, additive seconds on top of the plan's
    base cold start) that trace replay resamples; workers beyond its
    length — e.g. autoscaled joiners — pay no extra.
    """
    crashes: Tuple[WorkerCrash, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()
    storm: Optional[ColdStartStorm] = None
    byzantine: Tuple[ByzantineWorker, ...] = ()
    seed: int = 0
    cold_start_extra_s: Tuple[float, ...] = ()

    def storm_victims(self, n_workers: int) -> Tuple[int, ...]:
        """Seeded choice of which workers the cold-start storm hits.

        Drawn from a sub-stream of its own, so the victim set is
        independent of every other fault class's draws; ``fraction=0``
        hits nobody and ``fraction >= 1`` hits the whole fleet (k is
        clamped to [0, n_workers])."""
        if self.storm is None:
            return ()
        k = min(max(int(round(self.storm.fraction * n_workers)), 0),
                n_workers)
        if k == 0:
            return ()
        rng = _stream_rng(self.seed, _STREAM_STORM_VICTIMS)
        return tuple(sorted(
            int(v) for v in rng.choice(n_workers, size=k, replace=False)))

    def cold_extra(self, worker: int) -> float:
        """Per-worker additive cold-start seconds (trace replay)."""
        v = self.cold_start_extra_s
        return v[worker] if 0 <= worker < len(v) else 0.0

    def slowdown(self, worker: int, t: float) -> float:
        f = 1.0
        for s in self.stragglers:
            if s.worker == worker and s.start_s <= t < s.end_s:
                f = max(f, s.slowdown)
        return f

    def byzantine_workers(self) -> Tuple[int, ...]:
        return tuple(sorted({b.worker for b in self.byzantine}))

    @classmethod
    def random(cls, *, seed: int, n_workers: int, horizon_s: float,
               crash_rate: float = 0.0, straggler_rate: float = 0.0,
               byzantine_fraction: float = 0.0,
               storm_prob: float = 0.0) -> "FaultPlan":
        """Draw a reproducible plan.  Rates are expected events per
        worker per epoch (Poisson-thinned to at most one per worker);
        each fault class draws from its own (seed, class) sub-stream,
        so e.g. raising the straggler rate never shifts crash times."""
        crashes = _draw_crashes(seed, n_workers, horizon_s, crash_rate)
        rng = _stream_rng(seed, _STREAM_STRAGGLER)
        stragglers = []
        for w in range(n_workers):
            if rng.random() < straggler_rate:
                t0 = float(rng.uniform(0.0, 0.7) * horizon_s)
                stragglers.append(Straggler(
                    w, slowdown=float(rng.uniform(2.0, 6.0)),
                    start_s=t0, end_s=t0 + 0.3 * horizon_s))
        byz = _draw_byzantine(seed, n_workers, byzantine_fraction)
        storm_u = _stream_rng(seed, _STREAM_STORM).random()
        storm = ColdStartStorm() if storm_u < storm_prob else None
        return cls(crashes=crashes, stragglers=tuple(stragglers),
                   storm=storm, byzantine=byz, seed=seed)

    @classmethod
    def from_trace(cls, trace: "Trace", *, seed: int, n_workers: int,
                   horizon_s: float, base_cold_start_s: float = 0.0,
                   crash_rate: float = 0.0,
                   byzantine_fraction: float = 0.0,
                   n_spare_workers: int = 0) -> "FaultPlan":
        """Resample a replayable plan from an empirical :class:`Trace`.

        Per-worker cold-start extras and straggler windows come from the
        trace's measured distributions by inverse CDF over seeded
        sub-streams, with a *fixed* number of uniforms per worker — the
        plan is a pure function of (trace, seed, n_workers, horizon_s)
        and one worker's draws never shift a neighbour's.

        ``trace.cold_start_s`` samples are absolute measured latencies;
        each worker's extra is ``max(0, sample - base_cold_start_s)`` so
        the runtime's plan-level base cold start is not double counted.
        A straggler window's start is placed uniformly so the whole
        window fits inside the horizon (clamped to start at 0 when a
        sampled duration exceeds it).

        Crashes and byzantine workers are not part of the measured
        trace; the optional rates draw them exactly as :meth:`random`
        does, from the same sub-streams, so a trace-replayed grid and a
        synthetic one with equal seeds share crash/byzantine draws —
        any difference between the two isolates the tail behaviour.

        ``n_spare_workers`` extends the cold-start vector past the
        epoch-start fleet so workers an autoscaler spawns mid-epoch pay
        measured cold starts too (otherwise every joiner would get the
        best-case base — a bias, not a measurement).  Spares only
        append draws: the first ``n_workers`` extras, and all
        crash/straggler draws, are unchanged by the spare count.
        """
        u_cold = _stream_rng(seed, _STREAM_COLD_START).random(
            n_workers + n_spare_workers)
        extras = tuple(max(0.0, float(c) - base_cold_start_s)
                       for c in trace.sample("cold_start_s", u_cold))
        u = _stream_rng(seed, _STREAM_TRACE_STRAGGLER).random(
            (n_workers, 4))
        stragglers = []
        for w in range(n_workers):
            occur, u_slow, u_dur, u_start = u[w]
            if occur < trace.straggler_prob:
                dur = float(trace.sample("straggler_duration_s", u_dur))
                t0 = float(u_start) * max(horizon_s - dur, 0.0)
                stragglers.append(Straggler(
                    w,
                    slowdown=float(trace.sample("straggler_slowdown",
                                                u_slow)),
                    start_s=t0, end_s=t0 + dur))
        return cls(crashes=_draw_crashes(seed, n_workers, horizon_s,
                                         crash_rate),
                   stragglers=tuple(stragglers), storm=None,
                   byzantine=_draw_byzantine(seed, n_workers,
                                             byzantine_fraction),
                   seed=seed, cold_start_extra_s=extras)


def _draw_crashes(seed: int, n_workers: int, horizon_s: float,
                  crash_rate: float) -> Tuple[WorkerCrash, ...]:
    rng = _stream_rng(seed, _STREAM_CRASH)
    crashes = []
    for w in range(n_workers):
        if rng.random() < crash_rate:
            crashes.append(WorkerCrash(w, float(
                rng.uniform(0.1, 0.9) * horizon_s)))
    return tuple(crashes)


def _draw_byzantine(seed: int, n_workers: int,
                    fraction: float) -> Tuple[ByzantineWorker, ...]:
    # same [0, n_workers] clamp as storm_victims: fraction > 1 must not
    # ask choice() for a larger sample than the fleet
    n_byz = min(max(int(round(fraction * n_workers)), 0), n_workers)
    if n_byz <= 0:
        return ()
    rng = _stream_rng(seed, _STREAM_BYZANTINE)
    return tuple(ByzantineWorker(int(w))
                 for w in rng.choice(n_workers, size=n_byz, replace=False))


# ---------------------------------------------------------------------------
# Real-training byzantine injection: a composable Strategy wrapper
# ---------------------------------------------------------------------------
def _linear_axis_index(axis_names):
    """Flattened data-parallel worker index inside a shard_map body."""
    import jax

    from repro.compat import axis_size
    axes = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


import repro.core.strategies as _strategies


@dataclasses.dataclass(frozen=True)
class ByzantineGradients(_strategies.Strategy):
    """Wrap any Strategy; designated workers ship corrupted gradients.

    The corruption runs *inside* the shard_map body before the inner
    strategy's collective, so a robust aggregator downstream sees
    exactly what a poisoned serverless worker would have pushed to the
    channel.  ``attack`` names a registered
    :class:`repro.serverless.adversarial.AttackSpec` (``sign_flip``,
    ``scale``, ``gaussian_noise``, ``little_is_enough``, ``zero``, plus
    anything third parties register); ``scale`` is the attack magnitude
    (``None`` = the attack's own default) and ``seed`` feeds the
    stochastic attacks' per-worker noise streams.

    Every kwarg is validated HERE, at construction: a bad worker set,
    an unknown attack name, a non-finite magnitude or a byzantine
    *majority* (``len(workers) > (n_workers-1)/2`` when the fleet size
    is declared) used to surface only deep inside the first jitted sync
    step, as an XLA trace error with the configuration long gone.
    """
    name: str = "byzantine"
    inner: Optional[_strategies.Strategy] = None
    workers: Tuple[int, ...] = (0,)
    attack: str = "scale"
    scale: Optional[float] = None      # None => the attack's default
    seed: int = 0                      # stochastic attacks' noise stream
    n_workers: Optional[int] = None    # declared fleet size (validation)

    def __post_init__(self):
        if self.inner is None:
            raise ValueError("ByzantineGradients needs an inner strategy")
        # the wrapper rides the inner strategy's accumulation schedule
        # (SPIRT etc.); a conflicting explicit value would silently
        # change training semantics, so reject it
        if self.microbatches not in (1, self.inner.microbatches):
            raise ValueError(
                f"microbatches={self.microbatches} conflicts with "
                f"inner.microbatches={self.inner.microbatches}; set it on "
                "the inner strategy instead")
        object.__setattr__(self, "microbatches", self.inner.microbatches)
        workers = tuple(self.workers)
        if not workers:
            raise ValueError(
                "ByzantineGradients needs a non-empty workers tuple "
                "(an attack with no attackers is a plain wrapper bug)")
        if len(set(workers)) != len(workers) \
                or any(not isinstance(w, (int, np.integer)) or w < 0
                       for w in workers):
            raise ValueError(
                f"workers must be distinct non-negative ints, got "
                f"{workers!r}")
        object.__setattr__(self, "workers", workers)
        if self.n_workers is not None:
            if self.n_workers < 1:
                raise ValueError(
                    f"n_workers must be >= 1, got {self.n_workers}")
            if any(w >= self.n_workers for w in workers):
                raise ValueError(
                    f"workers {workers!r} out of range for a fleet of "
                    f"{self.n_workers}")
            # byzantine fraction must stay in [0, (W-1)/2W]: a corrupted
            # majority out-votes EVERY robust statistic, so the run
            # would measure nothing but the attack
            max_byz = (self.n_workers - 1) // 2
            if len(workers) > max_byz:
                raise ValueError(
                    f"{len(workers)} byzantine workers of {self.n_workers}"
                    f" is a corrupted majority; at most {max_byz} "
                    f"(fraction <= (W-1)/2W) are aggregatable")
        # resolves through the registry: unknown names raise with the
        # registered list (mirrors get_arch's actionable error)
        from repro.serverless.adversarial import get_attack
        spec = get_attack(self.attack)
        scale = spec.default_scale if self.scale is None else self.scale
        if not math.isfinite(scale):
            raise ValueError(f"attack scale must be finite, got {scale}")
        object.__setattr__(self, "scale", float(scale))

    def init_state(self, grads_like):
        # (sync-step counter, inner state): the counter feeds the
        # stochastic attacks' PRNG keys so every step corrupts with
        # fresh draws — matching the numpy twins' redraw-per-step
        import jax.numpy as jnp
        return (jnp.zeros((), jnp.int32),
                self.inner.init_state(grads_like))

    def sync(self, grads, state, axis_names):
        import jax.numpy as jnp

        from repro.serverless.adversarial import get_attack
        step, inner_state = state
        idx = _linear_axis_index(axis_names)
        bad = jnp.zeros((), bool)
        for w in self.workers:
            bad = jnp.logical_or(bad, idx == w)
        corrupted = get_attack(self.attack).jax_apply(
            grads, bad, axis_names, self.scale, self.seed, step)
        out, inner_state, info = self.inner.sync(corrupted, inner_state,
                                                 axis_names)
        return out, (step + 1, inner_state), info

    def comm_bytes(self, grads_like, n_workers):
        return self.inner.comm_bytes(grads_like, n_workers)
