"""Fault taxonomy + seeded injection for the serverless event runtime.

Four fault classes, matching the failure modes the paper's
fault-tolerance comparison (and SPIRT's §5 / MLLess's §6 evaluations)
is about:

  WorkerCrash     a Lambda invocation dies mid-epoch; its in-flight
                  round is lost.  What happens next is the recovery
                  policy's job (``recovery.py``): checkpoint-restore
                  re-invokes and replays, SPIRT peer takeover reassigns
                  the partition because state lives in the database.
  Straggler       a worker computes ``slowdown`` x slower inside a time
                  window (noisy neighbour / throttled vCPU).  Under
                  synchronous training every barrier inherits the
                  straggler's finish time.
  ColdStartStorm  a fraction of the fleet pays ``extra_s`` additional
                  cold start (concurrent-invocation burst, arXiv
                  2105.07806's dominant serverless overhead).
  ByzantineWorker a worker ships poisoned (scaled) gradients.  Timing
                  is unaffected; correctness bookkeeping flows through
                  the runtime's robust-aggregation accounting, and the
                  *real-training* analogue is :class:`ByzantineGradients`
                  below.

``FaultPlan`` bundles specs; ``FaultPlan.random`` draws a reproducible
plan from per-class rates with a seeded RNG, so every experiment is
replayable from (seed, rates).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkerCrash:
    worker: int
    time_s: float


@dataclasses.dataclass(frozen=True)
class Straggler:
    worker: int
    slowdown: float = 4.0
    start_s: float = 0.0
    end_s: float = math.inf


@dataclasses.dataclass(frozen=True)
class ColdStartStorm:
    extra_s: float = 10.0
    fraction: float = 0.5


@dataclasses.dataclass(frozen=True)
class ByzantineWorker:
    worker: int
    scale: float = -10.0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, fully-resolved set of faults for one epoch run."""
    crashes: Tuple[WorkerCrash, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()
    storm: Optional[ColdStartStorm] = None
    byzantine: Tuple[ByzantineWorker, ...] = ()
    seed: int = 0

    def storm_victims(self, n_workers: int) -> Tuple[int, ...]:
        """Seeded choice of which workers the cold-start storm hits."""
        if self.storm is None:
            return ()
        rng = np.random.RandomState(self.seed)
        k = max(1, int(round(self.storm.fraction * n_workers)))
        return tuple(sorted(rng.choice(n_workers, size=k, replace=False)))

    def slowdown(self, worker: int, t: float) -> float:
        f = 1.0
        for s in self.stragglers:
            if s.worker == worker and s.start_s <= t < s.end_s:
                f = max(f, s.slowdown)
        return f

    def byzantine_workers(self) -> Tuple[int, ...]:
        return tuple(sorted({b.worker for b in self.byzantine}))

    @classmethod
    def random(cls, *, seed: int, n_workers: int, horizon_s: float,
               crash_rate: float = 0.0, straggler_rate: float = 0.0,
               byzantine_fraction: float = 0.0,
               storm_prob: float = 0.0) -> "FaultPlan":
        """Draw a reproducible plan.  Rates are expected events per
        worker per epoch (Poisson-thinned to at most one per worker)."""
        rng = np.random.RandomState(seed)
        crashes, stragglers, byz = [], [], []
        for w in range(n_workers):
            if rng.rand() < crash_rate:
                crashes.append(WorkerCrash(w, float(
                    rng.uniform(0.1, 0.9) * horizon_s)))
            if rng.rand() < straggler_rate:
                t0 = float(rng.uniform(0.0, 0.7) * horizon_s)
                stragglers.append(Straggler(
                    w, slowdown=float(rng.uniform(2.0, 6.0)),
                    start_s=t0, end_s=t0 + 0.3 * horizon_s))
        n_byz = int(round(byzantine_fraction * n_workers))
        for w in rng.choice(n_workers, size=n_byz, replace=False):
            byz.append(ByzantineWorker(int(w)))
        storm = ColdStartStorm() if rng.rand() < storm_prob else None
        return cls(crashes=tuple(crashes), stragglers=tuple(stragglers),
                   storm=storm, byzantine=tuple(byz), seed=seed)


# ---------------------------------------------------------------------------
# Real-training byzantine injection: a composable Strategy wrapper
# ---------------------------------------------------------------------------
def _linear_axis_index(axis_names):
    """Flattened data-parallel worker index inside a shard_map body."""
    import jax

    from repro.compat import axis_size
    axes = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


import repro.core.strategies as _strategies


@dataclasses.dataclass(frozen=True)
class ByzantineGradients(_strategies.Strategy):
    """Wrap any Strategy; designated workers ship corrupted gradients.

    The corruption runs *inside* the shard_map body before the inner
    strategy's collective, so a robust aggregator downstream sees
    exactly what a poisoned serverless worker would have pushed to the
    channel.  ``mode``: ``scale`` (g *= scale), ``sign_flip`` (-g) or
    ``zero`` (dropped contribution).
    """
    name: str = "byzantine"
    inner: Optional[_strategies.Strategy] = None
    workers: Tuple[int, ...] = (0,)
    mode: str = "scale"
    scale: float = -10.0

    def __post_init__(self):
        if self.inner is None:
            raise ValueError("ByzantineGradients needs an inner strategy")
        # the wrapper rides the inner strategy's accumulation schedule
        # (SPIRT etc.); a conflicting explicit value would silently
        # change training semantics, so reject it
        if self.microbatches not in (1, self.inner.microbatches):
            raise ValueError(
                f"microbatches={self.microbatches} conflicts with "
                f"inner.microbatches={self.inner.microbatches}; set it on "
                "the inner strategy instead")
        object.__setattr__(self, "microbatches", self.inner.microbatches)

    def init_state(self, grads_like):
        return self.inner.init_state(grads_like)

    def sync(self, grads, state, axis_names):
        import jax
        import jax.numpy as jnp
        idx = _linear_axis_index(axis_names)
        bad = jnp.zeros((), bool)
        for w in self.workers:
            bad = jnp.logical_or(bad, idx == w)

        def corrupt(g):
            if self.mode == "scale":
                evil = g * jnp.asarray(self.scale, g.dtype)
            elif self.mode == "sign_flip":
                evil = -g
            elif self.mode == "zero":
                evil = jnp.zeros_like(g)
            else:
                raise ValueError(self.mode)
            return jnp.where(bad, evil, g)

        return self.inner.sync(jax.tree.map(corrupt, grads), state,
                               axis_names)

    def comm_bytes(self, grads_like, n_workers):
        return self.inner.comm_bytes(grads_like, n_workers)
