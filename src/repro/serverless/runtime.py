"""Discrete-event serverless training runtime (optimized hot path).

Event model
-----------
A single priority queue of ``(time, seq, worker, gen, opcode, arg)``
events drives the whole fleet.  Each worker is a lifecycle state machine

    COLD_START -> STATE_LOAD -> COMPUTE -> SYNC -> (barrier) -> UPDATE
         ^                                                        |
         |                 next round / re-invocation             |
         +--------------------------------------------------------+

whose stage *durations* come from :func:`repro.serverless.simulator.
round_plan` — the identical closed-form terms the analytic
``simulate_epoch`` sums.  With homogeneous fault-free workers every
barrier is free, so the event makespan reproduces the analytic
per-worker time exactly; ``simulate_epoch`` is therefore the engine's
validated fast path, and everything the analytic model *cannot*
express — crashes, stragglers, cold-start storms, byzantine gradients,
elastic fleets — is layered on top as events.

Synchronous-training semantics: a round's barrier releases when every
*expected* worker has finished its sync stage (and any recovery holds
have cleared); all workers then apply the update and enter the next
round.  The epoch's work is a shared pool of ``W0 x total_batches``
minibatches, so an autoscaler that grows the fleet genuinely shortens
the epoch (fewer rounds), and peer takeover after a crash genuinely
lengthens per-worker rounds (survivors absorb the partition).

Hot-path design (ISSUE 2 tentpole) — this engine exists to be swept
thousands of times per chart by ``repro.serverless.sweep``, so the
per-event machinery of the reference implementation
(``runtime_ref.py``, kept frozen for regression) is replaced by:

  * ``__slots__`` workers with plain float stage accumulators instead
    of a per-worker dict;
  * integer event opcodes dispatched through a bound-method table —
    no per-event closure allocation;
  * timeline logging off by default (``max_timeline=0``); enabling it
    also disables round batching so the recorded timeline has full
    per-event granularity;
  * a lazy heap: when nothing can interleave with the next round (no
    scheduled crash/respawn/rejoin/spawn at or before the projected
    barrier release, no restoring worker, no pending scale-in), the
    whole update -> fetch -> compute -> sync -> barrier sequence for
    every worker is executed inline with the *same floating-point
    operation order* as the event path, so ``RuntimeReport`` numbers
    are byte-identical to the reference engine
    (``tests/test_event_runtime_opt.py`` asserts this).

Fault taxonomy lives in ``faults.py``; recovery semantics (checkpoint
replay vs SPIRT in-database peer takeover) in ``recovery.py``; scaling
policies in ``autoscale.py``.  Billing follows
``repro.costmodel.pricing``: Lambda workers bill GB-seconds for their
entire invocation wall-clock (barrier waits included — stalls are not
free, which is exactly why stragglers show up in the cost column), the
GPU baseline bills instance-hours for the makespan.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from heapq import heappop, heappush
from typing import Dict, List, Optional, Tuple

from repro.guards import no_tracer_fields
from repro.serverless.archs import get_arch
from repro.serverless.faults import FaultPlan
from repro.serverless.recovery import (CheckpointRestore, PeerTakeover,
                                       RecoveryEvent, RecoveryPolicy)
from repro.serverless.simulator import (RoundPlan, ServerlessSetup,
                                        round_plan)


def resolve_recovery(arch: str, name: str, *,
                     checkpoint_every: int = 4) -> RecoveryPolicy:
    """THE string -> :class:`RecoveryPolicy` mapping (one place —
    :func:`run_event_epoch`, the sweep engine and the benchmarks all
    route through here).  ``"auto"`` resolves the architecture's own
    :class:`~repro.serverless.archs.ArchSpec` default: in-DB-state
    designs (SPIRT and its hybrids) take over from peers, everything
    else re-invokes and replays from a checkpoint."""
    if name == "auto":
        name = get_arch(arch).default_recovery
    if name == "takeover":
        return PeerTakeover()
    if name == "restore":
        return CheckpointRestore(checkpoint_every=checkpoint_every)
    raise ValueError(f"unknown recovery {name!r}; expected 'auto', "
                     "'restore', 'takeover' or a RecoveryPolicy")


def default_recovery(arch: str, *,
                     checkpoint_every: int = 4) -> RecoveryPolicy:
    """The architecture's ``recovery="auto"`` policy (see
    :func:`resolve_recovery`)."""
    return resolve_recovery(arch, "auto",
                            checkpoint_every=checkpoint_every)

# worker lifecycle states
COLD_START, STATE_LOAD, COMPUTE, SYNC, WAIT_BARRIER, UPDATE, DONE, DEAD = (
    "cold_start", "state_load", "compute", "sync", "wait_barrier",
    "update", "done", "dead")

# integer event opcodes; heap entries are (t, seq, wid, gen, op, arg)
(_OP_COLD_DONE, _OP_LOADED, _OP_ROUND_LOADED, _OP_COMPUTED, _OP_SYNCED,
 _OP_UPDATED, _OP_RELEASE, _OP_MAYBE_RELEASE, _OP_CRASH,
 _OP_RESPAWN) = range(10)

# _fast_round outcomes
_CLASSIC, _EPOCH_DONE, _NEXT_BARRIER = 0, 1, 2


class _Worker:
    """Per-worker state; slotted — this is the hot allocation."""
    __slots__ = ("id", "state", "gen", "alive", "spawn_time", "done_time",
                 "joined", "work_mult", "replay_rounds", "byzantine",
                 "restoring", "initial", "pending_recovery",
                 "async_reserve",
                 "s_cold", "s_fetch", "s_compute", "s_sync", "s_update",
                 "s_wait", "s_replay", "_stage_started")

    def __init__(self, wid: int, byzantine: bool = False):
        self.id = wid
        self.state = COLD_START
        self.gen = 0                 # bumped on crash; stale events ignored
        self.alive = True
        self.spawn_time = 0.0
        self.done_time: Optional[float] = None
        self.joined = False          # finished cold start + first load
        self.work_mult = 1.0         # >1 after absorbing a peer's partition
        self.replay_rounds = 0       # pending checkpoint replay after restore
        self.byzantine = byzantine
        self.restoring = False       # crashed, checkpoint-restore in flight
        self.initial = False         # part of the epoch-start fleet
        self.pending_recovery: Optional[RecoveryEvent] = None
        self.async_reserve = 0.0     # in-flight pool claim (barrier-free)
        # per-stage busy-time accounting (excludes barrier waits)
        self.s_cold = 0.0
        self.s_fetch = 0.0
        self.s_compute = 0.0
        self.s_sync = 0.0
        self.s_update = 0.0
        self.s_wait = 0.0
        self.s_replay = 0.0
        self._stage_started = 0.0


@dataclasses.dataclass
class RuntimeReport:
    """What one event-driven epoch produced."""
    arch: str
    makespan_s: float
    analytic_s: float                  # simulate_epoch's fault-free time
    rounds: int
    work_done_batches: float
    n_workers_start: int
    n_workers_peak: int
    n_workers_end: int
    total_cost: float
    stage_totals: Dict[str, float]     # summed across workers
    recoveries: List[RecoveryEvent]
    poisoned_updates: int              # byzantine contributions applied
    masked_updates: int                # byzantine contributions masked
    scale_events: List[Tuple[float, int]]   # (time, delta)
    timeline: List[Tuple[float, int, str]]  # (time, worker, event)

    def __post_init__(self):
        # runtime backstop for the static trace-safety rule: a report
        # built inside a traced function would freeze abstract values
        # into golden snapshots / BENCH payloads
        no_tracer_fields(self)

    @property
    def time_to_recover_s(self) -> float:
        return max((r.time_to_recover_s for r in self.recoveries),
                   default=0.0)

    @property
    def overhead_vs_analytic(self) -> float:
        return self.makespan_s / self.analytic_s - 1.0


class EventRuntime:
    """Heap-scheduled execution of one epoch of a :class:`RoundPlan`."""

    def __init__(self, plan: RoundPlan, setup: ServerlessSetup, *,
                 faults: Optional[FaultPlan] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 autoscaler=None, robust_trim: int = 0,
                 max_timeline: int = 0):
        self.plan = plan
        self.setup = setup
        self.faults = faults or FaultPlan()
        self.recovery = recovery or CheckpointRestore()
        self.autoscaler = autoscaler
        self.robust_trim = robust_trim
        self.max_timeline = max_timeline
        self._tl = max_timeline > 0    # timeline off by default (hot path)

        self.t = 0.0
        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self.workers: List[_Worker] = []
        self.round_idx = 0
        # shared epoch work pool: W0 workers x per-worker batches
        self.pool = plan.n_workers * plan.total_batches
        self.arrived: set = set()
        self.barrier_not_before = 0.0
        # barrier-free plans: committed syncs since the last
        # fleet-equivalent round tick (n_workers commits ~ one round)
        self._async_syncs = 0
        # barrier-free mode: pool batches claimed by in-flight rounds —
        # a worker may only start a round against pool MINUS what its
        # peers have already claimed, or cold-start spread lets fast
        # workers overdraft the epoch with phantom rounds
        self._async_reserved = 0.0
        self.recoveries: List[RecoveryEvent] = []
        self.scale_events: List[Tuple[float, int]] = []
        self.timeline: List[Tuple[float, int, str]] = []
        self.poisoned = 0
        self.masked = 0
        self._pending_scale_in = 0
        # hot-path indices: per-worker straggler lists (preserving the
        # FaultPlan tuple order so max-of-overlaps matches bit-for-bit),
        # byzantine presence, and work_mult uniformity (falsified by
        # peer takeover, which skews survivor partitions)
        self._strag_by_worker: Dict[int, list] = {}
        for s in self.faults.stragglers:
            self._strag_by_worker.setdefault(s.worker, []).append(s)
        self._has_byz = bool(self.faults.byzantine)
        self._uniform = True

    # ------------------------------------------------------------ events
    def _schedule(self, t: float, w: Optional[_Worker], op: int, arg=None):
        if w is None:
            heappush(self._heap, (t, next(self._seq), -1, -1, op, arg))
        else:
            heappush(self._heap, (t, next(self._seq), w.id, w.gen, op,
                                  arg))

    def _log(self, w: int, event: str):
        if len(self.timeline) < self.max_timeline:
            self.timeline.append((self.t, w, event))

    # ------------------------------------------------------------ stages
    def _spawn_worker(self, t: float, *, byzantine: bool = False,
                      replay_rounds: int = 0,
                      existing: Optional[_Worker] = None) -> _Worker:
        """(Re-)invoke a worker: cold start, then first state load."""
        if existing is None:
            w = _Worker(len(self.workers), byzantine)
            self.workers.append(w)
        else:
            w = existing
            w.alive, w.state = True, COLD_START
        w.spawn_time = t if existing is None else w.spawn_time
        w.replay_rounds = replay_rounds
        # heterogeneous cold starts: the trace-replay per-worker vector
        # (every (re-)invocation of a worker id re-pays its extra, like
        # a storm victim re-pays the storm's) on top of the storm
        cold = self.plan.cold_start_s + self.faults.cold_extra(w.id)
        if w.id in self._storm_victims:
            cold += self.faults.storm.extra_s
        if not self.plan.barrier:
            # claim the first round's quantum at invocation: a peer
            # finishing early must not overdraft the pool share of a
            # worker still paying its cold start
            self._async_reserved -= w.async_reserve
            w.async_reserve = self.plan.batches_per_round * w.work_mult
            self._async_reserved += w.async_reserve
        if self._tl:
            self._log(w.id, f"invoke(cold={cold:.2f}s)")
        w.state = COLD_START
        w._stage_started = self.t
        self._schedule(t + cold, w, _OP_COLD_DONE, cold)
        return w

    def _h_cold_done(self, w: _Worker, cold):
        w.s_cold += cold
        self._begin_load(w)

    def _begin_load(self, w: _Worker):
        w.state = STATE_LOAD
        w._stage_started = self.t
        dur = self.plan.fetch_s
        if w.replay_rounds:
            # replay compute for rounds lost since the last checkpoint
            dur += w.replay_rounds * (self.plan.batches_per_round
                                      * self.plan.compute_s_per_batch)
        self._schedule(self.t + dur, w, _OP_LOADED, dur)

    def _h_loaded(self, w: _Worker, dur):
        w.s_fetch += self.plan.fetch_s
        if w.replay_rounds:
            w.s_replay += dur - self.plan.fetch_s
            if self._tl:
                self._log(w.id, f"replayed {w.replay_rounds} rounds")
            w.replay_rounds = 0
        w.joined = True
        self._begin_compute(w)

    def _round_fetch_needed(self) -> bool:
        if self.plan.fetch_first_round_only:
            return False
        # barrier mode only reaches _begin_round again after round 0's
        # barrier (round_idx >= 1); a barrier-free worker re-fetches at
        # the top of every self-paced round
        return self.round_idx > 0 or not self.plan.barrier

    def _begin_round(self, w: _Worker):
        """Top of a round for an already-joined worker."""
        if self._round_fetch_needed():
            w.state = STATE_LOAD
            w._stage_started = self.t
            self._schedule(self.t + self.plan.fetch_s, w, _OP_ROUND_LOADED)
        else:
            self._begin_compute(w)

    def _h_round_loaded(self, w: _Worker, arg):
        w.s_fetch += self.t - w._stage_started
        self._begin_compute(w)

    def _begin_compute(self, w: _Worker):
        if not self.plan.barrier:
            # claim this round's quantum up front (released at commit,
            # or at crash for a round that will never commit); the
            # re-subtract makes the claim idempotent across the
            # fetch -> compute hand-off
            self._async_reserved -= w.async_reserve
            w.async_reserve = self.plan.batches_per_round * w.work_mult
            self._async_reserved += w.async_reserve
        w.state = COMPUTE
        w._stage_started = self.t
        slow = self.faults.slowdown(w.id, self.t)
        dur = (self.plan.batches_per_round * w.work_mult
               * self.plan.compute_s_per_batch * slow)
        if slow > 1.0 and self._tl:
            self._log(w.id, f"straggling x{slow:.1f}")
        self._schedule(self.t + dur, w, _OP_COMPUTED)

    def _h_computed(self, w: _Worker, arg):
        w.s_compute += self.t - w._stage_started
        self._begin_sync(w)

    def _begin_sync(self, w: _Worker):
        w.state = SYNC
        w._stage_started = self.t
        self._schedule(self.t + self.plan.sync_s * w.work_mult, w,
                       _OP_SYNCED)

    def _h_synced(self, w: _Worker, arg):
        w.s_sync += self.t - w._stage_started
        if not self.plan.barrier:
            self._commit_async_sync(w)
            return
        w.state = WAIT_BARRIER
        w._stage_started = self.t
        if w.pending_recovery is not None:
            # back at the barrier: recovery complete
            w.pending_recovery.rejoined_time_s = self.t
            w.pending_recovery = None
            w.restoring = False
        self.arrived.add(w.id)
        self._maybe_release_barrier()

    def _commit_async_sync(self, w: _Worker):
        """Barrier-free commit: the worker's push lands in the shared
        store immediately — no WAIT_BARRIER state, no fleet stall.  The
        pool drains per commit (instead of per barrier round), and
        every ``n_workers`` commits count as one fleet-equivalent round
        for reporting and autoscaler pacing."""
        if w.pending_recovery is not None:
            # first committed sync after the respawn: recovery complete
            w.pending_recovery.rejoined_time_s = self.t
            w.pending_recovery = None
            w.restoring = False
        if self._has_byz and w.byzantine:
            # the in-DB aggregate masks this worker's contribution only
            # when the robust statistic is feasible over the live fleet
            # (same feasibility rule as the barrier path)
            expected = self._expected()
            n_byz = sum(1 for v in expected if v.byzantine)
            if len(expected) > 2 * self.robust_trim \
                    and n_byz <= self.robust_trim:
                self.masked += 1
            else:
                self.poisoned += 1
        # drain exactly what this round claimed at its start (work_mult
        # changes from a mid-round takeover apply from the next round)
        self.pool -= w.async_reserve
        self._async_reserved -= w.async_reserve
        w.async_reserve = 0.0
        self._async_syncs += 1
        if self._async_syncs % self.plan.n_workers == 0:
            self.round_idx += 1
            if self._tl:
                self._log(-1, f"async round={self.round_idx} "
                              f"commits={self._async_syncs}")
            if self.autoscaler is not None:
                self._autoscale_hook()
        self._begin_update(w)

    # ------------------------------------------------------------ barrier
    def _expected(self) -> List[_Worker]:
        """Workers the current barrier must wait for.  A checkpoint-
        restoring worker stays expected (synchronous training cannot
        proceed without its gradient — the fleet stalls, which is the
        measured time-to-recover); a taken-over worker does not.  The
        epoch-start fleet is expected from t=0 (a cold-start storm gates
        the first barrier); autoscaled workers only once they join."""
        return [w for w in self.workers
                if (w.alive or w.restoring)
                and (w.joined or w.initial)
                and w.done_time is None]

    def _maybe_release_barrier(self):
        expected = self._expected()
        if not expected:
            return
        arrived = self.arrived
        for w in expected:
            if w.id not in arrived:
                return
        release_at = max(self.t, self.barrier_not_before)
        self._schedule(release_at, None, _OP_RELEASE)

    def _h_release(self, w, arg):
        expected = self._expected()
        for v in expected:
            if v.id not in self.arrived:
                return                  # a recovery hold re-queued us
        if self.barrier_not_before > self.t:
            self._schedule(self.barrier_not_before, None, _OP_RELEASE)
            return
        self._barrier_rounds()

    def _barrier_rounds(self):
        """Process the barrier at ``self.t``, then keep executing whole
        rounds inline for as long as :meth:`_fast_round` allows; fall
        back to per-event scheduling the moment anything (fault,
        respawn, rejoin hold, scale event, restoring worker) could
        interleave."""
        plan = self.plan
        # a committed inline round processes no events, so the expected
        # fleet (and therefore its per-round work quantum) is invariant
        # across loop iterations — compute both once
        expected = self._expected()
        batches = sum(plan.batches_per_round * v.work_mult
                      for v in expected)
        while True:
            # byzantine accounting for this aggregation round; masking
            # needs a feasible trimmed aggregate (W > 2*trim, see
            # recovery.py) AND no more byzantine contributions than the
            # trim width
            if self._has_byz:
                n_byz = 0
                for v in expected:
                    if v.byzantine:
                        n_byz += 1
                if n_byz:
                    feasible = len(expected) > 2 * self.robust_trim
                    if feasible and n_byz <= self.robust_trim:
                        self.masked += n_byz
                    else:
                        self.poisoned += n_byz
            self.pool -= batches
            self.round_idx += 1
            self.arrived.clear()
            if self._tl:
                self._log(-1, f"barrier round={self.round_idx} "
                              f"workers={len(expected)}")
            T = self.t
            for v in expected:
                v.s_wait += T - v._stage_started
            if self.autoscaler is not None:
                self._autoscale_hook()
            if not expected:
                # the whole fleet is gone (e.g. every worker crashed
                # under takeover): mirror the reference engine, which
                # accounts this barrier once and schedules nothing —
                # looping would commit zero-batch rounds forever
                return
            fate = self._fast_round(expected, T)
            if fate == _CLASSIC:
                for v in expected:
                    self._begin_update(v)
                return
            if fate == _EPOCH_DONE:
                return
            # _NEXT_BARRIER: round committed inline, self.t is the next
            # barrier's release time; loop

    def _fast_round(self, expected: List[_Worker], T: float) -> int:
        """Attempt to run update -> (fetch) -> compute -> sync ->
        barrier for every expected worker inline, bypassing the heap.

        Legal only when nothing can interleave before the projected
        barrier release: no pending scale-in, no restoring/replaying
        worker, and no scheduled event at or before the release.  The
        arithmetic reproduces the event path's floating-point operation
        order exactly, so reports stay byte-identical to the reference
        engine.  Timeline mode disables batching for full granularity.
        """
        if self._pending_scale_in or self._tl:
            return _CLASSIC
        plan = self.plan
        heap = self._heap
        t1 = T + plan.update_s
        if self.pool <= 1e-9:
            # final update, then the whole fleet retires
            if heap and heap[0][0] <= t1:
                return _CLASSIC
            for v in expected:
                v.s_update += t1 - T
                if v.alive and v.done_time is None:
                    v.state = DONE
                    v.done_time = t1
            self.t = t1
            return _EPOCH_DONE
        # Invariant: every expected worker here is alive, joined and
        # fully recovered — a restoring worker cannot have arrived at
        # the barrier (restoring clears in _h_synced, before arrival),
        # and replay_rounds clears in _h_loaded, before its compute.
        fetch = (not plan.fetch_first_round_only) and self.round_idx > 0
        t2 = t1 + plan.fetch_s if fetch else t1
        arrived = self.arrived
        strag = self._strag_by_worker
        if self._uniform and not strag:
            # homogeneous fleet: one worker's arithmetic is everyone's
            # (x * 1.0 is exact, so folding work_mult/slowdown away
            # preserves the event path's floats bit-for-bit)
            t3 = t2 + plan.batches_per_round * plan.compute_s_per_batch
            t4 = t3 + plan.sync_s
            release = t4 if t4 > self.barrier_not_before \
                else self.barrier_not_before
            if heap and heap[0][0] <= release:
                return _CLASSIC
            du, dc, ds = t1 - T, t3 - t2, t4 - t3
            df = t2 - t1
            for v in expected:
                v.s_update += du
                if fetch:
                    v.s_fetch += df
                v.s_compute += dc
                v.s_sync += ds
                v.state = WAIT_BARRIER
                v._stage_started = t4
                arrived.add(v.id)
            self.t = release
            return _NEXT_BARRIER
        bpr, comp = plan.batches_per_round, plan.compute_s_per_batch
        sync_s = plan.sync_s
        arrivals = []
        release = self.barrier_not_before
        for v in expected:
            slow = 1.0
            for s in strag.get(v.id, ()):
                if s.start_s <= t2 < s.end_s and s.slowdown > slow:
                    slow = s.slowdown
            t3 = t2 + bpr * v.work_mult * comp * slow
            t4 = t3 + sync_s * v.work_mult
            arrivals.append((t3, t4))
            if t4 > release:
                release = t4
        if heap and heap[0][0] <= release:
            return _CLASSIC
        # commit: identical increments to the per-event path
        for v, (t3, t4) in zip(expected, arrivals):
            v.s_update += t1 - T
            if fetch:
                v.s_fetch += t2 - t1
            v.s_compute += t3 - t2
            v.s_sync += t4 - t3
            v.state = WAIT_BARRIER
            v._stage_started = t4
            arrived.add(v.id)
        self.t = release
        return _NEXT_BARRIER

    def _begin_update(self, w: _Worker):
        w.state = UPDATE
        w._stage_started = self.t
        self._schedule(self.t + self.plan.update_s, w, _OP_UPDATED)

    def _h_updated(self, w: _Worker, arg):
        w.s_update += self.t - w._stage_started
        # barrier-free: only unclaimed pool work justifies another round
        # (peers' in-flight rounds will drain their reservations);
        # barrier mode keeps reservations at zero so this is unchanged
        if (self.pool - self._async_reserved > 1e-9
                and not self._retire_if_requested(w)):
            self._begin_round(w)
        elif w.alive and w.done_time is None:
            w.state = DONE
            w.done_time = self.t
            if self._tl:
                self._log(w.id, "done")

    def _retire_if_requested(self, w: _Worker) -> bool:
        if self._pending_scale_in > 0 and len(self._expected()) > 1:
            self._pending_scale_in -= 1
            w.alive = False
            w.state = DONE
            w.done_time = self.t
            if self._tl:
                self._log(w.id, "scaled in")
            return True
        return False

    # ------------------------------------------------------------ faults
    def _h_crash(self, w, widx):
        self._on_crash(self.workers[widx], self.t)

    def _on_crash(self, w: _Worker, t: float):
        if not w.alive or w.done_time is not None:
            return
        w.gen += 1                      # invalidate in-flight events
        w.alive = False
        w.state = DEAD
        # barrier-free pool claims survive a restore crash (the worker
        # respawns and commits the round); takeover settles the claim
        # from the in-DB partition below
        reserve = w.async_reserve
        self.arrived.discard(w.id)
        if self._tl:
            self._log(w.id, "CRASH")
        ch = self.setup.channel
        if isinstance(self.recovery, PeerTakeover):
            # survivors fetch the dead worker's in-DB partition and
            # absorb its share of the remaining work; the dead Lambda
            # stops billing at the crash
            w.done_time = t
            rejoin = (t + self.recovery.detection_s
                      + ch.transfer(self.plan.model_bytes, ops=1))
            survivors = [v for v in self.workers
                         if v.alive and v.id != w.id]
            if survivors:
                extra = w.work_mult / len(survivors)
                for v in survivors:
                    v.work_mult += extra
                self._uniform = False
                if reserve:
                    # barrier-free takeover mirrors the barrier
                    # engine's economics: the dead worker's partial
                    # accumulation is already in the DB, so its claimed
                    # round drains without recompute (the sync engine
                    # commits it through the inflated work_mult at the
                    # crash round's barrier)
                    self.pool -= reserve
                    self._async_reserved -= reserve
                    w.async_reserve = 0.0
            self.barrier_not_before = max(self.barrier_not_before, rejoin)
            self.recoveries.append(RecoveryEvent(
                worker=w.id, crash_time_s=t, rejoined_time_s=rejoin,
                mode="takeover"))
            if self._tl:
                self._log(w.id, f"takeover by {len(survivors)} peers")
            self._schedule(rejoin, None, _OP_MAYBE_RELEASE)
        else:
            replay = self.recovery.replay_rounds(self.round_idx)
            rec = RecoveryEvent(worker=w.id, crash_time_s=t,
                                rejoined_time_s=math.nan, mode="restore")
            self.recoveries.append(rec)
            w.restoring = True
            w.pending_recovery = rec
            w.replay_rounds = replay
            self._schedule(t + self.recovery.detection_s, None,
                           _OP_RESPAWN, w.id)

    def _h_respawn(self, w, widx):
        v = self.workers[widx]
        self._spawn_worker(self.t, replay_rounds=v.replay_rounds,
                           existing=v)

    def _h_maybe_release(self, w, arg):
        self._maybe_release_barrier()

    # ------------------------------------------------------------ scaling
    def _autoscale_hook(self):
        expected = self._expected()
        ideal = (self.plan.fetch_s * (0 if self.plan.fetch_first_round_only
                                      else 1)
                 + self.plan.batches_per_round
                 * self.plan.compute_s_per_batch
                 + self.plan.sync_s + self.plan.update_s)
        delta = self.autoscaler.observe(
            round_idx=self.round_idx, now_s=self.t,
            active_workers=len(expected),
            remaining_batches=max(self.pool, 0.0),
            batches_per_round=self.plan.batches_per_round,
            ideal_round_s=ideal)
        if delta > 0:
            for _ in range(delta):
                if self._tl:
                    self._log(-1, "scale out +1")
                self._spawn_worker(self.t)
            self.scale_events.append((self.t, delta))
        elif delta < 0:
            self._pending_scale_in += -delta
            self.scale_events.append((self.t, delta))

    # ------------------------------------------------------------ driver
    def run(self) -> RuntimeReport:
        plan, setup = self.plan, self.setup
        self._storm_victims = set(self.faults.storm_victims(plan.n_workers))
        byz = set(self.faults.byzantine_workers())
        for i in range(plan.n_workers):
            self._spawn_worker(0.0, byzantine=i in byz).initial = True
        for c in self.faults.crashes:
            if c.worker < len(self.workers):
                self._schedule(c.time_s, None, _OP_CRASH, c.worker)

        heap = self._heap
        workers = self.workers
        ops = self._OPS
        guard = 0
        while heap:
            t, _, wid, gen, op, arg = heappop(heap)
            if wid >= 0:
                w = workers[wid]
                if w.gen != gen:
                    continue            # event from a crashed incarnation
            else:
                w = None
            if t > self.t:
                self.t = t
            ops[op](self, w, arg)
            guard += 1
            if guard > 2_000_000:
                raise RuntimeError("event-loop runaway (>2M events)")

        makespan = max((w.done_time for w in self.workers
                        if w.done_time is not None), default=self.t)
        # simulate_epoch's closed form, from the same plan terms
        analytic = (setup.cold_start_s
                    + plan.fetch_s * (1 if plan.fetch_first_round_only
                                      else plan.n_rounds)
                    + plan.total_batches * plan.compute_s_per_batch
                    + plan.n_rounds * (plan.sync_s + plan.update_s))

        # billing policy comes from the ArchSpec: Lambda archs bill each
        # worker's invocation wall-clock, stateful instances (the GPU
        # baseline) bill for the whole makespan
        total_cost = get_arch(plan.arch).fleet_cost(
            ((w.done_time or makespan) - w.spawn_time
             for w in self.workers),
            plan.ram_gb, makespan, len(self.workers))

        stage_totals = {"cold_start": 0.0, "fetch": 0.0, "compute": 0.0,
                        "sync": 0.0, "update": 0.0, "wait": 0.0,
                        "replay": 0.0}
        for w in self.workers:
            stage_totals["cold_start"] += w.s_cold
            stage_totals["fetch"] += w.s_fetch
            stage_totals["compute"] += w.s_compute
            stage_totals["sync"] += w.s_sync
            stage_totals["update"] += w.s_update
            stage_totals["wait"] += w.s_wait
            stage_totals["replay"] += w.s_replay
        alive_end = sum(1 for w in self.workers if w.alive)
        return RuntimeReport(
            arch=plan.arch, makespan_s=makespan, analytic_s=analytic,
            rounds=self.round_idx,
            work_done_batches=plan.n_workers * plan.total_batches
            - max(self.pool, 0.0),
            n_workers_start=plan.n_workers,
            n_workers_peak=len(self.workers),
            n_workers_end=alive_end, total_cost=total_cost,
            stage_totals=stage_totals, recoveries=self.recoveries,
            poisoned_updates=self.poisoned, masked_updates=self.masked,
            scale_events=self.scale_events, timeline=self.timeline)


# opcode -> handler, indexed by the _OP_* constants; class-level so the
# table is built once, not per epoch
EventRuntime._OPS = (
    EventRuntime._h_cold_done, EventRuntime._h_loaded,
    EventRuntime._h_round_loaded, EventRuntime._h_computed,
    EventRuntime._h_synced, EventRuntime._h_updated,
    EventRuntime._h_release, EventRuntime._h_maybe_release,
    EventRuntime._h_crash, EventRuntime._h_respawn)


def run_event_epoch(arch: str, *, n_params: int, compute_s_per_batch: float,
                    setup: ServerlessSetup = ServerlessSetup(),
                    significant_fraction: float = 0.3,
                    accumulation: int = 24,
                    faults: Optional[FaultPlan] = None,
                    recovery=None,
                    autoscaler=None, robust_trim: int = 0,
                    max_timeline: int = 0) -> RuntimeReport:
    """One event-driven epoch; mirrors ``simulate_epoch``'s signature.

    ``recovery`` accepts a :class:`RecoveryPolicy`, one of the strings
    ``"auto"`` (resolve the architecture's default via
    :func:`default_recovery`) / ``"restore"`` / ``"takeover"`` (the
    sweep layer's vocabulary), or ``None`` (checkpoint-restore — the
    frozen reference engine's behaviour, kept so ``runtime_ref``
    equivalence scenarios stay policy-identical).
    """
    if isinstance(recovery, str):
        recovery = resolve_recovery(arch, recovery)
    plan = round_plan(arch, n_params=n_params,
                      compute_s_per_batch=compute_s_per_batch, setup=setup,
                      significant_fraction=significant_fraction,
                      accumulation=accumulation)
    return EventRuntime(plan, setup, faults=faults, recovery=recovery,
                        autoscaler=autoscaler, robust_trim=robust_trim,
                        max_timeline=max_timeline).run()
