"""Pluggable architecture registry: one :class:`ArchSpec` per training
architecture, unifying every layer of the serverless stack.

Before this module the five paper architectures lived in five places in
lock-step: a string if-chain in ``simulator._round_terms``, a
hard-coded ``ARCHS`` tuple, ``gpu`` special-cases in the cost formulas
and the event runtime's billing, a spirt special-case in default
recovery resolution, and a parallel-but-disconnected class set in
``repro.core.strategies``.  Adding an architecture meant editing all of
them.  Now an architecture is ONE frozen :class:`ArchSpec` carrying

  * ``round_terms``   — the per-round stage arithmetic (elementwise:
    scalars or numpy arrays, so the same function backs the scalar
    ``simulate_epoch`` and the vectorized ``sweep_analytic``);
  * ``stateful``      — whether state loads once per epoch (the GPU
    baseline) or once per round (stateless Lambda);
  * ``sync_channel``  — an optional *pinned* gradient channel (the GPU
    baseline always exchanges via S3 regardless of the configured
    channel; sweeps use this to mark label-vs-numbers mismatches);
  * ``cost`` / ``fleet_cost`` — analytic and event-engine billing
    (Lambda GB-seconds vs instance-hours);
  * ``default_recovery`` — what crash recovery the architecture gets
    when the caller asks for ``"auto"`` (SPIRT-style in-DB state means
    peer takeover; everything else re-invokes and replays);
  * ``jax_strategy``  — the :mod:`repro.core.strategies` name realizing
    the architecture on real hardware, so the simulated arch and the
    real-training arch are one object
    (``repro.core.get_strategy(spec.name)`` resolves through here);
  * ``anchor`` / ``compute_share`` — which paper Table 2 row calibrates
    ``simulator.paper_compute_anchor`` for the architecture.

``register_arch`` / ``get_arch`` / ``list_archs`` manage the registry.
The five paper architectures (``paper=True``) register first, in the
paper's order; ``simulator.ARCHS`` is derived from them.  Two
beyond-paper hybrids register below with zero edits anywhere else —
they flow automatically through ``sweep_analytic``, ``sweep_events``
(including trace replay), the event engine, and the Pareto/knee
benchmarks:

  hier_spirt  two-level hierarchy: SPIRT's in-DB averaging inside
              sqrt(W)-sized groups, ScatterReduce-style chunk exchange
              across group leaders (the hybrid direction SPIRT's P2P
              fault-tolerance lineage — arXiv 2309.14148 / 2302.13995
              — points at).
  spirt_s3    SPIRT semantics with the gradient path pinned to S3,
              isolating the Redis premium from the algorithm.

A further family of asynchronous / semi-sync / compressed variants
(``local_sgd``, ``async_spirt``, ``async_spirt_q8``,
``scatterreduce_q8``, ``spirt_sf``) registers at the bottom of this
module: ``barrier_sync=False`` switches the event runtime to
barrier-free per-worker commits under a bounded-staleness convergence
tax, and ``compression`` scales the wire bytes through
:data:`COMPRESSION_SCHEMES` (int8 quantization per
``QuantizedScatterReduce``, MLLess significance filtering).  See
``examples/async_comm_sweep.py``.

See ``examples/custom_arch.py`` for registering a third-party
architecture in ~20 lines.  This module stays import-light (numpy +
pricing only — no jax), so analytic sweeps never pay accelerator
import costs; ``ArchSpec.make_strategy`` lazy-imports the JAX side.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.costmodel import pricing


# ---------------------------------------------------------------------------
# Channels (moved here from simulator.py, which re-exports them)
# ---------------------------------------------------------------------------
def _transfer(nbytes, bandwidth_Bps, latency_s, ops=1):
    """Channel transfer time.  Elementwise — every argument may be a
    Python scalar or a broadcastable numpy array, which is what lets the
    vectorized sweep (``repro.serverless.sweep``) evaluate whole grids
    through the *same* expressions the scalar path uses (exact
    agreement by construction)."""
    return nbytes / bandwidth_Bps + ops * latency_s


@dataclasses.dataclass(frozen=True)
class Channel:
    """External state channel (Redis on EC2 / S3)."""
    name: str = "redis"
    bandwidth_Bps: float = 1.25e9 / 8 * 10      # ~10 Gb EC2 NIC -> 1.25 GB/s
    latency_s: float = 0.002                    # per operation RTT

    def transfer(self, nbytes: float, ops: int = 1) -> float:
        return _transfer(nbytes, self.bandwidth_Bps, self.latency_s, ops)


S3 = Channel("s3", bandwidth_Bps=0.6e9, latency_s=0.030)
REDIS = Channel("redis")


def _grad_bytes(n_params: int, dtype_bytes: int = 4) -> float:
    return n_params * dtype_bytes


# ---------------------------------------------------------------------------
# Wire compression schemes
# ---------------------------------------------------------------------------
# Mirrors repro.core.compression.QuantizedScatterReduce.chunk — the wire
# factor below must stay in lock-step with that strategy's comm_bytes.
_Q8_CHUNK = 512


def _int8_wire_scale(significant_fraction):
    # int8 payload plus one fp32 scale per chunk: the exact per-byte
    # factor QuantizedScatterReduce.comm_bytes charges.  The *update*
    # path shrinks by the same factor because the aggregate is
    # requantized before the all-gather — the update IS int8 + scales.
    return 0.25 * (1.0 + 4.0 / _Q8_CHUNK)


def _significance_wire_scale(significant_fraction):
    # MLLess semantics: only the significant fraction of the gradient
    # crosses the wire (error feedback keeps the rest local).  Only
    # meaningful for archs whose update path is in-DB (update_bytes=0);
    # a dense model pull would not be filtered.
    return significant_fraction


COMPRESSION_SCHEMES: Dict[str, Callable[[Any], Any]] = {
    "int8": _int8_wire_scale,
    "significance": _significance_wire_scale,
}


# ---------------------------------------------------------------------------
# Billing policies
# ---------------------------------------------------------------------------
def lambda_epoch_cost(per_worker_s, ram_gb, n_workers):
    """Analytic epoch billing for stateless Lambda workers; elementwise
    ``(cost_per_worker, total_cost)``."""
    cost_worker = pricing.lambda_cost(per_worker_s, ram_gb)
    return cost_worker, cost_worker * n_workers


def instance_epoch_cost(per_worker_s, ram_gb, n_workers):
    """Analytic epoch billing for stateful instances (GPU baseline):
    hourly rate, RAM tier is part of the instance price."""
    cost_worker = pricing.gpu_cost(per_worker_s)
    return cost_worker, cost_worker * n_workers


def lambda_fleet_cost(wall_clocks, ram_gb, makespan_s, n_instances):
    """Event-engine billing: each Lambda bills GB-seconds for its whole
    invocation wall-clock (barrier stalls included)."""
    return sum(pricing.lambda_cost(t, ram_gb) for t in wall_clocks)


def instance_fleet_cost(wall_clocks, ram_gb, makespan_s, n_instances):
    """Event-engine billing: instances bill hourly for the makespan."""
    return pricing.gpu_cost(makespan_s, n_instances=n_instances)


# ---------------------------------------------------------------------------
# ArchSpec + registry
# ---------------------------------------------------------------------------
TermFn = Callable[..., Dict[str, Any]]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """Everything the stack needs to know about one architecture.

    ``round_terms(G=, W=, bw=, lat=, sync_bw=, sync_lat=, nb=,
    significant_fraction=, accumulation=)`` returns the per-round dict
    (``n_rounds``, ``batches_per_round``, ``sync_s``, ``update_s``,
    ``sync_bytes``, ``update_bytes``); the shared dispatcher
    :func:`arch_round_terms` adds the common state-load term and the
    ``stateful`` fetch policy.  ``sync_bw``/``sync_lat`` are the
    gradient path's channel — the configured one unless
    ``sync_channel`` pins it.
    """
    name: str
    round_terms: TermFn
    description: str = ""
    paper: bool = False                    # one of the paper's five
    stateful: bool = False                 # load state once per epoch
    sync_channel: Optional[Channel] = None  # pinned gradient channel
    cost: Callable = lambda_epoch_cost
    fleet_cost: Callable = lambda_fleet_cost
    default_recovery: str = "restore"      # "restore" | "takeover"
    jax_strategy: Optional[str] = None     # repro.core.get_strategy name
    jax_strategy_kwargs: Tuple[Tuple[str, Any], ...] = ()
    ram_scales_compute: bool = True        # Lambda vCPU scales with RAM
    anchor: Optional[str] = None           # PAPER_TABLE2 calibration row
    compute_share: float = 0.85            # compute share of paper time
    # how the architecture combines the fleet's gradients when workers
    # may be adversarial — the paper's per-arch vulnerability story:
    # SPIRT's in-database aggregation is byzantine-robust (trimmed
    # mean), everything else plain-averages.  Must name a simulated
    # aggregator in repro.serverless.adversarial.SIM_AGGREGATORS;
    # benchmarks/adversarial_curves.py draws each architecture's
    # byzantine-fraction degradation curve under this statistic.
    default_aggregator: str = "mean"
    # --- asynchrony ---------------------------------------------------
    # barrier_sync=False makes the event runtime commit each worker's
    # sync immediately instead of waiting at the round barrier:
    # stragglers no longer stall the fleet, but convergence pays a
    # staleness tax.  Async specs MUST declare a bounded staleness model
    # (the `staleness-spec` lint rule pins this statically, the
    # validation below pins it at runtime): the effective staleness —
    # (W - 1) concurrent unsynced peers for barrier-free specs,
    # (accumulation - 1) deferred local steps for semi-sync ones — is
    # capped at `staleness_bound`, and the work to converge inflates by
    # (1 + staleness_penalty * min(staleness, staleness_bound)),
    # modeled like the accumulation-fraction axis: folded into the
    # per-round terms so round counts stay integral.
    barrier_sync: bool = True
    staleness_bound: float = 0.0
    staleness_penalty: float = 0.0
    # optional wire-compression scheme applied to the gradient bytes G
    # before the round terms are computed — a COMPRESSION_SCHEMES key
    compression: Optional[str] = None

    def __post_init__(self):
        if self.default_recovery not in ("restore", "takeover"):
            raise ValueError(
                f"arch {self.name!r}: default_recovery must be "
                f"'restore' or 'takeover', got "
                f"{self.default_recovery!r}")
        from repro.serverless.adversarial import SIM_AGGREGATORS
        if self.default_aggregator not in SIM_AGGREGATORS:
            raise ValueError(
                f"arch {self.name!r}: default_aggregator must be one "
                f"of {', '.join(SIM_AGGREGATORS)}, got "
                f"{self.default_aggregator!r}")
        if self.staleness_bound < 0 or self.staleness_penalty < 0:
            raise ValueError(
                f"arch {self.name!r}: staleness_bound/staleness_penalty "
                "must be non-negative")
        if not self.barrier_sync:
            if not (self.staleness_bound > 0
                    and math.isfinite(self.staleness_bound)):
                raise ValueError(
                    f"arch {self.name!r}: barrier-free (async) specs "
                    "must declare a finite positive staleness_bound, "
                    f"got {self.staleness_bound!r}")
            if not self.staleness_penalty > 0:
                raise ValueError(
                    f"arch {self.name!r}: barrier-free (async) specs "
                    "must declare a positive staleness_penalty, got "
                    f"{self.staleness_penalty!r}")
        if (self.compression is not None
                and self.compression not in COMPRESSION_SCHEMES):
            raise ValueError(
                f"arch {self.name!r}: unknown compression "
                f"{self.compression!r}; registered: "
                f"{', '.join(COMPRESSION_SCHEMES)}")

    def pins_channel(self, channel: Channel) -> bool:
        """True when the configured ``channel`` is overridden by this
        architecture's pinned gradient channel — the grid point's label
        then disagrees with its sync numbers and sweeps mark it."""
        return (self.sync_channel is not None
                and channel.name != self.sync_channel.name)

    def make_strategy(self, **overrides):
        """The real-training :class:`repro.core.strategies.Strategy`
        realizing this architecture (lazy import — keeps this module
        jax-free for analytic-only users)."""
        if self.jax_strategy is None:
            raise ValueError(f"arch {self.name!r} has no JAX strategy")
        from repro.core.strategies import STRATEGIES, get_strategy
        if self.jax_strategy == self.name \
                and self.jax_strategy not in STRATEGIES:
            # get_strategy falls through to the registry for arch names
            # it doesn't know, so a spec naming itself (with no
            # concrete strategy behind the name — unlike e.g. spirt,
            # which IS a STRATEGIES entry) would recurse forever
            raise ValueError(
                f"arch {self.name!r} names itself as its jax_strategy; "
                "name a concrete repro.core.strategies entry instead")
        kw = dict(self.jax_strategy_kwargs)
        kw.update(overrides)
        return get_strategy(self.jax_strategy, **kw)


_REGISTRY: Dict[str, ArchSpec] = {}


def register_arch(spec: ArchSpec, *, overwrite: bool = False) -> ArchSpec:
    """Add ``spec`` to the registry (returns it, so modules can keep a
    handle).  Re-registering a name is an error unless ``overwrite``
    — silent replacement is how five-files-in-lock-step bugs start."""
    if not overwrite and spec.name in _REGISTRY:
        raise ValueError(f"architecture {spec.name!r} is already "
                         "registered (pass overwrite=True to replace)")
    _REGISTRY[spec.name] = spec
    return spec


def unregister_arch(name: str) -> None:
    """Remove an architecture (tests / examples cleaning up after
    themselves)."""
    _REGISTRY.pop(name, None)


def get_arch(name: str) -> ArchSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown architecture {name!r}; registered: "
            f"{', '.join(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    """All registered architecture names, in registration order (the
    paper's five first)."""
    return tuple(_REGISTRY)


def paper_archs() -> Tuple[str, ...]:
    """The paper's comparison set (``simulator.ARCHS`` derives from
    this)."""
    return tuple(n for n, s in _REGISTRY.items() if s.paper)


# ---------------------------------------------------------------------------
# Shared dispatcher (backs simulator._round_terms and the sweeps)
# ---------------------------------------------------------------------------
def arch_round_terms(arch, *, n_params, n_workers, bandwidth_Bps,
                     latency_s, batches_per_worker, model_bytes,
                     minibatch_bytes, significant_fraction, accumulation):
    """Per-round stage arithmetic for one architecture, resolved through
    the registry.  Elementwise: every numeric argument may be a scalar
    or a broadcastable numpy array — one implementation backs BOTH the
    scalar :func:`repro.serverless.simulator.round_plan` and the
    vectorized analytic sweep, so the two agree bit-for-bit.

    Alongside each stage *time* the spec returns the exact wire *bytes*
    the stage moves (the sum of the ``nbytes`` arguments fed to the
    channel) — per-op latencies contribute seconds but never bytes.
    """
    spec = arch if isinstance(arch, ArchSpec) else get_arch(arch)
    if spec.sync_channel is not None:
        sync_bw = spec.sync_channel.bandwidth_Bps
        sync_lat = spec.sync_channel.latency_s
    else:
        sync_bw, sync_lat = bandwidth_Bps, latency_s
    G = _grad_bytes(n_params)
    if spec.compression is not None:
        # wire compression shrinks the gradient bytes every stage moves
        # (the schemes are only paired with term fns whose update path
        # is either in-DB or itself compressed — see the scheme notes)
        G = G * COMPRESSION_SCHEMES[spec.compression](significant_fraction)
    terms = spec.round_terms(
        G=G, W=n_workers,
        bw=bandwidth_Bps, lat=latency_s,
        sync_bw=sync_bw, sync_lat=sync_lat,
        nb=batches_per_worker,
        significant_fraction=significant_fraction,
        accumulation=accumulation)
    if spec.staleness_penalty:
        # converging under staleness needs `factor`x the gradient work;
        # fold it into the per-round terms (keeping n_rounds integral so
        # the scalar and vectorized paths stay bit-exact) — the state
        # reload amortizes, so the tax lands on compute and comm
        staleness = (n_workers - 1.0) if not spec.barrier_sync \
            else (accumulation - 1.0)
        factor = 1.0 + spec.staleness_penalty \
            * np.minimum(staleness, spec.staleness_bound)
        for key in ("batches_per_round", "sync_s", "update_s",
                    "sync_bytes", "update_bytes"):
            terms[key] = terms[key] * factor
    terms["barrier"] = spec.barrier_sync
    # every invocation of a stateless worker reloads model + minibatch;
    # stateful archs pay it once (fetch_first_round_only)
    terms["fetch_s"] = _transfer(model_bytes + minibatch_bytes,
                                 bandwidth_Bps, latency_s, ops=2)
    terms["fetch_first_round_only"] = spec.stateful
    return terms


def arch_epoch_cost(arch, per_worker_s, ram_gb, n_workers):
    """(cost_per_worker, total_cost); elementwise in the numeric args."""
    spec = arch if isinstance(arch, ArchSpec) else get_arch(arch)
    return spec.cost(per_worker_s, ram_gb, n_workers)


# ---------------------------------------------------------------------------
# The paper's five architectures
# ---------------------------------------------------------------------------
def _spirt_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
                 significant_fraction, accumulation):
    # one long-lived invocation per epoch computes `accumulation`
    # minibatches; gradients averaged IN the local Redis (in-database
    # ops): per-minibatch store + one in-db average; a single
    # cross-worker sync per accumulation round.
    invocations = np.maximum(1, nb // accumulation)
    bpr = nb / invocations
    cross = (W - 1) * _transfer(G, sync_bw, sync_lat, ops=2) \
        + 2 * sync_lat * W                  # sync queue polls
    return dict(n_rounds=invocations, batches_per_round=bpr,
                sync_s=bpr * _transfer(G, sync_bw, sync_lat, ops=1)
                + cross,
                update_s=_transfer(0, sync_bw, sync_lat, ops=1),  # in-db
                sync_bytes=bpr * G + (W - 1) * G,
                update_bytes=0 * G)


def _mlless_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
                  significant_fraction, accumulation):
    # per-minibatch invocations; only significant updates pushed;
    # supervisor round-trip gates every sync step
    pushed = significant_fraction * G
    per_sync = (_transfer(pushed, sync_bw, sync_lat, ops=1)
                + (W - 1) * _transfer(pushed, sync_bw, sync_lat, ops=1)
                + 4 * sync_lat              # queue notify + supervisor
                + 2 * sync_lat * W)         # supervisor fan-out
    return dict(n_rounds=nb, batches_per_round=1.0,
                sync_s=per_sync,
                update_s=_transfer(G, sync_bw, sync_lat, ops=1),
                sync_bytes=pushed + (W - 1) * pushed,
                update_bytes=1.0 * G)


def _scatterreduce_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
                         significant_fraction, accumulation):
    # push W-1 chunks, fetch W-1 assigned chunks, push aggregate,
    # fetch W-1 aggregated chunks
    chunk = G / W
    per_sync = (_transfer((W - 1) * chunk, sync_bw, sync_lat,
                          ops=W - 1) * 2
                + _transfer(chunk, sync_bw, sync_lat, ops=1)
                + _transfer((W - 1) * chunk, sync_bw, sync_lat,
                            ops=W - 1))
    return dict(n_rounds=nb, batches_per_round=1.0,
                sync_s=per_sync,
                update_s=_transfer(G, sync_bw, sync_lat, ops=1),
                sync_bytes=(W - 1) * chunk * 2 + chunk + (W - 1) * chunk,
                update_bytes=1.0 * G)


def _allreduce_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
                     significant_fraction, accumulation):
    # everyone pushes G; the designated master then pulls all W
    # gradients SERIALLY, aggregates and pushes the result; every
    # worker blocks on the master (the paper's §4.2 scalability
    # bottleneck), then fetches
    master_path = W * _transfer(G, sync_bw, sync_lat, ops=1) \
        + _transfer(G, sync_bw, sync_lat, ops=1)
    per_sync = (_transfer(G, sync_bw, sync_lat, ops=1) + master_path
                + _transfer(G, sync_bw, sync_lat, ops=1))
    return dict(n_rounds=nb, batches_per_round=1.0,
                sync_s=per_sync,
                update_s=_transfer(G, sync_bw, sync_lat, ops=1),
                sync_bytes=1.0 * G + (W * G + G) + G,
                update_bytes=1.0 * G)


def _gpu_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
               significant_fraction, accumulation):
    # stateful: load once; gradient exchange on the pinned S3 channel
    per_sync = _transfer(G, sync_bw, sync_lat, ops=1) \
        + (W - 1) * _transfer(G, sync_bw, sync_lat, ops=1)
    return dict(n_rounds=nb, batches_per_round=1.0,
                sync_s=per_sync, update_s=0.0,
                sync_bytes=1.0 * G + (W - 1) * G,
                update_bytes=0 * G)


register_arch(ArchSpec(
    name="spirt", round_terms=_spirt_terms, paper=True,
    description="P2P; per-worker in-DB gradient averaging + in-DB "
                "update, one cross-worker sync per accumulation round",
    default_recovery="takeover", default_aggregator="trimmed_mean",
    jax_strategy="spirt", jax_strategy_kwargs=(("microbatches", 4),)))

register_arch(ArchSpec(
    name="mlless", round_terms=_mlless_terms, paper=True,
    description="significance filtering; supervisor-coordinated sync",
    jax_strategy="mlless", jax_strategy_kwargs=(("threshold", 0.7),)))

register_arch(ArchSpec(
    name="scatterreduce", round_terms=_scatterreduce_terms, paper=True,
    description="chunk ownership; 2 rounds of chunk exchange",
    jax_strategy="scatterreduce"))

register_arch(ArchSpec(
    name="allreduce", round_terms=_allreduce_terms, paper=True,
    description="master aggregates; everyone else pushes+polls",
    jax_strategy="parameter_server"))

register_arch(ArchSpec(
    name="gpu", round_terms=_gpu_terms, paper=True,
    description="stateful instances; S3 gradient exchange only",
    stateful=True, sync_channel=S3,
    cost=instance_epoch_cost, fleet_cost=instance_fleet_cost,
    jax_strategy="allreduce",              # ring all-reduce on-device
    ram_scales_compute=False,              # fixed by the accelerator
    compute_share=0.90))


# ---------------------------------------------------------------------------
# Beyond-paper hybrids — registered here and NOWHERE else; everything
# downstream (sweeps, event engine, trace replay, Pareto/knee
# benchmarks) picks them up through the registry.
# ---------------------------------------------------------------------------
def _hier_spirt_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
                      significant_fraction, accumulation):
    # two-level hierarchy: SPIRT's in-DB averaging inside groups of
    # ~sqrt(W) workers, then a ScatterReduce-style chunk exchange among
    # the group leaders.  Group-local traffic is identical to SPIRT
    # with W -> group size; the cross-group path moves n_groups chunks
    # of G / n_groups bytes instead of (W-1) full gradients, which is
    # what flattens the sync wall at large W.
    group = np.maximum(1, np.floor(np.sqrt(W)))
    n_groups = np.ceil(W / group)
    invocations = np.maximum(1, nb // accumulation)
    bpr = nb / invocations
    local = bpr * _transfer(G, sync_bw, sync_lat, ops=1) \
        + (group - 1) * _transfer(G, sync_bw, sync_lat, ops=2) \
        + 2 * sync_lat * group              # group-local queue polls
    chunk = G / n_groups
    cross = (_transfer((n_groups - 1) * chunk, sync_bw, sync_lat,
                       ops=n_groups - 1) * 2
             + _transfer(chunk, sync_bw, sync_lat, ops=1))
    return dict(n_rounds=invocations, batches_per_round=bpr,
                sync_s=local + cross,
                update_s=_transfer(0, sync_bw, sync_lat, ops=1),  # in-db
                sync_bytes=bpr * G + (group - 1) * G
                + (n_groups - 1) * chunk * 2 + chunk,
                update_bytes=0 * G)


register_arch(ArchSpec(
    name="hier_spirt", round_terms=_hier_spirt_terms,
    description="two-level SPIRT: group-local in-DB averaging, "
                "cross-group chunk exchange among leaders",
    default_recovery="takeover",           # state lives in the DB
    default_aggregator="trimmed_mean",     # in-DB robust statistic
    jax_strategy="spirt", jax_strategy_kwargs=(("microbatches", 4),),
    anchor="spirt"))

register_arch(ArchSpec(
    name="spirt_s3", round_terms=_spirt_terms,
    description="SPIRT semantics over the S3 channel (isolates the "
                "Redis premium from the algorithm)",
    sync_channel=S3,
    default_recovery="takeover",           # state lives in S3 instead
    default_aggregator="trimmed_mean",     # in-DB robust statistic
    jax_strategy="spirt", jax_strategy_kwargs=(("microbatches", 4),),
    anchor="spirt"))


# ---------------------------------------------------------------------------
# Asynchronous / semi-sync / compressed-communication architectures.
# Registered here and NOWHERE else (the PR 4 extension rule): the paper
# specs above are pinned bit-exactly by tests/golden/, so the missing
# axis of the cost-performance analysis — staleness-tolerant peer
# updates (SPIRT's in-DB lineage, arXiv 2309.14148) and compressed
# wire bytes (arXiv 2105.07806's communication-dominates result) —
# enters purely additively.
# ---------------------------------------------------------------------------
def _async_spirt_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
                       significant_fraction, accumulation):
    # barrier-free SPIRT: accumulate like SPIRT, but instead of the
    # (W-1)-wide cross-worker exchange + queue polls, each worker
    # pushes its accumulated gradient to the shared store and pulls the
    # merged state whenever it arrives — O(1) in W.  Dropping the
    # (W-1) term is the whole speedup; the dispatcher's staleness
    # factor is the price.
    invocations = np.maximum(1, nb // accumulation)
    bpr = nb / invocations
    return dict(n_rounds=invocations, batches_per_round=bpr,
                sync_s=bpr * _transfer(G, sync_bw, sync_lat, ops=1)
                + _transfer(G, sync_bw, sync_lat, ops=2),
                update_s=_transfer(0, sync_bw, sync_lat, ops=1),  # in-db
                sync_bytes=bpr * G + G,
                update_bytes=0 * G)


def _local_sgd_terms(*, G, W, bw, lat, sync_bw, sync_lat, nb,
                     significant_fraction, accumulation):
    # semi-sync local SGD: `accumulation` local steps between barriers,
    # each barrier a ScatterReduce-style chunk exchange of the
    # accumulated model delta (same wire pattern as scatterreduce, but
    # amortized over the sync period)
    invocations = np.maximum(1, nb // accumulation)
    bpr = nb / invocations
    chunk = G / W
    per_sync = (_transfer((W - 1) * chunk, sync_bw, sync_lat,
                          ops=W - 1) * 2
                + _transfer(chunk, sync_bw, sync_lat, ops=1)
                + _transfer((W - 1) * chunk, sync_bw, sync_lat,
                            ops=W - 1))
    return dict(n_rounds=invocations, batches_per_round=bpr,
                sync_s=per_sync,
                update_s=_transfer(G, sync_bw, sync_lat, ops=1),
                sync_bytes=(W - 1) * chunk * 2 + chunk + (W - 1) * chunk,
                update_bytes=1.0 * G)


register_arch(ArchSpec(
    name="local_sgd", round_terms=_local_sgd_terms,
    description="semi-sync local SGD: accumulation local steps per "
                "barrier, chunked delta exchange at each barrier; the "
                "deferred steps pay the staleness tax",
    staleness_penalty=0.004, staleness_bound=16.0,
    jax_strategy="spirt", jax_strategy_kwargs=(("microbatches", 4),),
    anchor="scatterreduce"))

register_arch(ArchSpec(
    name="async_spirt", round_terms=_async_spirt_terms,
    barrier_sync=False, staleness_bound=8.0, staleness_penalty=0.02,
    description="barrier-free SPIRT: workers push/pull the shared "
                "in-DB state without waiting for peers; bounded "
                "staleness, stragglers never stall the fleet",
    default_recovery="takeover",           # state lives in the DB
    default_aggregator="trimmed_mean",     # in-DB robust statistic
    jax_strategy="spirt", jax_strategy_kwargs=(("microbatches", 4),),
    anchor="spirt"))

register_arch(ArchSpec(
    name="async_spirt_q8", round_terms=_async_spirt_terms,
    barrier_sync=False, staleness_bound=8.0, staleness_penalty=0.02,
    compression="int8",
    description="async SPIRT with int8-quantized pushes (wire bytes "
                "follow QuantizedScatterReduce's payload factor)",
    default_recovery="takeover",
    default_aggregator="trimmed_mean",
    jax_strategy="quantized_scatterreduce",
    anchor="spirt"))

register_arch(ArchSpec(
    name="scatterreduce_q8", round_terms=_scatterreduce_terms,
    compression="int8",
    description="ScatterReduce with int8-quantized chunk exchange + "
                "error feedback (realized by QuantizedScatterReduce "
                "on real hardware)",
    jax_strategy="quantized_scatterreduce",
    anchor="scatterreduce"))

register_arch(ArchSpec(
    name="spirt_sf", round_terms=_spirt_terms,
    compression="significance",
    description="SPIRT with MLLess-style significance filtering on "
                "the gradient path (error feedback preserves "
                "convergence; update stays in-DB)",
    default_recovery="takeover",
    default_aggregator="trimmed_mean",
    jax_strategy="mlless", jax_strategy_kwargs=(("threshold", 0.7),),
    anchor="spirt"))
