"""High-throughput Monte-Carlo sweep engine over the serverless models.

The paper's contribution is a *surface* — cost and makespan across five
architectures and many configurations — and every chart the ROADMAP
asks for (elastic pricing sweeps, fault-rate stress grids, Pareto
fronts) needs `simulate_epoch` / the event runtime evaluated thousands
of times.  This module provides the three performance layers:

  1. **Vectorized analytic path** — :func:`sweep_analytic` evaluates an
     entire :class:`SweepGrid` (arch x n_workers x RAM tier x channel x
     accumulation x significant_fraction) through the *same*
     elementwise formulas the scalar ``simulate_epoch`` uses
     (``simulator._round_terms`` / ``_epoch_terms`` / ``_epoch_cost``),
     just on numpy arrays: one block of array ops per
     (arch, channel) pair instead of one Python call per point, with
     bit-exact agreement against the scalar path
     (``tests/test_sweep.py``).

  2. **Seeded multi-replicate event sweep** — :func:`sweep_events` fans
     fault-injected :func:`~repro.serverless.runtime.run_event_epoch`
     grid points across processes, drawing one reproducible
     :meth:`FaultPlan.random` per (point, replicate) seed — or, with
     ``trace=``, one :meth:`FaultPlan.from_trace` replaying measured
     cold-start/straggler tails — and aggregates mean / p50 / p95
     time-to-recover, makespan and cost overhead per point.

  3. **Pareto extraction** — :func:`pareto_front` returns the
     non-dominated (cost, makespan) subset, which
     ``benchmarks/pareto_sweep.py`` charts per architecture.

Everything is deterministic from (grid, seed): replicate seeds are a
pure function of the point index, so any cell of any chart can be
re-run in isolation.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import os
import zlib
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serverless.adversarial import (SIM_AGGREGATORS,
                                          byzantine_fractions, get_attack,
                                          list_attacks,
                                          sim_aggregator_max_f)
from repro.serverless.archs import get_arch
from repro.serverless.autoscale import ReactiveAutoscaler
from repro.serverless.faults import FaultPlan
from repro.serverless.runtime import (RuntimeReport, resolve_recovery,
                                      run_event_epoch)
from repro.serverless.traces import Trace
from repro.serverless.simulator import (ARCHS, REDIS, Channel,
                                        ServerlessSetup, _epoch_cost,
                                        _epoch_terms, _round_terms,
                                        simulate_epoch)

ComputeModel = Union[float, Callable[[str, float], float]]


def ram_scaled_compute(anchor_s_per_batch: float, *,
                       ref_ram_gb: float = 2.0) -> Callable[[str, float],
                                                            float]:
    """Lambda allocates vCPU proportionally to RAM, so per-batch compute
    shrinks as the tier grows; architectures whose spec clears
    ``ram_scales_compute`` (the GPU baseline — compute fixed by the
    accelerator, not the tier) keep the anchor.  Returns a compute
    model for :class:`SweepGrid` anchored at ``ref_ram_gb``."""
    def model(arch: str, ram_gb: float) -> float:
        if not get_arch(arch).ram_scales_compute:
            return anchor_s_per_batch
        return anchor_s_per_batch * (ref_ram_gb / ram_gb)
    return model


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """Cross-product axes + fixed epoch parameters for an analytic sweep.

    ``compute_s_per_batch`` is either a constant or a callable
    ``(arch, ram_gb) -> seconds`` (see :func:`ram_scaled_compute`);
    either way it is resolved per (arch, RAM tier), never per point, so
    the vectorized path stays a handful of array ops.
    """
    n_params: int
    compute_s_per_batch: ComputeModel
    archs: Tuple[str, ...] = ARCHS
    n_workers: Tuple[int, ...] = (4,)
    ram_gb: Tuple[float, ...] = (2.0,)
    channels: Tuple[Channel, ...] = (REDIS,)
    accumulation: Tuple[int, ...] = (24,)
    significant_fraction: Tuple[float, ...] = (0.3,)
    batches_per_worker: int = 24
    cold_start_s: float = 2.5
    model_bytes: float = 17e6
    minibatch_bytes: float = 512 * 32 * 32 * 3 * 4

    @property
    def n_points(self) -> int:
        return (len(self.archs) * len(self.channels) * len(self.n_workers)
                * len(self.ram_gb) * len(self.accumulation)
                * len(self.significant_fraction))

    def compute_for(self, arch: str, ram_gb: float) -> float:
        c = self.compute_s_per_batch
        return float(c(arch, ram_gb)) if callable(c) else float(c)


def iter_grid(grid: SweepGrid) -> Iterator[dict]:
    """Scalar enumeration of the grid, in the exact order the
    vectorized sweep lays points out (arch, channel outer; then
    n_workers, ram, accumulation, significant_fraction with the last
    axis fastest)."""
    for arch in grid.archs:
        spec = get_arch(arch)
        for ch in grid.channels:
            for W in grid.n_workers:
                for ram in grid.ram_gb:
                    for acc in grid.accumulation:
                        for sig in grid.significant_fraction:
                            yield dict(
                                arch=arch, channel=ch, n_workers=W,
                                ram_gb=ram, accumulation=acc,
                                significant_fraction=sig,
                                channel_pinned=spec.pins_channel(ch),
                                compute_s_per_batch=grid.compute_for(
                                    arch, ram))


def point_setup(grid: SweepGrid, point: dict) -> ServerlessSetup:
    """The :class:`ServerlessSetup` equivalent of one grid point."""
    return ServerlessSetup(n_workers=point["n_workers"],
                           batches_per_worker=grid.batches_per_worker,
                           ram_gb=point["ram_gb"],
                           cold_start_s=grid.cold_start_s,
                           model_bytes=grid.model_bytes,
                           minibatch_bytes=grid.minibatch_bytes,
                           channel=point["channel"])


def scalar_sweep(grid: SweepGrid) -> list:
    """The equivalent loop of scalar ``simulate_epoch`` calls — the
    baseline the vectorized path is benchmarked (and exactness-tested)
    against."""
    out = []
    for p in iter_grid(grid):
        out.append(simulate_epoch(
            p["arch"], n_params=grid.n_params,
            compute_s_per_batch=p["compute_s_per_batch"],
            setup=point_setup(grid, p),
            significant_fraction=p["significant_fraction"],
            accumulation=p["accumulation"]))
    return out


@dataclasses.dataclass
class AnalyticSweep:
    """Columnar result of :func:`sweep_analytic` (one row per point)."""
    grid: SweepGrid
    arch: np.ndarray                  # str
    channel_idx: np.ndarray           # index into grid.channels
    n_workers: np.ndarray
    ram_gb: np.ndarray
    accumulation: np.ndarray
    significant_fraction: np.ndarray
    compute_s_per_batch: np.ndarray
    fetch_s: np.ndarray
    compute_s: np.ndarray
    sync_s: np.ndarray
    update_s: np.ndarray
    per_worker_s: np.ndarray
    per_batch_s: np.ndarray
    comm_bytes_per_worker: np.ndarray
    cost_per_worker: np.ndarray
    total_cost: np.ndarray
    # True where the arch's pinned sync channel overrides the grid's
    # channel label (e.g. gpu x redis: the sync numbers are S3's) —
    # ISSUE 4 satellite: such points used to masquerade as channel
    # comparisons
    channel_pinned: np.ndarray

    def __len__(self) -> int:
        return len(self.per_worker_s)

    def point(self, i: int) -> dict:
        """One row as a dict (channel resolved back to its object)."""
        return dict(arch=str(self.arch[i]),
                    channel=self.grid.channels[int(self.channel_idx[i])],
                    n_workers=int(self.n_workers[i]),
                    ram_gb=float(self.ram_gb[i]),
                    accumulation=int(self.accumulation[i]),
                    significant_fraction=float(
                        self.significant_fraction[i]),
                    channel_pinned=bool(self.channel_pinned[i]),
                    compute_s_per_batch=float(self.compute_s_per_batch[i]),
                    per_worker_s=float(self.per_worker_s[i]),
                    total_cost=float(self.total_cost[i]))

    def mask(self, arch: Optional[str] = None, *,
             drop_pinned: bool = False) -> np.ndarray:
        """Row selector.  ``drop_pinned=True`` removes the bogus
        channel-comparison points (grid channel overridden by the
        arch's pinned sync channel)."""
        m = (np.ones(len(self), bool) if arch is None
             else self.arch == arch)
        if drop_pinned:
            m = m & ~self.channel_pinned
        return m


def sweep_analytic(grid: SweepGrid) -> AnalyticSweep:
    """Evaluate the whole grid in one block of array ops per
    architecture — exact agreement with :func:`scalar_sweep`.

    The (channel, n_workers, ram, accumulation, significant_fraction)
    mesh is built once and shared by every architecture block; results
    land in preallocated columns by slice assignment, so per-point
    Python cost is zero and per-op numpy overhead amortizes with grid
    size."""
    W_ax = np.asarray(grid.n_workers)
    ram_ax = np.asarray(grid.ram_gb, float)
    acc_ax = np.asarray(grid.accumulation)
    sig_ax = np.asarray(grid.significant_fraction, float)
    bw_ax = np.asarray([c.bandwidth_Bps for c in grid.channels])
    lat_ax = np.asarray([c.latency_s for c in grid.channels])
    ch_ix, W, ram_ix, acc, sig = (m.ravel() for m in np.meshgrid(
        np.arange(len(grid.channels)), W_ax, np.arange(len(ram_ax)),
        acc_ax, sig_ax, indexing="ij"))
    bw, lat, ram = bw_ax[ch_ix], lat_ax[ch_ix], ram_ax[ram_ix]
    n = len(W)                         # points per architecture block
    N = n * len(grid.archs)

    arch_col = np.empty(N, dtype=f"U{max(len(a) for a in grid.archs)}")
    out = {k: np.empty(N) for k in
           ("fetch_s", "compute_s", "sync_s", "update_s", "per_worker_s",
            "per_batch_s", "comm_bytes_per_worker", "cost_per_worker",
            "total_cost", "compute_s_per_batch")}
    pinned_col = np.empty(N, bool)
    for ai, arch in enumerate(grid.archs):
        spec = get_arch(arch)
        # compute model resolved once per (arch, RAM tier)
        comp = np.asarray([grid.compute_for(arch, r)
                           for r in ram_ax])[ram_ix]
        terms = _round_terms(
            arch, n_params=grid.n_params, n_workers=W,
            bandwidth_Bps=bw, latency_s=lat,
            batches_per_worker=grid.batches_per_worker,
            model_bytes=grid.model_bytes,
            minibatch_bytes=grid.minibatch_bytes,
            significant_fraction=sig, accumulation=acc)
        ep = _epoch_terms(
            n_rounds=terms["n_rounds"],
            batches_per_round=terms["batches_per_round"],
            fetch_s=terms["fetch_s"],
            fetch_first_round_only=terms["fetch_first_round_only"],
            sync_s=terms["sync_s"], update_s=terms["update_s"],
            sync_bytes=terms["sync_bytes"],
            update_bytes=terms["update_bytes"],
            compute_s_per_batch=comp,
            cold_start_s=grid.cold_start_s,
            batches_per_worker=grid.batches_per_worker)
        cost_w, cost_t = _epoch_cost(arch, ep["per_worker"], ram, W)
        lo, hi = ai * n, (ai + 1) * n
        arch_col[lo:hi] = arch
        pinned_col[lo:hi] = np.asarray(
            [spec.pins_channel(c) for c in grid.channels])[ch_ix]
        out["compute_s_per_batch"][lo:hi] = comp
        out["fetch_s"][lo:hi] = ep["fetch"]
        out["compute_s"][lo:hi] = ep["compute"]
        out["sync_s"][lo:hi] = ep["sync"]
        out["update_s"][lo:hi] = ep["update"]
        out["per_worker_s"][lo:hi] = ep["per_worker"]
        out["per_batch_s"][lo:hi] = ep["per_batch"]
        out["comm_bytes_per_worker"][lo:hi] = ep["comm_bytes"]
        out["cost_per_worker"][lo:hi] = cost_w
        out["total_cost"][lo:hi] = cost_t
    tile = len(grid.archs)
    return AnalyticSweep(grid=grid, arch=arch_col,
                         channel_idx=np.tile(ch_ix, tile),
                         n_workers=np.tile(W, tile),
                         ram_gb=np.tile(ram, tile),
                         accumulation=np.tile(acc, tile),
                         significant_fraction=np.tile(sig, tile),
                         channel_pinned=pinned_col, **out)


def pareto_front(costs: Sequence[float],
                 times: Sequence[float]) -> np.ndarray:
    """Indices of the non-dominated (minimize cost, minimize time)
    points, in increasing-cost order."""
    costs = np.asarray(costs, float)
    times = np.asarray(times, float)
    order = np.lexsort((times, costs))      # by cost, then time
    front: List[int] = []
    best_t = np.inf
    for i in order:
        if times[i] < best_t:
            front.append(int(i))
            best_t = times[i]
    return np.asarray(front, int)


def knee_point(x: Sequence[float], y: Sequence[float]) -> int:
    """Index (into the ORIGINAL arrays) of the maximum-curvature point
    of ``y(x)`` — the ROADMAP's fault-rate knee: the rate beyond which
    an architecture's cost overhead stops degrading gracefully.

    Both axes are min-max normalized so the knee is scale-free, the
    points are sorted by ``x``, and discrete curvature
    ``|x'y'' - y'x''| / (x'^2 + y'^2)^{3/2}`` (central differences via
    ``np.gradient``) is evaluated at every sample; endpoints are
    excluded (their one-sided differences make them spurious argmaxes).
    Degenerate inputs — fewer than 3 points, or an axis with no spread
    — have no curvature anywhere and raise ``ValueError``.
    """
    x = np.asarray(x, float)
    y = np.asarray(y, float)
    if x.shape != y.shape or x.ndim != 1 or len(x) < 3:
        raise ValueError("knee_point needs two equal-length 1-D arrays "
                         f"of >= 3 points, got {x.shape} / {y.shape}")
    order = np.argsort(x, kind="stable")
    xs, ys = x[order], y[order]
    x_span, y_span = xs[-1] - xs[0], ys.max() - ys.min()
    if x_span <= 0 or y_span <= 0:
        raise ValueError("knee_point needs spread on both axes "
                         f"(x span {x_span}, y span {y_span})")
    xn = (xs - xs[0]) / x_span
    yn = (ys - ys.min()) / y_span
    dx, dy = np.gradient(xn), np.gradient(yn)
    d2x, d2y = np.gradient(dx), np.gradient(dy)
    with np.errstate(divide="ignore", invalid="ignore"):
        k = np.abs(dx * d2y - dy * d2x) \
            / np.maximum(dx * dx + dy * dy, 1e-300) ** 1.5
    k[0] = k[-1] = -np.inf                  # interior points only
    return int(order[int(np.argmax(k))])


# ---------------------------------------------------------------------------
# Layer 3: seeded multi-replicate event-engine sweep
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultRates:
    """Per-epoch expected fault rates fed to :meth:`FaultPlan.random`."""
    crash_rate: float = 0.0
    straggler_rate: float = 0.0
    byzantine_fraction: float = 0.0
    storm_prob: float = 0.0

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v < 0:
                raise ValueError(f"{f.name} must be >= 0, got {v}")


@dataclasses.dataclass(frozen=True)
class EventSweepPoint:
    """One event-engine configuration to replicate under random faults.

    ``recovery="auto"`` resolves the architecture's own
    :class:`~repro.serverless.archs.ArchSpec` default — peer takeover
    for the in-DB SPIRT family, checkpoint-restore for everything else
    (the pairing ``benchmarks/fault_tolerance.py`` measures);
    ``autoscale_max > 0`` attaches a :class:`ReactiveAutoscaler` with
    the given bounds.  A non-``None`` ``trace`` replays measured
    cold-start/straggler tails via :meth:`FaultPlan.from_trace` instead
    of the Poisson ``FaultRates`` draws (crash/byzantine rates still
    apply — they are not part of the measured trace); it overrides any
    sweep-level trace passed to :func:`sweep_events`.
    """
    arch: str
    n_params: int
    compute_s_per_batch: float
    setup: ServerlessSetup = ServerlessSetup()
    significant_fraction: float = 0.3
    accumulation: int = 24
    recovery: str = "auto"             # "auto" | "restore" | "takeover"
    checkpoint_every: int = 4
    autoscale_min: int = 1
    autoscale_max: int = 0             # 0 => fixed fleet
    robust_trim: int = 0
    trace: Optional[Trace] = None
    label: str = ""


@dataclasses.dataclass
class EventPointStats:
    """Replicate aggregates for one sweep point."""
    point: EventSweepPoint
    n_replicates: int
    analytic_makespan_s: float
    analytic_cost: float
    makespan_mean_s: float
    makespan_p50_s: float
    makespan_p95_s: float
    ttr_mean_s: float
    ttr_p50_s: float
    ttr_p95_s: float
    cost_mean: float
    cost_overhead_mean: float
    cost_overhead_p50: float
    cost_overhead_p95: float


def _replicate_seed(base_seed: int, point_idx: int, replicate: int) -> int:
    # disjoint, reproducible streams per (point, replicate)
    return base_seed + 100_003 * point_idx + replicate


def _resolve_recovery(point: EventSweepPoint):
    # one shared string -> policy mapping (runtime.resolve_recovery);
    # "auto" resolves the ArchSpec's own recovery design
    return resolve_recovery(point.arch, point.recovery,
                            checkpoint_every=point.checkpoint_every)


def run_point_replicate(point: EventSweepPoint, rates: FaultRates,
                        seed: int, horizon_s: float,
                        trace: Optional[Trace] = None) -> RuntimeReport:
    """One seeded fault-injected epoch of one sweep point.  With a
    trace (per-point beats sweep-level), cold-start/straggler behaviour
    is resampled from the measured distributions instead of the Poisson
    rates."""
    trace = point.trace if point.trace is not None else trace
    if trace is not None:
        faults = FaultPlan.from_trace(
            trace, seed=seed, n_workers=point.setup.n_workers,
            horizon_s=horizon_s,
            base_cold_start_s=point.setup.cold_start_s,
            crash_rate=rates.crash_rate,
            byzantine_fraction=rates.byzantine_fraction,
            # autoscaled joiners must pay measured cold starts too.
            # Worker ids are never reused, so budget draws for the worst
            # churn case: the ReactiveAutoscaler adds at most `step` (1)
            # per barrier and there are ~batches_per_worker barriers per
            # epoch, so cumulative joiners cannot reach the budget
            n_spare_workers=(point.autoscale_max
                             + point.setup.batches_per_worker
                             if point.autoscale_max > 0 else 0))
    else:
        faults = FaultPlan.random(
            seed=seed, n_workers=point.setup.n_workers,
            horizon_s=horizon_s, crash_rate=rates.crash_rate,
            straggler_rate=rates.straggler_rate,
            byzantine_fraction=rates.byzantine_fraction,
            storm_prob=rates.storm_prob)
    autoscaler = (ReactiveAutoscaler(min_workers=point.autoscale_min,
                                     max_workers=point.autoscale_max)
                  if point.autoscale_max > 0 else None)
    return run_event_epoch(
        point.arch, n_params=point.n_params,
        compute_s_per_batch=point.compute_s_per_batch, setup=point.setup,
        significant_fraction=point.significant_fraction,
        accumulation=point.accumulation, faults=faults,
        recovery=_resolve_recovery(point), autoscaler=autoscaler,
        robust_trim=point.robust_trim)


def _run_point_job(job) -> List[Tuple[float, float, float]]:
    """Worker-process entry: all replicates of one point.  Module-level
    so it pickles under ProcessPoolExecutor.  The point's ArchSpec
    rides along and is re-registered on arrival: spawned workers
    re-import the package with only the built-in registrations, so a
    caller-registered architecture (examples/custom_arch.py) would
    otherwise be unknown in the child."""
    point, spec, rates, seeds, horizon_s, base_makespan, trace = job
    from repro.serverless.archs import register_arch
    # unconditional overwrite: the parent's registration (including an
    # overwrite=True replacement of a built-in) must win over whatever
    # the child's fresh import registered
    register_arch(spec, overwrite=True)
    out = []
    for s in seeds:
        rep = run_point_replicate(point, rates, s, horizon_s, trace=trace)
        ttr = (rep.time_to_recover_s if rep.recoveries
               else max(rep.makespan_s - base_makespan, 0.0))
        out.append((rep.makespan_s, rep.total_cost, ttr))
    return out


def sweep_events(points: Sequence[EventSweepPoint], *,
                 rates: FaultRates = FaultRates(),
                 n_replicates: int = 8, seed: int = 0,
                 processes: Optional[int] = None,
                 trace: Optional[Trace] = None) -> List[EventPointStats]:
    """Replicate every point ``n_replicates`` times under seeded random
    faults, fanning points across ``processes`` worker processes
    (default: cpu count, capped at 8; pass 0/1 to run inline), and
    aggregate mean/p50/p95 makespan, time-to-recover and cost overhead.
    A ``trace`` switches every point (unless the point carries its own)
    from Poisson rate draws to trace-driven replay of measured
    cold-start/straggler tails — same seeding discipline, so results
    stay bit-reproducible from (points, trace, seed).
    """
    jobs = []
    bases = []
    for i, p in enumerate(points):
        base = simulate_epoch(p.arch, n_params=p.n_params,
                              compute_s_per_batch=p.compute_s_per_batch,
                              setup=p.setup,
                              significant_fraction=p.significant_fraction,
                              accumulation=p.accumulation)
        seeds = tuple(_replicate_seed(seed, i, r)
                      for r in range(n_replicates))
        jobs.append((p, get_arch(p.arch), rates, seeds, base.per_worker_s,
                     base.per_worker_s, trace))
        bases.append(base)
    if processes is None:
        processes = min(os.cpu_count() or 1, 8)
    if processes > 1 and len(jobs) > 1:
        # spawn, not fork: this module (transitively) imports jax, whose
        # thread pools make forking the parent deadlock-prone (jax warns
        # on os.fork()).  Spawned workers pay one interpreter+import
        # start-up each, amortized across the whole sweep — prefer
        # processes=1 for small grids.
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=processes,
                                 mp_context=ctx) as ex:
            raw = list(ex.map(_run_point_job, jobs))
    else:
        raw = [_run_point_job(j) for j in jobs]

    stats = []
    for p, base, trips in zip(points, bases, raw):
        mk = np.asarray([t[0] for t in trips])
        cost = np.asarray([t[1] for t in trips])
        ttr = np.asarray([t[2] for t in trips])
        over = cost / base.total_cost - 1.0
        stats.append(EventPointStats(
            point=p, n_replicates=n_replicates,
            analytic_makespan_s=base.per_worker_s,
            analytic_cost=base.total_cost,
            makespan_mean_s=float(mk.mean()),
            makespan_p50_s=float(np.percentile(mk, 50)),
            makespan_p95_s=float(np.percentile(mk, 95)),
            ttr_mean_s=float(ttr.mean()),
            ttr_p50_s=float(np.percentile(ttr, 50)),
            ttr_p95_s=float(np.percentile(ttr, 95)),
            cost_mean=float(cost.mean()),
            cost_overhead_mean=float(over.mean()),
            cost_overhead_p50=float(np.percentile(over, 50)),
            cost_overhead_p95=float(np.percentile(over, 95))))
    return stats


# ---------------------------------------------------------------------------
# Layer 4: adversarial byzantine-fraction sweep (ROADMAP's last PR-1 item)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdversarialGrid:
    """Byzantine fraction x attack model x aggregator grid over the
    deterministic quadratic-loss training path.

    The simulated optimum is the origin: worker ``i``'s honest gradient
    at step ``t`` is ``theta + noise[t, i]`` (quadratic loss
    ``0.5 * ||theta||^2`` plus seeded per-worker minibatch noise), the
    byzantine subset (the first ``round(fraction * W)`` workers —
    exchangeable, since the noise is i.i.d.) corrupts its rows through
    the registered attack model, and the aggregator's batched numpy
    twin (``repro.serverless.adversarial.SIM_AGGREGATORS``) reduces the
    stack — the same statistics real training applies on-device.  Empty
    ``fractions`` / ``attacks`` / ``aggregators`` default to everything
    registered: the full ladder 0 -> (W-1)/2W, every attack model, and
    every ``SIM_AGGREGATORS`` statistic.

    ``attack_scales`` overrides individual attacks' default magnitudes
    (e.g. ``(("little_is_enough", 50.0),)``); robust aggregators are
    configured with the oracle budget ``f = min(n_byz, feasible cap)``
    so a curve's collapse past its cap IS the breakdown point.
    """
    n_workers: int = 12
    dim: int = 24
    steps: int = 80
    lr: float = 0.25
    noise: float = 0.05
    init_dist: float = 4.0
    converge_tol: float = 0.25
    fractions: Tuple[float, ...] = ()
    attacks: Tuple[str, ...] = ()
    aggregators: Tuple[str, ...] = ()
    attack_scales: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if self.n_workers < 3:
            raise ValueError(f"n_workers must be >= 3, got "
                             f"{self.n_workers}")
        for field, lo in (("dim", 1), ("steps", 1)):
            if getattr(self, field) < lo:
                raise ValueError(f"{field} must be >= {lo}, got "
                                 f"{getattr(self, field)}")
        for field in ("lr", "init_dist", "converge_tol"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be > 0, got "
                                 f"{getattr(self, field)}")
        if not np.isfinite(self.noise) or self.noise < 0:
            raise ValueError(f"noise must be finite and >= 0, got "
                             f"{self.noise}")
        for a in self.aggregators:
            # unknown names fail HERE with the registered list, not as
            # a bare KeyError mid-sweep
            sim_aggregator_max_f(a, self.n_workers)

    # empty tuple = everything registered, mirroring fractions/attacks
    # (a third-party SIM_AGGREGATORS entry shows up in default sweeps
    # with no edits here)
    def resolved_aggregators(self) -> Tuple[str, ...]:
        return self.aggregators or tuple(SIM_AGGREGATORS)

    def resolved_attacks(self) -> Tuple[str, ...]:
        return self.attacks or list_attacks()

    def resolved_fractions(self) -> Tuple[float, ...]:
        return self.fractions or byzantine_fractions(self.n_workers)


@dataclasses.dataclass(frozen=True)
class AdversarialCell:
    """One (aggregator, attack, fraction) result row.  A trajectory
    that overflows clean through inf reports ``final_dist=inf`` (never
    NaN), so same-seed sweeps always compare ``==`` cell for cell."""
    aggregator: str
    attack: str
    fraction: float
    n_byz: int
    f_used: int                        # oracle byzantine budget applied
    final_dist: float                  # |theta - theta*| after `steps`
    min_dist: float
    converged_step: int                # first step <= converge_tol; -1
    diverged: bool                     # left the 10x init_dist ball


def _adv_rng(seed: int, *key: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=tuple(key)))


def adversarial_sweep(grid: AdversarialGrid, *,
                      seed: int = 0) -> List[AdversarialCell]:
    """Evaluate the whole grid, vectorized over the fraction axis (one
    ``[n_fractions, W, dim]`` array-op block per step per
    (aggregator, attack) pair) and bit-reproducible from
    ``(grid, seed)`` — the same seeding discipline as
    :func:`sweep_events`: honest minibatch noise and the stochastic
    attacks draw from disjoint ``SeedSequence`` sub-streams, and the
    honest stream is SHARED across every cell so curves differ only by
    the adversarial configuration."""
    W, D = grid.n_workers, grid.dim
    fractions = grid.resolved_fractions()
    attacks = grid.resolved_attacks()
    scales = dict(grid.attack_scales)
    for name in scales:
        get_attack(name)               # unknown override -> actionable
    n_byz = np.asarray([int(round(f * W)) for f in fractions])
    if np.any(n_byz < 0) or np.any(n_byz > (W - 1) // 2):
        raise ValueError(
            f"fractions {tuple(fractions)} leave the aggregatable range:"
            f" byzantine counts {n_byz.tolist()} must stay within "
            f"[0, (W-1)//2] = [0, {(W - 1) // 2}] at W={W}")
    byz_mask = np.arange(W) < n_byz[:, None]           # [n_frac, W]

    honest_noise = _adv_rng(seed, 0).standard_normal(
        (grid.steps, W, D)) * grid.noise
    direction = _adv_rng(seed, 1).standard_normal(D)
    theta0 = direction / max(np.linalg.norm(direction), 1e-12) \
        * grid.init_dist

    cells: List[AdversarialCell] = []
    for agg_name in grid.resolved_aggregators():
        agg = SIM_AGGREGATORS[agg_name]
        f_used = np.minimum(n_byz, sim_aggregator_max_f(agg_name, W))
        for attack_name in attacks:
            spec = get_attack(attack_name)
            scale = scales.get(attack_name, spec.default_scale)
            # sub-stream keyed by the attack NAME (crc32, not its grid
            # or registry position): stochastic attacks replay
            # identically when the grid shrinks elsewhere, and every
            # aggregator block re-creates the same generator so the
            # chart panels compare aggregators on IDENTICAL corrupted
            # inputs
            arng = _adv_rng(seed, 2,
                            zlib.crc32(attack_name.encode("utf-8")))
            theta = np.tile(theta0, (len(n_byz), 1))
            dist = np.empty((grid.steps + 1, len(n_byz)))
            dist[0] = grid.init_dist
            with np.errstate(over="ignore", invalid="ignore"):
                for t in range(grid.steps):
                    g = theta[:, None, :] + honest_noise[t][None]
                    g = spec.apply_rows(g, byz_mask, arng, scale)
                    theta = theta - grid.lr * agg(g, f_used)
                    dist[t + 1] = np.linalg.norm(theta, axis=-1)
            below = dist <= grid.converge_tol          # [steps+1, n_frac]
            first = np.where(below.any(axis=0),
                             below.argmax(axis=0), -1)
            final = dist[-1]
            for i, frac in enumerate(fractions):
                fin = float(final[i])
                diverged = bool(not np.isfinite(fin)
                                or fin > 10.0 * grid.init_dist)
                if not np.isfinite(fin):
                    # overflow poisons the float through inf to NaN;
                    # report inf so NaN != NaN can never break the
                    # same-seed equality contract (min_dist is always
                    # finite: dist[0] = init_dist)
                    fin = float("inf")
                cells.append(AdversarialCell(
                    aggregator=agg_name, attack=attack_name,
                    fraction=float(frac), n_byz=int(n_byz[i]),
                    f_used=int(f_used[i]), final_dist=fin,
                    min_dist=float(np.nanmin(dist[:, i])),
                    converged_step=int(first[i]), diverged=diverged))
    return cells


def adversarial_curve(cells: Sequence[AdversarialCell], aggregator: str,
                      attack: str, metric: str = "final_dist"
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """One degradation curve: ``(fractions, metric values)`` sorted by
    fraction for a given (aggregator, attack) pair."""
    rows = sorted(((c.fraction, getattr(c, metric)) for c in cells
                   if c.aggregator == aggregator and c.attack == attack))
    if not rows:
        raise ValueError(f"no cells for aggregator={aggregator!r}, "
                         f"attack={attack!r}")
    fr, val = zip(*rows)
    return np.asarray(fr), np.asarray(val, float)
