"""Serverless training-architecture simulator.

Models the paper's execution semantics (§2, Table 1): stateless Lambda
workers that must (re)load model+data every invocation, communicate
gradients through external channels (Redis / S3), and synchronize via
queues — per architecture:

  SPIRT          P2P; per-worker in-DB gradient averaging (24 minibatches
                 per invocation via gradient accumulation), in-DB update.
  MLLess         significance filtering; supervisor-coordinated sync.
  ScatterReduce  chunk ownership; 2 rounds of chunk exchange.
  AllReduce      master aggregates; everyone else pushes+polls.
  GPU baseline   stateful instances; S3 gradient exchange only.

Timing model per invocation:
  t = cold_start (amortized) + state_load + K·compute + sync_comm + update
where sync_comm = strategy bytes / channel bandwidth + ops · latency.

Costs follow ``repro.costmodel.pricing`` (Lambda GB-second; EC2 hourly).
The simulator is deliberately *analytic + compositional* — every number
in the paper's Table 2 decomposes into these terms, and
``benchmarks/table2_cost.py`` validates the decomposition against the
paper's reported values.

The per-round decomposition lives in :class:`RoundPlan` /
:func:`round_plan`, which the discrete-event engine
(``repro.serverless.runtime``) replays event by event: ``simulate_epoch``
is the engine's closed-form fault-free fast path, and faults, recovery,
and elasticity live in the engine on top of the same timing terms.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.costmodel import pricing


def _transfer(nbytes, bandwidth_Bps, latency_s, ops=1):
    """Channel transfer time.  Elementwise — every argument may be a
    Python scalar or a broadcastable numpy array, which is what lets the
    vectorized sweep (``repro.serverless.sweep``) evaluate whole grids
    through the *same* expressions the scalar path uses (exact
    agreement by construction)."""
    return nbytes / bandwidth_Bps + ops * latency_s


@dataclasses.dataclass(frozen=True)
class Channel:
    """External state channel (Redis on EC2 / S3)."""
    name: str = "redis"
    bandwidth_Bps: float = 1.25e9 / 8 * 10      # ~10 Gb EC2 NIC -> 1.25 GB/s
    latency_s: float = 0.002                    # per operation RTT

    def transfer(self, nbytes: float, ops: int = 1) -> float:
        return _transfer(nbytes, self.bandwidth_Bps, self.latency_s, ops)


S3 = Channel("s3", bandwidth_Bps=0.6e9, latency_s=0.030)
REDIS = Channel("redis")


@dataclasses.dataclass(frozen=True)
class ServerlessSetup:
    n_workers: int = 4
    batches_per_worker: int = 24
    ram_gb: float = 2.0
    cold_start_s: float = 2.5
    model_bytes: float = 17e6          # MobileNet fp32 ~17 MB
    minibatch_bytes: float = 512 * 32 * 32 * 3 * 4
    channel: Channel = REDIS


@dataclasses.dataclass
class StageBreakdown:
    fetch: float = 0.0
    compute: float = 0.0
    sync: float = 0.0
    update: float = 0.0

    @property
    def total(self) -> float:
        return self.fetch + self.compute + self.sync + self.update


@dataclasses.dataclass
class EpochReport:
    arch: str
    per_batch_s: float
    per_worker_s: float
    total_time_s: float
    stages: StageBreakdown
    comm_bytes_per_worker: float
    cost_per_worker: float
    total_cost: float
    ram_gb: float


def _grad_bytes(n_params: int, dtype_bytes: int = 4) -> float:
    return n_params * dtype_bytes


ARCHS = ("spirt", "mlless", "scatterreduce", "allreduce", "gpu")


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Per-sync-round stage durations for one architecture.

    A *round* is the unit between two cross-worker synchronization
    barriers: fetch (state load) -> compute ``batches_per_round``
    minibatches -> sync -> update.  The analytic :func:`simulate_epoch`
    sums these terms in closed form; the discrete-event engine
    (``repro.serverless.runtime``) replays them event by event, so the
    two agree exactly in the fault-free case by construction.
    """
    arch: str
    n_workers: int
    n_rounds: int
    batches_per_round: float      # per worker per round
    fetch_s: float                # state (re)load at the top of a round
    fetch_first_round_only: bool  # stateful archs load once (gpu)
    compute_s_per_batch: float
    sync_s: float                 # per-worker sync work per round
    update_s: float
    cold_start_s: float
    model_bytes: float
    ram_gb: float
    sync_bytes: float = 0.0       # exact per-worker wire bytes per round
    update_bytes: float = 0.0     # (sum of the transfer() nbytes terms)

    @property
    def total_batches(self) -> float:
        """Epoch work for ONE worker (the pool is W times this)."""
        return self.n_rounds * self.batches_per_round

    @property
    def comm_bytes_per_round(self) -> float:
        """Gradient-path wire bytes one worker moves per round."""
        return self.sync_bytes + self.update_bytes


def _round_terms(arch, *, n_params, n_workers, bandwidth_Bps, latency_s,
                 batches_per_worker, model_bytes, minibatch_bytes,
                 significant_fraction, accumulation):
    """Per-round stage arithmetic for one architecture.

    Elementwise: every numeric argument may be a scalar or a
    broadcastable numpy array.  This single implementation backs BOTH
    the scalar :func:`round_plan` and the vectorized analytic sweep
    (``repro.serverless.sweep``), so the two agree bit-for-bit.

    Alongside each stage *time* it returns the exact wire *bytes* the
    stage moves (the sum of the ``nbytes`` arguments fed to the channel)
    — per-op latencies contribute seconds but never bytes.
    """
    W = n_workers
    bw, lat = bandwidth_Bps, latency_s
    G = _grad_bytes(n_params)
    nb = batches_per_worker

    # every invocation reloads model + its minibatch (statelessness)
    per_invocation_load = _transfer(model_bytes + minibatch_bytes,
                                    bw, lat, ops=2)
    terms = dict(fetch_s=per_invocation_load, fetch_first_round_only=False)

    if arch == "spirt":
        # one long-lived invocation per epoch computes `accumulation`
        # minibatches; gradients averaged IN the local Redis (in-database
        # ops): per-minibatch store + one in-db average; a single
        # cross-worker sync per accumulation round.
        invocations = np.maximum(1, nb // accumulation)
        bpr = nb / invocations
        cross = (W - 1) * _transfer(G, bw, lat, ops=2) \
            + 2 * lat * W                       # sync queue polls
        return dict(n_rounds=invocations, batches_per_round=bpr,
                    sync_s=bpr * _transfer(G, bw, lat, ops=1) + cross,
                    update_s=_transfer(0, bw, lat, ops=1),  # in-db update
                    sync_bytes=bpr * G + (W - 1) * G,
                    update_bytes=0 * G, **terms)
    if arch == "mlless":
        # per-minibatch invocations; only significant updates pushed;
        # supervisor round-trip gates every sync step
        pushed = significant_fraction * G
        per_sync = (_transfer(pushed, bw, lat, ops=1)
                    + (W - 1) * _transfer(pushed, bw, lat, ops=1)
                    + 4 * lat                   # queue notify + supervisor
                    + 2 * lat * W)              # supervisor fan-out
        return dict(n_rounds=nb, batches_per_round=1.0,
                    sync_s=per_sync,
                    update_s=_transfer(G, bw, lat, ops=1),
                    sync_bytes=pushed + (W - 1) * pushed,
                    update_bytes=1.0 * G, **terms)
    if arch == "scatterreduce":
        # push W-1 chunks, fetch W-1 assigned chunks, push aggregate,
        # fetch W-1 aggregated chunks
        chunk = G / W
        per_sync = (_transfer((W - 1) * chunk, bw, lat, ops=W - 1) * 2
                    + _transfer(chunk, bw, lat, ops=1)
                    + _transfer((W - 1) * chunk, bw, lat, ops=W - 1))
        return dict(n_rounds=nb, batches_per_round=1.0,
                    sync_s=per_sync,
                    update_s=_transfer(G, bw, lat, ops=1),
                    sync_bytes=(W - 1) * chunk * 2 + chunk
                    + (W - 1) * chunk,
                    update_bytes=1.0 * G, **terms)
    if arch == "allreduce":
        # everyone pushes G; the designated master then pulls all W
        # gradients SERIALLY, aggregates and pushes the result; every
        # worker blocks on the master (the paper's §4.2 scalability
        # bottleneck), then fetches
        master_path = W * _transfer(G, bw, lat, ops=1) \
            + _transfer(G, bw, lat, ops=1)
        per_sync = (_transfer(G, bw, lat, ops=1) + master_path
                    + _transfer(G, bw, lat, ops=1))
        return dict(n_rounds=nb, batches_per_round=1.0,
                    sync_s=per_sync,
                    update_s=_transfer(G, bw, lat, ops=1),
                    sync_bytes=1.0 * G + (W * G + G) + G,
                    update_bytes=1.0 * G, **terms)
    if arch == "gpu":
        # stateful: load once; S3 gradient exchange per step
        per_sync = S3.transfer(G, ops=1) + (W - 1) * S3.transfer(G, ops=1)
        terms["fetch_first_round_only"] = True
        return dict(n_rounds=nb, batches_per_round=1.0,
                    sync_s=per_sync, update_s=0.0,
                    sync_bytes=1.0 * G + (W - 1) * G,
                    update_bytes=0 * G, **terms)
    raise ValueError(arch)


def round_plan(arch: str, *, n_params: int, compute_s_per_batch: float,
               setup: ServerlessSetup = ServerlessSetup(),
               significant_fraction: float = 0.3,
               accumulation: int = 24) -> RoundPlan:
    """Decompose an architecture's epoch into per-round stage times."""
    ch = setup.channel
    terms = _round_terms(arch, n_params=n_params,
                         n_workers=setup.n_workers,
                         bandwidth_Bps=ch.bandwidth_Bps,
                         latency_s=ch.latency_s,
                         batches_per_worker=setup.batches_per_worker,
                         model_bytes=setup.model_bytes,
                         minibatch_bytes=setup.minibatch_bytes,
                         significant_fraction=significant_fraction,
                         accumulation=accumulation)
    # float()/int() strip numpy scalar types (bit-exact) so the event
    # engine's hot loop runs on native floats
    return RoundPlan(arch=arch, n_workers=setup.n_workers,
                     cold_start_s=setup.cold_start_s,
                     compute_s_per_batch=compute_s_per_batch,
                     model_bytes=setup.model_bytes, ram_gb=setup.ram_gb,
                     n_rounds=int(terms["n_rounds"]),
                     batches_per_round=float(terms["batches_per_round"]),
                     fetch_s=float(terms["fetch_s"]),
                     fetch_first_round_only=terms["fetch_first_round_only"],
                     sync_s=float(terms["sync_s"]),
                     update_s=float(terms["update_s"]),
                     sync_bytes=float(terms["sync_bytes"]),
                     update_bytes=float(terms["update_bytes"]))


def _epoch_terms(*, n_rounds, batches_per_round, fetch_s,
                 fetch_first_round_only, sync_s, update_s, sync_bytes,
                 update_bytes, compute_s_per_batch, cold_start_s,
                 batches_per_worker):
    """Epoch-level sums over the round terms.  Elementwise (scalars or
    arrays), shared by :func:`simulate_epoch` and the vectorized sweep
    so the closed forms agree bit-for-bit."""
    fetch = fetch_s * (1 if fetch_first_round_only else n_rounds)
    compute = (n_rounds * batches_per_round) * compute_s_per_batch
    sync = n_rounds * sync_s
    update = n_rounds * update_s
    # same association order as StageBreakdown.total
    per_worker = (fetch + compute + sync + update) + cold_start_s
    return dict(fetch=fetch, compute=compute, sync=sync, update=update,
                per_worker=per_worker,
                per_batch=per_worker / batches_per_worker,
                # exact wire bytes: latency ops contribute seconds, not
                # phantom bytes (ISSUE 2 satellite fix)
                comm_bytes=n_rounds * (sync_bytes + update_bytes))


def _epoch_cost(arch, per_worker_s, ram_gb, n_workers):
    """(cost_per_worker, total_cost); elementwise in the numeric args."""
    if arch == "gpu":
        cost_worker = pricing.gpu_cost(per_worker_s)
    else:
        cost_worker = pricing.lambda_cost(per_worker_s, ram_gb)
    return cost_worker, cost_worker * n_workers


def simulate_epoch(arch: str, *, n_params: int,
                   compute_s_per_batch: float,
                   setup: ServerlessSetup = ServerlessSetup(),
                   significant_fraction: float = 0.3,
                   accumulation: int = 24) -> EpochReport:
    """Simulate one training epoch under the given architecture.

    Closed-form fast path of the event engine: sums the
    :class:`RoundPlan` stage terms, assuming homogeneous fault-free
    workers (every barrier is free).  ``runtime.run_event_epoch``
    replays the identical plan event by event and reduces to these
    numbers when no faults are injected.
    """
    plan = round_plan(arch, n_params=n_params,
                      compute_s_per_batch=compute_s_per_batch, setup=setup,
                      significant_fraction=significant_fraction,
                      accumulation=accumulation)
    ep = _epoch_terms(n_rounds=plan.n_rounds,
                      batches_per_round=plan.batches_per_round,
                      fetch_s=plan.fetch_s,
                      fetch_first_round_only=plan.fetch_first_round_only,
                      sync_s=plan.sync_s, update_s=plan.update_s,
                      sync_bytes=plan.sync_bytes,
                      update_bytes=plan.update_bytes,
                      compute_s_per_batch=compute_s_per_batch,
                      cold_start_s=setup.cold_start_s,
                      batches_per_worker=setup.batches_per_worker)
    stages = StageBreakdown(fetch=ep["fetch"], compute=ep["compute"],
                            sync=ep["sync"], update=ep["update"])
    cost_worker, total_cost = _epoch_cost(arch, ep["per_worker"],
                                          setup.ram_gb, setup.n_workers)
    return EpochReport(arch=arch, per_batch_s=ep["per_batch"],
                       per_worker_s=ep["per_worker"],
                       total_time_s=ep["per_worker"],  # workers in parallel
                       stages=stages,
                       comm_bytes_per_worker=ep["comm_bytes"],
                       cost_per_worker=cost_worker,
                       total_cost=total_cost, ram_gb=setup.ram_gb)


# ---------------------------------------------------------------------------
# Paper-reported measurements (Table 2) — used to VALIDATE the cost
# arithmetic and as calibration anchors for the simulator.
# ---------------------------------------------------------------------------
PAPER_TABLE2 = {
    # arch: (per_batch_s, ram_mb, cost_per_worker, total_cost)
    "mobilenet": {
        "spirt": (15.44, 2685, 0.0165, 0.0660),
        "scatterreduce": (14.343, 2048, 0.0106, 0.0422),
        "allreduce": (14.382, 2048, 0.0107, 0.0427),
        "mlless": (69.425, 3024, 0.0839, 0.3356),
        "gpu": (92.00 / 24, None, 0.01344, 0.0538),
    },
    "resnet18": {
        "spirt": (28.55, 3200, 0.0365, 0.1460),
        "scatterreduce": (27.17, 2880, 0.0312, 0.1249),
        "allreduce": (26.79, 2986, 0.0332, 0.1328),
        "mlless": (78.39, 3630, 0.1137, 0.4548),
        "gpu": (139.00 / 24, None, 0.0203, 0.0812),
    },
}


def paper_compute_anchor(arch: str, model: str = "mobilenet") -> float:
    """Compute share of the paper's measured per-batch time: the
    non-compute stages account for ~15% of a serverless batch (~10% for
    the GPU baseline), so simulators anchored on Table 2 feed this as
    ``compute_s_per_batch``.  Shared by ``benchmarks/fault_tolerance``,
    ``benchmarks/pareto_sweep`` and the examples — one calibration,
    one place."""
    return PAPER_TABLE2[model][arch][0] * (0.9 if arch == "gpu" else 0.85)


def paper_cost_check(model: str, arch: str) -> Dict[str, float]:
    """Recompute the paper's Table 2 cost from its reported time+RAM."""
    per_batch, ram_mb, cost_w, total = PAPER_TABLE2[model][arch]
    if arch == "gpu":
        t = per_batch * 24
        ours = pricing.gpu_cost(t)
        return {"paper_cost_per_worker": cost_w, "our_cost": ours,
                "paper_total": total, "our_total": ours * 4}
    per_fn = pricing.lambda_cost(per_batch, ram_mb / 1024.0)
    ours_worker = per_fn * 24
    return {"paper_cost_per_worker": cost_w, "our_cost": ours_worker,
            "paper_total": total, "our_total": ours_worker * 4}
