"""Serverless training-architecture simulator.

Models the paper's execution semantics (§2, Table 1): stateless Lambda
workers that must (re)load model+data every invocation, communicate
gradients through external channels (Redis / S3), and synchronize via
queues — per architecture:

  SPIRT          P2P; per-worker in-DB gradient averaging (24 minibatches
                 per invocation via gradient accumulation), in-DB update.
  MLLess         significance filtering; supervisor-coordinated sync.
  ScatterReduce  chunk ownership; 2 rounds of chunk exchange.
  AllReduce      master aggregates; everyone else pushes+polls.
  GPU baseline   stateful instances; S3 gradient exchange only.

Timing model per invocation:
  t = cold_start (amortized) + state_load + K·compute + sync_comm + update
where sync_comm = strategy bytes / channel bandwidth + ops · latency.

Costs follow ``repro.costmodel.pricing`` (Lambda GB-second; EC2 hourly).
The simulator is deliberately *analytic + compositional* — every number
in the paper's Table 2 decomposes into these terms, and
``benchmarks/table2_cost.py`` validates the decomposition against the
paper's reported values.

The per-round decomposition lives in :class:`RoundPlan` /
:func:`round_plan`, which the discrete-event engine
(``repro.serverless.runtime``) replays event by event: ``simulate_epoch``
is the engine's closed-form fault-free fast path, and faults, recovery,
and elasticity live in the engine on top of the same timing terms.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.costmodel import pricing


@dataclasses.dataclass(frozen=True)
class Channel:
    """External state channel (Redis on EC2 / S3)."""
    name: str = "redis"
    bandwidth_Bps: float = 1.25e9 / 8 * 10      # ~10 Gb EC2 NIC -> 1.25 GB/s
    latency_s: float = 0.002                    # per operation RTT

    def transfer(self, nbytes: float, ops: int = 1) -> float:
        return nbytes / self.bandwidth_Bps + ops * self.latency_s


S3 = Channel("s3", bandwidth_Bps=0.6e9, latency_s=0.030)
REDIS = Channel("redis")


@dataclasses.dataclass(frozen=True)
class ServerlessSetup:
    n_workers: int = 4
    batches_per_worker: int = 24
    ram_gb: float = 2.0
    cold_start_s: float = 2.5
    model_bytes: float = 17e6          # MobileNet fp32 ~17 MB
    minibatch_bytes: float = 512 * 32 * 32 * 3 * 4
    channel: Channel = REDIS


@dataclasses.dataclass
class StageBreakdown:
    fetch: float = 0.0
    compute: float = 0.0
    sync: float = 0.0
    update: float = 0.0

    @property
    def total(self) -> float:
        return self.fetch + self.compute + self.sync + self.update


@dataclasses.dataclass
class EpochReport:
    arch: str
    per_batch_s: float
    per_worker_s: float
    total_time_s: float
    stages: StageBreakdown
    comm_bytes_per_worker: float
    cost_per_worker: float
    total_cost: float
    ram_gb: float


def _grad_bytes(n_params: int, dtype_bytes: int = 4) -> float:
    return n_params * dtype_bytes


ARCHS = ("spirt", "mlless", "scatterreduce", "allreduce", "gpu")


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Per-sync-round stage durations for one architecture.

    A *round* is the unit between two cross-worker synchronization
    barriers: fetch (state load) -> compute ``batches_per_round``
    minibatches -> sync -> update.  The analytic :func:`simulate_epoch`
    sums these terms in closed form; the discrete-event engine
    (``repro.serverless.runtime``) replays them event by event, so the
    two agree exactly in the fault-free case by construction.
    """
    arch: str
    n_workers: int
    n_rounds: int
    batches_per_round: float      # per worker per round
    fetch_s: float                # state (re)load at the top of a round
    fetch_first_round_only: bool  # stateful archs load once (gpu)
    compute_s_per_batch: float
    sync_s: float                 # per-worker sync work per round
    update_s: float
    cold_start_s: float
    model_bytes: float
    ram_gb: float

    @property
    def total_batches(self) -> float:
        """Epoch work for ONE worker (the pool is W times this)."""
        return self.n_rounds * self.batches_per_round


def round_plan(arch: str, *, n_params: int, compute_s_per_batch: float,
               setup: ServerlessSetup = ServerlessSetup(),
               significant_fraction: float = 0.3,
               accumulation: int = 24) -> RoundPlan:
    """Decompose an architecture's epoch into per-round stage times."""
    W = setup.n_workers
    ch = setup.channel
    G = _grad_bytes(n_params)
    nb = setup.batches_per_worker

    # every invocation reloads model + its minibatch (statelessness)
    per_invocation_load = ch.transfer(setup.model_bytes
                                      + setup.minibatch_bytes, ops=2)
    kw = dict(arch=arch, n_workers=W, cold_start_s=setup.cold_start_s,
              compute_s_per_batch=compute_s_per_batch,
              model_bytes=setup.model_bytes, ram_gb=setup.ram_gb,
              fetch_s=per_invocation_load, fetch_first_round_only=False)

    if arch == "spirt":
        # one long-lived invocation per epoch computes `accumulation`
        # minibatches; gradients averaged IN the local Redis (in-database
        # ops): per-minibatch store + one in-db average; a single
        # cross-worker sync per accumulation round.
        invocations = max(1, nb // accumulation)
        bpr = nb / invocations
        cross = (W - 1) * ch.transfer(G, ops=2) \
            + 2 * ch.latency_s * W              # sync queue polls
        return RoundPlan(n_rounds=invocations, batches_per_round=bpr,
                         sync_s=bpr * ch.transfer(G, ops=1) + cross,
                         update_s=ch.transfer(0, ops=1),  # in-db update
                         **kw)
    if arch == "mlless":
        # per-minibatch invocations; only significant updates pushed;
        # supervisor round-trip gates every sync step
        pushed = significant_fraction * G
        per_sync = (ch.transfer(pushed, ops=1)
                    + (W - 1) * ch.transfer(pushed, ops=1)
                    + 4 * ch.latency_s          # queue notify + supervisor
                    + 2 * ch.latency_s * W)     # supervisor fan-out
        return RoundPlan(n_rounds=nb, batches_per_round=1.0,
                         sync_s=per_sync,
                         update_s=ch.transfer(G, ops=1), **kw)
    if arch == "scatterreduce":
        # push W-1 chunks, fetch W-1 assigned chunks, push aggregate,
        # fetch W-1 aggregated chunks
        chunk = G / W
        per_sync = (ch.transfer((W - 1) * chunk, ops=W - 1) * 2
                    + ch.transfer(chunk, ops=1)
                    + ch.transfer((W - 1) * chunk, ops=W - 1))
        return RoundPlan(n_rounds=nb, batches_per_round=1.0,
                         sync_s=per_sync,
                         update_s=ch.transfer(G, ops=1), **kw)
    if arch == "allreduce":
        # everyone pushes G; the designated master then pulls all W
        # gradients SERIALLY, aggregates and pushes the result; every
        # worker blocks on the master (the paper's §4.2 scalability
        # bottleneck), then fetches
        master_path = W * ch.transfer(G, ops=1) + ch.transfer(G, ops=1)
        per_sync = (ch.transfer(G, ops=1) + master_path
                    + ch.transfer(G, ops=1))
        return RoundPlan(n_rounds=nb, batches_per_round=1.0,
                         sync_s=per_sync,
                         update_s=ch.transfer(G, ops=1), **kw)
    if arch == "gpu":
        # stateful: load once; S3 gradient exchange per step
        per_sync = S3.transfer(G, ops=1) + (W - 1) * S3.transfer(G, ops=1)
        kw["fetch_first_round_only"] = True
        return RoundPlan(n_rounds=nb, batches_per_round=1.0,
                         sync_s=per_sync, update_s=0.0, **kw)
    raise ValueError(arch)


def simulate_epoch(arch: str, *, n_params: int,
                   compute_s_per_batch: float,
                   setup: ServerlessSetup = ServerlessSetup(),
                   significant_fraction: float = 0.3,
                   accumulation: int = 24) -> EpochReport:
    """Simulate one training epoch under the given architecture.

    Closed-form fast path of the event engine: sums the
    :class:`RoundPlan` stage terms, assuming homogeneous fault-free
    workers (every barrier is free).  ``runtime.run_event_epoch``
    replays the identical plan event by event and reduces to these
    numbers when no faults are injected.
    """
    plan = round_plan(arch, n_params=n_params,
                      compute_s_per_batch=compute_s_per_batch, setup=setup,
                      significant_fraction=significant_fraction,
                      accumulation=accumulation)
    W = setup.n_workers
    ch = setup.channel
    nb = setup.batches_per_worker
    stages = StageBreakdown()
    stages.fetch = plan.fetch_s * (1 if plan.fetch_first_round_only
                                   else plan.n_rounds)
    stages.compute = plan.total_batches * compute_s_per_batch
    stages.sync = plan.n_rounds * plan.sync_s
    stages.update = plan.n_rounds * plan.update_s

    per_worker = stages.total + setup.cold_start_s
    per_batch = per_worker / nb
    comm = stages.sync * ch.bandwidth_Bps  # approx bytes equivalent
    if arch == "gpu":
        cost_worker = pricing.gpu_cost(per_worker)
        total_cost = cost_worker * W
    else:
        cost_worker = pricing.lambda_cost(per_worker, setup.ram_gb)
        total_cost = cost_worker * W
    return EpochReport(arch=arch, per_batch_s=per_batch,
                       per_worker_s=per_worker,
                       total_time_s=per_worker,   # workers run in parallel
                       stages=stages,
                       comm_bytes_per_worker=comm,
                       cost_per_worker=cost_worker,
                       total_cost=total_cost, ram_gb=setup.ram_gb)


# ---------------------------------------------------------------------------
# Paper-reported measurements (Table 2) — used to VALIDATE the cost
# arithmetic and as calibration anchors for the simulator.
# ---------------------------------------------------------------------------
PAPER_TABLE2 = {
    # arch: (per_batch_s, ram_mb, cost_per_worker, total_cost)
    "mobilenet": {
        "spirt": (15.44, 2685, 0.0165, 0.0660),
        "scatterreduce": (14.343, 2048, 0.0106, 0.0422),
        "allreduce": (14.382, 2048, 0.0107, 0.0427),
        "mlless": (69.425, 3024, 0.0839, 0.3356),
        "gpu": (92.00 / 24, None, 0.01344, 0.0538),
    },
    "resnet18": {
        "spirt": (28.55, 3200, 0.0365, 0.1460),
        "scatterreduce": (27.17, 2880, 0.0312, 0.1249),
        "allreduce": (26.79, 2986, 0.0332, 0.1328),
        "mlless": (78.39, 3630, 0.1137, 0.4548),
        "gpu": (139.00 / 24, None, 0.0203, 0.0812),
    },
}


def paper_cost_check(model: str, arch: str) -> Dict[str, float]:
    """Recompute the paper's Table 2 cost from its reported time+RAM."""
    per_batch, ram_mb, cost_w, total = PAPER_TABLE2[model][arch]
    if arch == "gpu":
        t = per_batch * 24
        ours = pricing.gpu_cost(t)
        return {"paper_cost_per_worker": cost_w, "our_cost": ours,
                "paper_total": total, "our_total": ours * 4}
    per_fn = pricing.lambda_cost(per_batch, ram_mb / 1024.0)
    ours_worker = per_fn * 24
    return {"paper_cost_per_worker": cost_w, "our_cost": ours_worker,
            "paper_total": total, "our_total": ours_worker * 4}
