"""Serverless training-architecture simulator.

Models the paper's execution semantics (§2, Table 1): stateless Lambda
workers that must (re)load model+data every invocation, communicate
gradients through external channels (Redis / S3), and synchronize via
queues — per architecture:

  SPIRT          P2P; per-worker in-DB gradient averaging (24 minibatches
                 per invocation via gradient accumulation), in-DB update.
  MLLess         significance filtering; supervisor-coordinated sync.
  ScatterReduce  chunk ownership; 2 rounds of chunk exchange.
  AllReduce      master aggregates; everyone else pushes+polls.
  GPU baseline   stateful instances; S3 gradient exchange only.

Timing model per invocation:
  t = cold_start (amortized) + state_load + K·compute + sync_comm + update
where sync_comm = strategy bytes / channel bandwidth + ops · latency.

Costs follow ``repro.costmodel.pricing`` (Lambda GB-second; EC2 hourly).
The simulator is deliberately *analytic + compositional* — every number
in the paper's Table 2 decomposes into these terms, and
``benchmarks/table2_cost.py`` validates the decomposition against the
paper's reported values.

The per-round decomposition lives in :class:`RoundPlan` /
:func:`round_plan`, which the discrete-event engine
(``repro.serverless.runtime``) replays event by event: ``simulate_epoch``
is the engine's closed-form fault-free fast path, and faults, recovery,
and elasticity live in the engine on top of the same timing terms.

Architecture semantics live in the pluggable registry
(``repro.serverless.archs``): each :class:`~repro.serverless.archs.
ArchSpec` carries its per-round term function, billing, channel policy
and recovery default, and :data:`ARCHS` (the paper's five) is derived
from it.  Registering a new spec is all it takes for an architecture to
flow through this module, the sweeps and the event engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.costmodel import pricing
from repro.serverless.archs import (  # noqa: F401  (re-exported API)
    REDIS, S3, Channel, _grad_bytes, _transfer, arch_epoch_cost,
    arch_round_terms, get_arch, list_archs, paper_archs,
)


@dataclasses.dataclass(frozen=True)
class ServerlessSetup:
    n_workers: int = 4
    batches_per_worker: int = 24
    ram_gb: float = 2.0
    cold_start_s: float = 2.5
    model_bytes: float = 17e6          # MobileNet fp32 ~17 MB
    minibatch_bytes: float = 512 * 32 * 32 * 3 * 4
    channel: Channel = REDIS

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got "
                             f"{self.n_workers}")
        if self.batches_per_worker < 1:
            raise ValueError(f"batches_per_worker must be >= 1, got "
                             f"{self.batches_per_worker}")
        if not self.ram_gb > 0:
            raise ValueError(f"ram_gb must be > 0, got {self.ram_gb}")
        if self.cold_start_s < 0:
            raise ValueError(f"cold_start_s must be >= 0, got "
                             f"{self.cold_start_s}")
        if self.model_bytes < 0 or self.minibatch_bytes < 0:
            raise ValueError("model_bytes / minibatch_bytes must be "
                             ">= 0")


@dataclasses.dataclass
class StageBreakdown:
    fetch: float = 0.0
    compute: float = 0.0
    sync: float = 0.0
    update: float = 0.0

    @property
    def total(self) -> float:
        return self.fetch + self.compute + self.sync + self.update


@dataclasses.dataclass
class EpochReport:
    arch: str
    per_batch_s: float
    per_worker_s: float
    total_time_s: float
    stages: StageBreakdown
    comm_bytes_per_worker: float
    cost_per_worker: float
    total_cost: float
    ram_gb: float


# the paper's comparison set, derived from the registry (beyond-paper
# registrations show up in list_archs(), not here)
ARCHS = paper_archs()


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Per-sync-round stage durations for one architecture.

    A *round* is the unit between two cross-worker synchronization
    barriers: fetch (state load) -> compute ``batches_per_round``
    minibatches -> sync -> update.  The analytic :func:`simulate_epoch`
    sums these terms in closed form; the discrete-event engine
    (``repro.serverless.runtime``) replays them event by event, so the
    two agree exactly in the fault-free case by construction.
    """
    arch: str
    n_workers: int
    n_rounds: int
    batches_per_round: float      # per worker per round
    fetch_s: float                # state (re)load at the top of a round
    fetch_first_round_only: bool  # stateful archs load once (gpu)
    compute_s_per_batch: float
    sync_s: float                 # per-worker sync work per round
    update_s: float
    cold_start_s: float
    model_bytes: float
    ram_gb: float
    sync_bytes: float = 0.0       # exact per-worker wire bytes per round
    update_bytes: float = 0.0     # (sum of the transfer() nbytes terms)
    barrier: bool = True          # False: workers commit syncs without
                                  # waiting for peers (async archs)

    @property
    def total_batches(self) -> float:
        """Epoch work for ONE worker (the pool is W times this)."""
        return self.n_rounds * self.batches_per_round

    @property
    def comm_bytes_per_round(self) -> float:
        """Gradient-path wire bytes one worker moves per round."""
        return self.sync_bytes + self.update_bytes


# the registry's dispatcher IS the implementation; this alias keeps the
# historical name the sweeps and tests import
_round_terms = arch_round_terms


def round_plan(arch: str, *, n_params: int, compute_s_per_batch: float,
               setup: ServerlessSetup = ServerlessSetup(),
               significant_fraction: float = 0.3,
               accumulation: int = 24) -> RoundPlan:
    """Decompose an architecture's epoch into per-round stage times."""
    ch = setup.channel
    terms = _round_terms(arch, n_params=n_params,
                         n_workers=setup.n_workers,
                         bandwidth_Bps=ch.bandwidth_Bps,
                         latency_s=ch.latency_s,
                         batches_per_worker=setup.batches_per_worker,
                         model_bytes=setup.model_bytes,
                         minibatch_bytes=setup.minibatch_bytes,
                         significant_fraction=significant_fraction,
                         accumulation=accumulation)
    # float()/int() strip numpy scalar types (bit-exact) so the event
    # engine's hot loop runs on native floats
    return RoundPlan(arch=arch, n_workers=setup.n_workers,
                     cold_start_s=setup.cold_start_s,
                     compute_s_per_batch=compute_s_per_batch,
                     model_bytes=setup.model_bytes, ram_gb=setup.ram_gb,
                     n_rounds=int(terms["n_rounds"]),
                     batches_per_round=float(terms["batches_per_round"]),
                     fetch_s=float(terms["fetch_s"]),
                     fetch_first_round_only=terms["fetch_first_round_only"],
                     sync_s=float(terms["sync_s"]),
                     update_s=float(terms["update_s"]),
                     sync_bytes=float(terms["sync_bytes"]),
                     update_bytes=float(terms["update_bytes"]),
                     barrier=bool(terms.get("barrier", True)))


def _epoch_terms(*, n_rounds, batches_per_round, fetch_s,
                 fetch_first_round_only, sync_s, update_s, sync_bytes,
                 update_bytes, compute_s_per_batch, cold_start_s,
                 batches_per_worker):
    """Epoch-level sums over the round terms.  Elementwise (scalars or
    arrays), shared by :func:`simulate_epoch` and the vectorized sweep
    so the closed forms agree bit-for-bit."""
    fetch = fetch_s * (1 if fetch_first_round_only else n_rounds)
    compute = (n_rounds * batches_per_round) * compute_s_per_batch
    sync = n_rounds * sync_s
    update = n_rounds * update_s
    # same association order as StageBreakdown.total
    per_worker = (fetch + compute + sync + update) + cold_start_s
    return dict(fetch=fetch, compute=compute, sync=sync, update=update,
                per_worker=per_worker,
                per_batch=per_worker / batches_per_worker,
                # exact wire bytes: latency ops contribute seconds, not
                # phantom bytes (ISSUE 2 satellite fix)
                comm_bytes=n_rounds * (sync_bytes + update_bytes))


# billing dispatch now lives on the ArchSpec (Lambda GB-seconds vs
# instance-hours); alias kept for the sweeps and tests
_epoch_cost = arch_epoch_cost


def simulate_epoch(arch: str, *, n_params: int,
                   compute_s_per_batch: float,
                   setup: ServerlessSetup = ServerlessSetup(),
                   significant_fraction: float = 0.3,
                   accumulation: int = 24) -> EpochReport:
    """Simulate one training epoch under the given architecture.

    Closed-form fast path of the event engine: sums the
    :class:`RoundPlan` stage terms, assuming homogeneous fault-free
    workers (every barrier is free).  ``runtime.run_event_epoch``
    replays the identical plan event by event and reduces to these
    numbers when no faults are injected.
    """
    plan = round_plan(arch, n_params=n_params,
                      compute_s_per_batch=compute_s_per_batch, setup=setup,
                      significant_fraction=significant_fraction,
                      accumulation=accumulation)
    ep = _epoch_terms(n_rounds=plan.n_rounds,
                      batches_per_round=plan.batches_per_round,
                      fetch_s=plan.fetch_s,
                      fetch_first_round_only=plan.fetch_first_round_only,
                      sync_s=plan.sync_s, update_s=plan.update_s,
                      sync_bytes=plan.sync_bytes,
                      update_bytes=plan.update_bytes,
                      compute_s_per_batch=compute_s_per_batch,
                      cold_start_s=setup.cold_start_s,
                      batches_per_worker=setup.batches_per_worker)
    stages = StageBreakdown(fetch=ep["fetch"], compute=ep["compute"],
                            sync=ep["sync"], update=ep["update"])
    cost_worker, total_cost = _epoch_cost(arch, ep["per_worker"],
                                          setup.ram_gb, setup.n_workers)
    return EpochReport(arch=arch, per_batch_s=ep["per_batch"],
                       per_worker_s=ep["per_worker"],
                       total_time_s=ep["per_worker"],  # workers in parallel
                       stages=stages,
                       comm_bytes_per_worker=ep["comm_bytes"],
                       cost_per_worker=cost_worker,
                       total_cost=total_cost, ram_gb=setup.ram_gb)


# ---------------------------------------------------------------------------
# Paper-reported measurements (Table 2) — used to VALIDATE the cost
# arithmetic and as calibration anchors for the simulator.
# ---------------------------------------------------------------------------
PAPER_TABLE2 = {
    # arch: (per_batch_s, ram_mb, cost_per_worker, total_cost)
    "mobilenet": {
        "spirt": (15.44, 2685, 0.0165, 0.0660),
        "scatterreduce": (14.343, 2048, 0.0106, 0.0422),
        "allreduce": (14.382, 2048, 0.0107, 0.0427),
        "mlless": (69.425, 3024, 0.0839, 0.3356),
        "gpu": (92.00 / 24, None, 0.01344, 0.0538),
    },
    "resnet18": {
        "spirt": (28.55, 3200, 0.0365, 0.1460),
        "scatterreduce": (27.17, 2880, 0.0312, 0.1249),
        "allreduce": (26.79, 2986, 0.0332, 0.1328),
        "mlless": (78.39, 3630, 0.1137, 0.4548),
        "gpu": (139.00 / 24, None, 0.0203, 0.0812),
    },
}


def paper_compute_anchor(arch: str, model: str = "mobilenet") -> float:
    """Compute share of the paper's measured per-batch time: the
    non-compute stages account for ~15% of a serverless batch (~10% for
    the GPU baseline), so simulators anchored on Table 2 feed this as
    ``compute_s_per_batch``.  Shared by ``benchmarks/fault_tolerance``,
    ``benchmarks/pareto_sweep`` and the examples — one calibration,
    one place.  Beyond-paper architectures calibrate through their
    spec's ``anchor`` row (e.g. the SPIRT hybrids anchor on spirt) and
    ``compute_share``."""
    spec = get_arch(arch)
    row = PAPER_TABLE2[model].get(spec.anchor or spec.name)
    if row is None:
        raise ValueError(
            f"arch {arch!r} has no paper Table 2 calibration row; set "
            f"ArchSpec.anchor to one of {tuple(PAPER_TABLE2[model])} to "
            "use the anchored benchmarks")
    return row[0] * spec.compute_share


def paper_cost_check(model: str, arch: str) -> Dict[str, float]:
    """Recompute the paper's Table 2 cost from its reported time+RAM."""
    per_batch, ram_mb, cost_w, total = PAPER_TABLE2[model][arch]
    if arch == "gpu":
        t = per_batch * 24
        ours = pricing.gpu_cost(t)
        return {"paper_cost_per_worker": cost_w, "our_cost": ours,
                "paper_total": total, "our_total": ours * 4}
    per_fn = pricing.lambda_cost(per_batch, ram_mb / 1024.0)
    ours_worker = per_fn * 24
    return {"paper_cost_per_worker": cost_w, "our_cost": ours_worker,
            "paper_total": total, "our_total": ours_worker * 4}
