"""Reference discrete-event serverless training runtime (PR 1, frozen).

This is the original closure-per-event engine, kept verbatim as the
semantic reference for the optimized ``repro.serverless.runtime``: the
regression suite (``tests/test_event_runtime_opt.py``) asserts the
optimized engine reproduces this engine's ``RuntimeReport`` numbers
*exactly* on seeded fault scenarios, and ``benchmarks/pareto_sweep.py``
measures the optimized engine's speedup against it.  Do not optimize
this file — its slowness is the baseline being measured.

Event model
-----------
A single priority queue of ``(time, seq, callback)`` events drives the
whole fleet.  Each worker is a lifecycle state machine

    COLD_START -> STATE_LOAD -> COMPUTE -> SYNC -> (barrier) -> UPDATE
         ^                                                        |
         |                 next round / re-invocation             |
         +--------------------------------------------------------+

whose stage *durations* come from :func:`repro.serverless.simulator.
round_plan` — the identical closed-form terms the analytic
``simulate_epoch`` sums.  With homogeneous fault-free workers every
barrier is free, so the event makespan reproduces the analytic
per-worker time exactly; ``simulate_epoch`` is therefore the engine's
validated fast path, and everything the analytic model *cannot*
express — crashes, stragglers, cold-start storms, byzantine gradients,
elastic fleets — is layered on top as events.

Synchronous-training semantics: a round's barrier releases when every
*expected* worker has finished its sync stage (and any recovery holds
have cleared); all workers then apply the update and enter the next
round.  The epoch's work is a shared pool of ``W0 x total_batches``
minibatches, so an autoscaler that grows the fleet genuinely shortens
the epoch (fewer rounds), and peer takeover after a crash genuinely
lengthens per-worker rounds (survivors absorb the partition).

Fault taxonomy lives in ``faults.py``; recovery semantics (checkpoint
replay vs SPIRT in-database peer takeover) in ``recovery.py``; scaling
policies in ``autoscale.py``.  Billing follows
``repro.costmodel.pricing``: Lambda workers bill GB-seconds for their
entire invocation wall-clock (barrier waits included — stalls are not
free, which is exactly why stragglers show up in the cost column), the
GPU baseline bills instance-hours for the makespan.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.costmodel import pricing
from repro.serverless.faults import FaultPlan
from repro.serverless.recovery import (CheckpointRestore, PeerTakeover,
                                       RecoveryEvent, RecoveryPolicy)
from repro.serverless.simulator import (RoundPlan, ServerlessSetup,
                                        round_plan)

# worker lifecycle states
COLD_START, STATE_LOAD, COMPUTE, SYNC, WAIT_BARRIER, UPDATE, DONE, DEAD = (
    "cold_start", "state_load", "compute", "sync", "wait_barrier",
    "update", "done", "dead")


@dataclasses.dataclass
class _Worker:
    id: int
    state: str = COLD_START
    gen: int = 0                 # bumped on crash; stale events ignored
    alive: bool = True
    spawn_time: float = 0.0
    done_time: Optional[float] = None
    joined: bool = False         # finished cold start + first load
    work_mult: float = 1.0       # >1 after absorbing a peer's partition
    replay_rounds: int = 0       # pending checkpoint replay after restore
    byzantine: bool = False
    restoring: bool = False      # crashed, checkpoint-restore in flight
    initial: bool = False        # part of the epoch-start fleet
    pending_recovery: Optional[RecoveryEvent] = None
    # per-stage busy-time accounting (excludes barrier waits)
    stage_s: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {"cold_start": 0.0, "fetch": 0.0,
                                 "compute": 0.0, "sync": 0.0,
                                 "update": 0.0, "wait": 0.0, "replay": 0.0})
    _stage_started: float = 0.0


@dataclasses.dataclass
class RuntimeReport:
    """What one event-driven epoch produced."""
    arch: str
    makespan_s: float
    analytic_s: float                  # simulate_epoch's fault-free time
    rounds: int
    work_done_batches: float
    n_workers_start: int
    n_workers_peak: int
    n_workers_end: int
    total_cost: float
    stage_totals: Dict[str, float]     # summed across workers
    recoveries: List[RecoveryEvent]
    poisoned_updates: int              # byzantine contributions applied
    masked_updates: int                # byzantine contributions masked
    scale_events: List[Tuple[float, int]]   # (time, delta)
    timeline: List[Tuple[float, int, str]]  # (time, worker, event)

    @property
    def time_to_recover_s(self) -> float:
        return max((r.time_to_recover_s for r in self.recoveries),
                   default=0.0)

    @property
    def overhead_vs_analytic(self) -> float:
        return self.makespan_s / self.analytic_s - 1.0


class EventRuntime:
    """Heap-scheduled execution of one epoch of a :class:`RoundPlan`."""

    def __init__(self, plan: RoundPlan, setup: ServerlessSetup, *,
                 faults: Optional[FaultPlan] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 autoscaler=None, robust_trim: int = 0,
                 max_timeline: int = 4096):
        self.plan = plan
        self.setup = setup
        self.faults = faults or FaultPlan()
        self.recovery = recovery or CheckpointRestore()
        self.autoscaler = autoscaler
        self.robust_trim = robust_trim
        self.max_timeline = max_timeline

        self.t = 0.0
        self._heap: List[Tuple[float, int, int, int, Callable]] = []
        self._seq = itertools.count()
        self.workers: List[_Worker] = []
        self.round_idx = 0
        # shared epoch work pool: W0 workers x per-worker batches
        self.pool = plan.n_workers * plan.total_batches
        self.arrived: set = set()
        self.barrier_not_before = 0.0
        self.recoveries: List[RecoveryEvent] = []
        self.scale_events: List[Tuple[float, int]] = []
        self.timeline: List[Tuple[float, int, str]] = []
        self.poisoned = 0
        self.masked = 0
        self._pending_scale_in = 0

    # ------------------------------------------------------------ events
    def _schedule(self, t: float, w: Optional[_Worker], fn: Callable):
        gen = w.gen if w is not None else -1
        wid = w.id if w is not None else -1
        heapq.heappush(self._heap, (t, next(self._seq), wid, gen, fn))

    def _log(self, w: int, event: str):
        if len(self.timeline) < self.max_timeline:
            self.timeline.append((self.t, w, event))

    # ------------------------------------------------------------ stages
    def _begin_stage(self, w: _Worker, state: str):
        w.state = state
        w._stage_started = self.t

    def _end_stage(self, w: _Worker, key: str):
        w.stage_s[key] += self.t - w._stage_started

    def _spawn_worker(self, t: float, *, byzantine: bool = False,
                      replay_rounds: int = 0,
                      existing: Optional[_Worker] = None) -> _Worker:
        """(Re-)invoke a worker: cold start, then first state load."""
        if existing is None:
            w = _Worker(id=len(self.workers), byzantine=byzantine)
            self.workers.append(w)
        else:
            w = existing
            w.alive, w.state = True, COLD_START
        w.spawn_time = t if existing is None else w.spawn_time
        w.replay_rounds = replay_rounds
        cold = self.plan.cold_start_s
        if w.id in self._storm_victims:
            cold += self.faults.storm.extra_s
        self._log(w.id, f"invoke(cold={cold:.2f}s)")

        def after_cold():
            w.stage_s["cold_start"] += cold
            self._begin_load(w)
        self._begin_stage(w, COLD_START)
        self._schedule(t + cold, w, after_cold)
        return w

    def _begin_load(self, w: _Worker):
        self._begin_stage(w, STATE_LOAD)
        dur = self.plan.fetch_s
        if w.replay_rounds:
            # replay compute for rounds lost since the last checkpoint
            dur += w.replay_rounds * (self.plan.batches_per_round
                                      * self.plan.compute_s_per_batch)

        def loaded():
            w.stage_s["fetch"] += self.plan.fetch_s
            if w.replay_rounds:
                w.stage_s["replay"] += dur - self.plan.fetch_s
                self._log(w.id, f"replayed {w.replay_rounds} rounds")
                w.replay_rounds = 0
            w.joined = True
            self._begin_compute(w)
        self._schedule(self.t + dur, w, loaded)

    def _round_fetch_needed(self) -> bool:
        return (not self.plan.fetch_first_round_only) and self.round_idx > 0

    def _begin_round(self, w: _Worker):
        """Top of a round for an already-joined worker."""
        if self._round_fetch_needed():
            self._begin_stage(w, STATE_LOAD)

            def loaded():
                self._end_stage(w, "fetch")
                self._begin_compute(w)
            self._schedule(self.t + self.plan.fetch_s, w, loaded)
        else:
            self._begin_compute(w)

    def _begin_compute(self, w: _Worker):
        self._begin_stage(w, COMPUTE)
        slow = self.faults.slowdown(w.id, self.t)
        dur = (self.plan.batches_per_round * w.work_mult
               * self.plan.compute_s_per_batch * slow)
        if slow > 1.0:
            self._log(w.id, f"straggling x{slow:.1f}")

        def computed():
            self._end_stage(w, "compute")
            self._begin_sync(w)
        self._schedule(self.t + dur, w, computed)

    def _begin_sync(self, w: _Worker):
        self._begin_stage(w, SYNC)

        def synced():
            self._end_stage(w, "sync")
            w.state = WAIT_BARRIER
            w._stage_started = self.t
            if w.pending_recovery is not None:
                # back at the barrier: recovery complete
                w.pending_recovery.rejoined_time_s = self.t
                w.pending_recovery = None
                w.restoring = False
            self.arrived.add(w.id)
            self._maybe_release_barrier()
        self._schedule(self.t + self.plan.sync_s * w.work_mult, w, synced)

    # ------------------------------------------------------------ barrier
    def _expected(self) -> List[_Worker]:
        """Workers the current barrier must wait for.  A checkpoint-
        restoring worker stays expected (synchronous training cannot
        proceed without its gradient — the fleet stalls, which is the
        measured time-to-recover); a taken-over worker does not.  The
        epoch-start fleet is expected from t=0 (a cold-start storm gates
        the first barrier); autoscaled workers only once they join."""
        return [w for w in self.workers
                if (w.alive or w.restoring)
                and (w.joined or w.initial)
                and w.done_time is None]

    def _maybe_release_barrier(self):
        expected = self._expected()
        if not expected or any(w.id not in self.arrived for w in expected):
            return
        release_at = max(self.t, self.barrier_not_before)
        self._schedule(release_at, None, self._release_barrier)

    def _release_barrier(self):
        expected = self._expected()
        if any(w.id not in self.arrived for w in expected):
            return                      # a recovery hold re-queued us
        if self.barrier_not_before > self.t:
            self._schedule(self.barrier_not_before, None,
                           self._release_barrier)
            return
        # byzantine accounting for this aggregation round; masking needs
        # a feasible trimmed aggregate (W > 2*trim, see recovery.py) AND
        # no more byzantine contributions than the trim width
        n_byz = sum(1 for w in expected if w.byzantine)
        if n_byz:
            feasible = len(expected) > 2 * self.robust_trim
            if feasible and n_byz <= self.robust_trim:
                self.masked += n_byz
            else:
                self.poisoned += n_byz
        batches = sum(self.plan.batches_per_round * w.work_mult
                      for w in expected)
        self.pool -= batches
        self.round_idx += 1
        self.arrived.clear()
        self._log(-1, f"barrier round={self.round_idx} "
                      f"workers={len(expected)}")
        for w in expected:
            w.stage_s["wait"] += self.t - w._stage_started
            self._begin_update(w)
        if self.autoscaler is not None:
            self._autoscale_hook()

    def _begin_update(self, w: _Worker):
        self._begin_stage(w, UPDATE)

        def updated():
            self._end_stage(w, "update")
            if self.pool > 1e-9 and not self._retire_if_requested(w):
                self._begin_round(w)
            elif w.alive and w.done_time is None:
                w.state = DONE
                w.done_time = self.t
                self._log(w.id, "done")
        self._schedule(self.t + self.plan.update_s, w, updated)

    def _retire_if_requested(self, w: _Worker) -> bool:
        if self._pending_scale_in > 0 and len(self._expected()) > 1:
            self._pending_scale_in -= 1
            w.alive = False
            w.state = DONE
            w.done_time = self.t
            self._log(w.id, "scaled in")
            return True
        return False

    # ------------------------------------------------------------ faults
    def _on_crash(self, w: _Worker, t: float):
        if not w.alive or w.done_time is not None:
            return
        w.gen += 1                      # invalidate in-flight events
        w.alive = False
        w.state = DEAD
        self.arrived.discard(w.id)
        self._log(w.id, "CRASH")
        ch = self.setup.channel
        if isinstance(self.recovery, PeerTakeover):
            # survivors fetch the dead worker's in-DB partition and
            # absorb its share of the remaining work; the dead Lambda
            # stops billing at the crash
            w.done_time = t
            rejoin = (t + self.recovery.detection_s
                      + ch.transfer(self.plan.model_bytes, ops=1))
            survivors = [v for v in self.workers
                         if v.alive and v.id != w.id]
            if survivors:
                extra = w.work_mult / len(survivors)
                for v in survivors:
                    v.work_mult += extra
            self.barrier_not_before = max(self.barrier_not_before, rejoin)
            self.recoveries.append(RecoveryEvent(
                worker=w.id, crash_time_s=t, rejoined_time_s=rejoin,
                mode="takeover"))
            self._log(w.id, f"takeover by {len(survivors)} peers")
            self._schedule(rejoin, None, self._maybe_release_barrier)
        else:
            replay = self.recovery.replay_rounds(self.round_idx)
            rec = RecoveryEvent(worker=w.id, crash_time_s=t,
                                rejoined_time_s=math.nan, mode="restore")
            self.recoveries.append(rec)
            w.restoring = True
            w.pending_recovery = rec

            def respawn():
                self._spawn_worker(self.t, replay_rounds=replay,
                                   existing=w)
            self._schedule(t + self.recovery.detection_s, None, respawn)

    # ------------------------------------------------------------ scaling
    def _autoscale_hook(self):
        expected = self._expected()
        ideal = (self.plan.fetch_s * (0 if self.plan.fetch_first_round_only
                                      else 1)
                 + self.plan.batches_per_round
                 * self.plan.compute_s_per_batch
                 + self.plan.sync_s + self.plan.update_s)
        delta = self.autoscaler.observe(
            round_idx=self.round_idx, now_s=self.t,
            active_workers=len(expected),
            remaining_batches=max(self.pool, 0.0),
            batches_per_round=self.plan.batches_per_round,
            ideal_round_s=ideal)
        if delta > 0:
            for _ in range(delta):
                self._log(-1, "scale out +1")
                self._spawn_worker(self.t)
            self.scale_events.append((self.t, delta))
        elif delta < 0:
            self._pending_scale_in += -delta
            self.scale_events.append((self.t, delta))

    # ------------------------------------------------------------ driver
    def run(self) -> RuntimeReport:
        plan, setup = self.plan, self.setup
        self._storm_victims = set(self.faults.storm_victims(plan.n_workers))
        byz = set(self.faults.byzantine_workers())
        for i in range(plan.n_workers):
            self._spawn_worker(0.0, byzantine=i in byz).initial = True
        for c in self.faults.crashes:
            if c.worker < len(self.workers):
                w = self.workers[c.worker]
                self._schedule(c.time_s, None,
                               lambda w=w, t=c.time_s:
                               self._on_crash(w, max(t, self.t)))

        guard = 0
        while self._heap:
            t, _, wid, gen, fn = heapq.heappop(self._heap)
            if wid >= 0 and self.workers[wid].gen != gen:
                continue                # event from a crashed incarnation
            self.t = max(self.t, t)
            fn()
            guard += 1
            if guard > 2_000_000:
                raise RuntimeError("event-loop runaway (>2M events)")

        makespan = max((w.done_time for w in self.workers
                        if w.done_time is not None), default=self.t)
        # simulate_epoch's closed form, from the same plan terms
        analytic = (setup.cold_start_s
                    + plan.fetch_s * (1 if plan.fetch_first_round_only
                                      else plan.n_rounds)
                    + plan.total_batches * plan.compute_s_per_batch
                    + plan.n_rounds * (plan.sync_s + plan.update_s))

        # billing: lambda bills each worker's invocation wall-clock;
        # the GPU baseline bills instances for the whole makespan
        if plan.arch == "gpu":
            total_cost = pricing.gpu_cost(makespan,
                                          n_instances=len(self.workers))
        else:
            total_cost = sum(
                pricing.lambda_cost((w.done_time or makespan)
                                    - w.spawn_time, plan.ram_gb)
                for w in self.workers)

        stage_totals: Dict[str, float] = {}
        for w in self.workers:
            for k, v in w.stage_s.items():
                stage_totals[k] = stage_totals.get(k, 0.0) + v
        alive_end = sum(1 for w in self.workers if w.alive)
        return RuntimeReport(
            arch=plan.arch, makespan_s=makespan, analytic_s=analytic,
            rounds=self.round_idx,
            work_done_batches=plan.n_workers * plan.total_batches
            - max(self.pool, 0.0),
            n_workers_start=plan.n_workers,
            n_workers_peak=len(self.workers),
            n_workers_end=alive_end, total_cost=total_cost,
            stage_totals=stage_totals, recoveries=self.recoveries,
            poisoned_updates=self.poisoned, masked_updates=self.masked,
            scale_events=self.scale_events, timeline=self.timeline)


def run_event_epoch(arch: str, *, n_params: int, compute_s_per_batch: float,
                    setup: ServerlessSetup = ServerlessSetup(),
                    significant_fraction: float = 0.3,
                    accumulation: int = 24,
                    faults: Optional[FaultPlan] = None,
                    recovery: Optional[RecoveryPolicy] = None,
                    autoscaler=None, robust_trim: int = 0) -> RuntimeReport:
    """One event-driven epoch; mirrors ``simulate_epoch``'s signature."""
    plan = round_plan(arch, n_params=n_params,
                      compute_s_per_batch=compute_s_per_batch, setup=setup,
                      significant_fraction=significant_fraction,
                      accumulation=accumulation)
    return EventRuntime(plan, setup, faults=faults, recovery=recovery,
                        autoscaler=autoscaler,
                        robust_trim=robust_trim).run()
