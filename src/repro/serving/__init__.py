"""Request-level serving simulation subsystem.

Import-light by design: the workload / fleet / steady-state layers are
numpy-only so analytic sweeps never pay accelerator import costs.  The
real engine (``jax``-backed) stays a direct-module import:
``from repro.serving.engine import ServingEngine``.
"""
from repro.serving.workload import (  # noqa: F401
    RequestPlan, Workload,
)
from repro.serving.fleet import (  # noqa: F401
    FleetReport, FleetSim,
)
from repro.serving.steady_state import (  # noqa: F401
    ServingGrid, ServingSweep, analytic_point, serving_sweep_analytic,
)
