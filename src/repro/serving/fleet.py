"""Request-level discrete-event simulation of a continuous-batching
serving fleet.

:class:`FleetSim` schedules a :class:`~repro.serving.workload.RequestPlan`
onto replicas whose per-step semantics are the real
:class:`~repro.serving.engine.ServingEngine`'s, timed instead of
executed (``tests/test_serving_engine.py`` pins the semantics this
model cites):

  * admission prefills queued requests one at a time (batch 1) into
    free cache slots — the prefill produces the FIRST token, so TTFT is
    the request's own prefill end minus its arrival, and a
    one-token request completes at admission without occupying a slot;
  * every engine step then decodes ONE token for every active slot in
    ``decode_step_s`` wall-clock — a half-empty batch pays the same
    step time as a full one, which is exactly the utilisation/latency
    trade continuous batching exists to manage;
  * a finished slot frees immediately for the next queued request (no
    head-of-line blocking).

Around that per-replica core sit the serverless stack's pieces:
replicas cold-start through the measured :class:`Trace` tails
(arXiv 2105.07806) with the fault stack's fixed-draws-per-spawn seeding,
:class:`~repro.serverless.autoscale.ReactiveAutoscaler` drives
scale-in/out at control ticks (observing queue depth and recent
latency through its existing barrier contract), and the fleet bills
through each :class:`~repro.serverless.archs.ArchSpec`'s
``fleet_cost`` — Lambda replicas pay GB-seconds for their whole
up-time (idle included), the GPU baseline pays instance-hours on the
makespan.  Per-step compute follows the training sweeps'
``ram_scaled_compute`` rule: Lambda vCPU scales with the RAM tier,
accelerator-backed archs (``ram_scales_compute=False``) get a fixed
``gpu_speedup`` over the reference tier instead.

The event loop is the ``EventRuntime`` idiom: a single heap of
``(t, seq, op, arg)`` tuples with integer opcodes and ``__slots__``
replica records; no RNG anywhere except the seeded cold-start draws,
so a run is a pure function of ``(sim, plan)``.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import List, Optional, Tuple

import numpy as np

from repro.guards import no_tracer_fields
from repro.serverless.archs import get_arch
from repro.serverless.autoscale import ReactiveAutoscaler
from repro.serverless.traces import Trace
from repro.serving.workload import RequestPlan, Workload, _stream_rng

# opcodes (heap events are (t, seq, op, arg) — seq breaks ties, so runs
# are deterministic however floats collide)
_ARRIVAL, _REPLICA, _CONTROL = range(3)

# cold-start sub-stream key; disjoint from the Workload's field streams
# by living under a different dataclass seed, but keep it distinct
# anyway so a shared seed never aliases draws
_STREAM_COLD = 7


class _Replica:
    """One continuous-batching replica; ``__slots__`` record like the
    training runtime's workers."""
    __slots__ = ("idx", "state", "slots", "up_since", "end_s",
                 "draining")
    COLD, IDLE, BUSY, DEAD = range(4)

    def __init__(self, idx: int, batch_size: int, up_since: float):
        self.idx = idx
        self.state = _Replica.COLD
        self.slots: List[Optional[Tuple[int, int]]] = [None] * batch_size
        self.up_since = up_since
        self.end_s: Optional[float] = None      # retire time, else billed
        self.draining = False                   # to the fleet makespan

    def active(self) -> int:
        return sum(s is not None for s in self.slots)


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Everything one fleet run measured."""
    arch: str
    n_requests: int
    makespan_s: float
    latency_p50_s: float
    latency_p95_s: float
    latency_p99_s: float
    ttft_p50_s: float
    ttft_p95_s: float
    mean_latency_s: float
    throughput_rps: float
    tokens_generated: int
    total_cost: float
    usd_per_1k_requests: float
    peak_replicas: int
    replica_seconds: float
    n_cold_starts: int
    scale_decisions: Tuple[Tuple[int, int, str], ...] = ()
    latencies_s: Tuple[float, ...] = dataclasses.field(
        default=(), repr=False)

    def __post_init__(self):
        # runtime backstop for the static trace-safety rule: a report
        # built inside a traced function would freeze abstract values
        # into BENCH payloads
        no_tracer_fields(self)


@dataclasses.dataclass(frozen=True)
class FleetSim:
    """A continuous-batching fleet: configuration is frozen and
    validated eagerly; :meth:`run` is a pure function of the plan.

    ``prefill_s_per_token`` / ``decode_step_s`` are anchored at
    ``ref_ram_gb`` — the effective step times follow the arch's
    ``ram_scales_compute`` policy (see :meth:`step_times`).
    """
    arch: str = "spirt"
    replicas: int = 2                    # initial fleet size
    batch_size: int = 8                  # cache slots per replica
    ram_gb: float = 2.0
    prefill_s_per_token: float = 2e-4    # @ ref_ram_gb
    decode_step_s: float = 0.05          # @ ref_ram_gb
    ref_ram_gb: float = 2.0
    gpu_speedup: float = 8.0             # fixed-accelerator step speedup
    cold_start_s: float = 2.5
    min_replicas: int = 1
    max_replicas: int = 8
    autoscale: bool = False
    control_interval_s: float = 10.0
    trace: Optional[Trace] = None        # measured cold-start tails
    seed: int = 0                        # cold-start draws only

    def __post_init__(self):
        get_arch(self.arch)              # unknown arch fails here
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got "
                             f"{self.batch_size}")
        if not (1 <= self.min_replicas <= self.replicas
                <= self.max_replicas):
            raise ValueError(
                "need 1 <= min_replicas <= replicas <= max_replicas, "
                f"got {self.min_replicas} / {self.replicas} / "
                f"{self.max_replicas}")
        for f in ("ram_gb", "ref_ram_gb", "prefill_s_per_token",
                  "decode_step_s", "gpu_speedup", "control_interval_s"):
            v = getattr(self, f)
            if not (math.isfinite(v) and v > 0):
                raise ValueError(f"{f} must be finite and > 0, got {v}")
        if not (math.isfinite(self.cold_start_s)
                and self.cold_start_s >= 0):
            raise ValueError(f"cold_start_s must be >= 0, got "
                             f"{self.cold_start_s}")

    # ------------------------------------------------------------ model
    def step_times(self) -> Tuple[float, float]:
        """Effective (prefill_s_per_token, decode_step_s) for this arch
        and RAM tier — the serving twin of ``ram_scaled_compute``."""
        spec = get_arch(self.arch)
        if spec.ram_scales_compute:
            scale = self.ref_ram_gb / self.ram_gb
        else:
            scale = 1.0 / self.gpu_speedup
        return (self.prefill_s_per_token * scale,
                self.decode_step_s * scale)

    def service_s(self, prompt_tokens, decode_tokens):
        """No-queueing service time of a request: own prefill (which
        yields token 1) plus ``d - 1`` decode steps.  Elementwise."""
        prefill_s, decode_s = self.step_times()
        d = np.asarray(decode_tokens, float)
        return (np.asarray(prompt_tokens, float) * prefill_s
                + np.maximum(d - 1.0, 0.0) * decode_s)

    def _cold_time(self, u: float) -> float:
        """Replica cold start: base, or the measured tail when a trace
        is bound (``max(base, sample)`` — the fault stack's extra-over-
        base rule)."""
        if self.trace is None:
            return self.cold_start_s
        return max(self.cold_start_s,
                   float(self.trace.sample("cold_start_s", u)))

    # -------------------------------------------------------------- run
    def run_workload(self, workload: Workload, seed: int = 0,
                     scaler=None) -> FleetReport:
        return self.run(workload.generate(seed), scaler=scaler)

    def run(self, plan: RequestPlan, scaler=None) -> FleetReport:
        n = len(plan)
        if n == 0:
            raise ValueError("empty RequestPlan")
        if scaler is None and self.autoscale:
            scaler = ReactiveAutoscaler(min_workers=self.min_replicas,
                                        max_workers=self.max_replicas)
        prefill_s, decode_s = self.step_times()
        ideal_s = float(np.mean(self.service_s(plan.prompt_tokens,
                                               plan.decode_tokens)))
        cold_rng = _stream_rng(self.seed, _STREAM_COLD)

        heap: list = []
        seq = itertools.count()

        def push(t: float, op: int, arg: int):
            heapq.heappush(heap, (t, next(seq), op, arg))

        reps: List[_Replica] = []
        live = 0
        n_cold = 0

        def spawn(t: float) -> Optional[_Replica]:
            nonlocal live, n_cold
            if live >= self.max_replicas:
                return None
            r = _Replica(len(reps), self.batch_size, up_since=t)
            reps.append(r)
            live += 1
            n_cold += 1
            push(t + self._cold_time(cold_rng.random()), _REPLICA, r.idx)
            return r

        for _ in range(self.replicas):
            spawn(0.0)
        peak = live

        queue: deque = deque()
        ttft = [0.0] * n
        finish = [math.inf] * n
        completed = 0

        arrival = plan.arrival_s
        for i, t_a in enumerate(arrival):
            push(t_a, _ARRIVAL, i)

        # autoscaler adapter state: a fake clock whose "round" length is
        # the window's mean completed latency, so the scaler's EMA/ratio
        # logic reads serving latency the way it reads round times
        fake_now = 0.0
        tick = 0
        window: List[float] = []
        if scaler is not None:
            push(self.control_interval_s, _CONTROL, 0)

        def replica_step(r: _Replica, t: float):
            nonlocal completed, live
            if r.state == _Replica.DEAD:
                return
            if r.draining and r.active() == 0:
                r.state = _Replica.DEAD
                r.end_s = t
                live -= 1
                return
            r.state = _Replica.BUSY
            t_cur = t
            if not r.draining:
                for slot in range(self.batch_size):
                    # ServingEngine._admit: serial batch-1 prefills; a
                    # request done AT prefill frees the slot for the
                    # next queued one immediately
                    while r.slots[slot] is None and queue:
                        i = queue.popleft()
                        t_cur += plan.prompt_tokens[i] * prefill_s
                        ttft[i] = t_cur - arrival[i]
                        rem = plan.decode_tokens[i] - 1
                        if rem <= 0:
                            finish[i] = t_cur
                            window.append(t_cur - arrival[i])
                            completed += 1
                        else:
                            r.slots[slot] = (i, rem)
            if r.active() == 0:
                r.state = _Replica.IDLE
                return
            # one decode step: every active slot gains one token
            t_end = t_cur + decode_s
            for slot in range(self.batch_size):
                held = r.slots[slot]
                if held is None:
                    continue
                i, rem = held
                rem -= 1
                if rem == 0:
                    finish[i] = t_end
                    window.append(t_end - arrival[i])
                    completed += 1
                    r.slots[slot] = None        # _retire: frees now
                else:
                    r.slots[slot] = (i, rem)
            push(t_end, _REPLICA, r.idx)

        def control(t: float):
            nonlocal fake_now, tick, window, peak, live
            tick += 1
            round_s = (sum(window) / len(window)) if window else ideal_s
            window = []
            in_flight = sum(r.active() for r in reps
                            if r.state != _Replica.DEAD)
            fake_now += round_s
            delta = scaler.observe(
                round_idx=tick, now_s=fake_now,
                active_workers=live,
                remaining_batches=len(queue) + in_flight,
                batches_per_round=float(self.batch_size),
                ideal_round_s=ideal_s)
            if delta > 0:
                for _ in range(delta):
                    if spawn(t) is None:
                        break
                peak = max(peak, live)
            elif delta < 0:
                # drain from the top: newest non-draining replica first
                standing = [r for r in reps
                            if r.state != _Replica.DEAD
                            and not r.draining]
                for r in standing[-(-delta):][::-1]:
                    # keep min_replicas replicas that will still ACCEPT
                    # work — draining ones are already on their way out
                    if len(standing) <= self.min_replicas:
                        break
                    standing.remove(r)
                    r.draining = True
                    if r.state == _Replica.IDLE:
                        r.state = _Replica.DEAD
                        r.end_s = t
                        live -= 1
            if completed < n:
                push(t + self.control_interval_s, _CONTROL, 0)

        while heap:
            t, _, op, arg = heapq.heappop(heap)
            if op == _ARRIVAL:
                queue.append(arg)
                for r in reps:
                    if r.state == _Replica.IDLE and not r.draining:
                        r.state = _Replica.BUSY  # claimed; no double wake
                        push(t, _REPLICA, r.idx)
                        break
            elif op == _REPLICA:
                replica_step(reps[arg], t)
            else:
                control(t)

        if completed < n:
            raise RuntimeError(
                f"fleet stalled: {completed}/{n} requests completed "
                "(all replicas drained with work queued?)")

        makespan = max(finish)
        wall_clocks = [(r.end_s if r.end_s is not None else makespan)
                       - r.up_since for r in reps]
        spec = get_arch(self.arch)
        cost = float(spec.fleet_cost(wall_clocks, self.ram_gb, makespan,
                                     n_instances=peak))
        lat = np.asarray([finish[i] - arrival[i] for i in range(n)])
        ttft_a = np.asarray(ttft)
        p50, p95, p99 = (float(np.percentile(lat, q))
                         for q in (50, 95, 99))
        return FleetReport(
            arch=self.arch, n_requests=n, makespan_s=float(makespan),
            latency_p50_s=p50, latency_p95_s=p95, latency_p99_s=p99,
            ttft_p50_s=float(np.percentile(ttft_a, 50)),
            ttft_p95_s=float(np.percentile(ttft_a, 95)),
            mean_latency_s=float(lat.mean()),
            throughput_rps=n / makespan if makespan > 0 else 0.0,
            tokens_generated=plan.total_tokens,
            total_cost=cost,
            usd_per_1k_requests=cost / n * 1000.0,
            peak_replicas=peak,
            replica_seconds=float(sum(wall_clocks)),
            n_cold_starts=n_cold,
            scale_decisions=tuple(getattr(scaler, "decisions", ()))
            if scaler is not None else (),
            latencies_s=tuple(float(x) for x in lat))
