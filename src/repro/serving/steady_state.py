"""Vectorized steady-state fast path for the serving fleet.

The event engine (:mod:`repro.serving.fleet`) is exact but walks every
request; a latency/cost Pareto sweep wants arch × replicas × RAM ×
arrival-rate grids with thousands of points.  This module answers each
grid point in closed form the way ``sweep_analytic`` vectorized
training epochs — whole-grid numpy columns, no Python loop over
requests — which is what lets ``benchmarks/serving_sweep.py`` simulate
millions of requests per second of wall clock.

Queueing model, per grid point:

  * the fleet is an M/G/c station with ``c = replicas × batch_size``
    servers (every cache slot serves one request at a time; the engine
    decodes all active slots each step, so slots are effectively
    independent servers at the per-request service rate);
  * service time ``S = prompt · prefill_s + (decode − 1) · decode_s``
    over the workload's empirical token distributions (prompt and
    decode counts independent → their outer product is the joint
    sample set), with the arch/RAM step times from
    :meth:`FleetSim.step_times`;
  * the wait is Erlang-C with the Allen–Cunneen squared-CV correction
    — ``Wq = C/(cμ − λ) · (1 + CV²)/2`` — and an exponential
    conditional tail calibrated to that mean:
    ``P(W > x) = C · exp(−C·x/Wq)``;
  * latency percentiles invert ``F_L(t) = E_S[F_W(t − S)]`` by
    vectorized bisection across all stable points at once.

``ρ ≥ 1`` points are kept in the columns but marked unstable with
``inf`` latencies (an open-loop queue there grows without bound —
exactly what the event engine shows if you insist).  Steady state has
no cold starts and no autoscaler by construction; the event path
covers those transients, and ``tests/test_serving_fleet.py`` pins the
two paths' agreement on the overlapping grid.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.serverless.archs import get_arch, list_archs
from repro.serving.fleet import FleetSim
from repro.serving.workload import Workload


def _erlang_c(c: np.ndarray, a: np.ndarray) -> np.ndarray:
    """P(wait) for M/M/c at offered load ``a = λ·E[S]`` erlangs, via
    the Erlang-B recursion (vectorized over points; ``c`` is the
    per-point server count).  Valid where ``a < c``."""
    b = np.ones_like(a)
    kmax = int(c.max())
    for ki in range(1, kmax + 1):
        nb = a * b / (ki + a * b)
        b = np.where(ki <= c, nb, b)
    rho = a / c
    return b / (1.0 - rho * (1.0 - b))


@dataclasses.dataclass(frozen=True)
class ServingGrid:
    """Arch × replicas × RAM × arrival-rate grid for the analytic
    sweep; token distributions come from ``workload`` (its own rate is
    ignored — ``rate_rps`` is the swept axis)."""
    archs: Tuple[str, ...] = ()            # () => every registered arch
    replicas: Tuple[int, ...] = (1, 2, 4)
    ram_gb: Tuple[float, ...] = (2.0, 4.0)
    rate_rps: Tuple[float, ...] = (0.5, 1.0, 2.0, 4.0)
    batch_size: int = 8
    workload: Optional[Workload] = None    # None => bundled LLM trace
    prefill_s_per_token: float = 2e-4      # @ ref_ram_gb
    decode_step_s: float = 0.05
    ref_ram_gb: float = 2.0
    gpu_speedup: float = 8.0
    n_requests: int = 10_000               # per-point request mass

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got "
                             f"{self.batch_size}")
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got "
                             f"{self.n_requests}")
        for f, lo in (("replicas", 1), ("ram_gb", 0), ("rate_rps", 0)):
            vals = getattr(self, f)
            if not vals or any(v < lo or (lo == 0 and v <= 0)
                               for v in vals):
                raise ValueError(f"{f} must be non-empty with values "
                                 f">{'=' if lo else ''} {lo or 0}, "
                                 f"got {vals}")

    def resolved_archs(self) -> Tuple[str, ...]:
        return self.archs or list_archs()

    def resolved_workload(self) -> Workload:
        if self.workload is not None:
            return self.workload
        from repro.serverless.traces import request_default
        return Workload(n_requests=self.n_requests,
                        trace=request_default())


@dataclasses.dataclass(frozen=True)
class ServingSweep:
    """Columnar result of :func:`serving_sweep_analytic` (one row per
    grid point)."""
    grid: ServingGrid
    arch: np.ndarray                   # str
    replicas: np.ndarray
    ram_gb: np.ndarray
    rate_rps: np.ndarray
    servers: np.ndarray                # c = replicas * batch_size
    rho: np.ndarray                    # utilisation; >= 1 => unstable
    stable: np.ndarray                 # bool
    service_mean_s: np.ndarray         # E[S]
    wait_mean_s: np.ndarray            # Wq (Allen–Cunneen)
    mean_latency_s: np.ndarray         # Wq + E[S]
    latency_p50_s: np.ndarray
    latency_p95_s: np.ndarray
    latency_p99_s: np.ndarray
    total_cost: np.ndarray             # serving grid.n_requests requests
    usd_per_1k_requests: np.ndarray

    def __len__(self) -> int:
        return len(self.arch)

    @property
    def requests_simulated(self) -> int:
        """Request mass the sweep covered — the throughput-record
        numerator (requests answered per wall-clock second)."""
        return len(self) * self.grid.n_requests


def _latency_percentile(q, s_samples, pw, theta, stable):
    """Invert F_L(t) = mean_i F_W(t - S_i) by bisection, vectorized
    over points.  ``s_samples`` is (N, M); ``pw``/``theta`` are (N,)."""
    n = s_samples.shape[0]
    out = np.full(n, np.inf)
    idx = np.flatnonzero(stable)
    if idx.size == 0:
        return out
    s = s_samples[idx]
    pwv = pw[idx][:, None]
    thv = theta[idx][:, None]

    def cdf(t):
        x = t[:, None] - s
        fw = np.where(x >= 0.0, 1.0 - pwv * np.exp(-thv * np.maximum(x, 0.0)),
                      0.0)
        return fw.mean(axis=1)

    lo = s.min(axis=1)
    hi = s.max(axis=1) + 1.0
    # expand hi until the CDF clears q everywhere (wait tails are
    # exponential, so doubling converges fast)
    for _ in range(60):
        short = cdf(hi) < q
        if not short.any():
            break
        hi = np.where(short, hi * 2.0 + 1.0, hi)
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        below = cdf(mid) < q
        lo = np.where(below, mid, lo)
        hi = np.where(below, hi, mid)
    out[idx] = 0.5 * (lo + hi)
    return out


def serving_sweep_analytic(grid: ServingGrid) -> ServingSweep:
    """Evaluate the whole grid in closed form (columnar, vectorized)."""
    archs = grid.resolved_archs()
    wl = grid.resolved_workload()

    # joint service-time sample set per (arch, ram): prompt and decode
    # counts are independent empirical draws -> outer product
    if wl.trace is not None and wl.trace.prompt_tokens:
        p_s = np.asarray(wl.trace.prompt_tokens, float)
    else:
        p_s = np.asarray([float(wl.prompt_tokens)])
    if wl.trace is not None and wl.trace.decode_tokens:
        d_s = np.asarray(wl.trace.decode_tokens, float)
    else:
        d_s = np.asarray([float(wl.decode_tokens)])
    pp, dd = np.meshgrid(p_s, d_s, indexing="ij")
    pp, dd = pp.ravel(), dd.ravel()            # (M,)

    rows_arch, rows_R, rows_ram, rows_rate = [], [], [], []
    step_pre, step_dec = [], []
    for a in archs:
        spec = get_arch(a)
        for ram in grid.ram_gb:
            if spec.ram_scales_compute:
                scale = grid.ref_ram_gb / ram
            else:
                scale = 1.0 / grid.gpu_speedup
            for R in grid.replicas:
                for rate in grid.rate_rps:
                    rows_arch.append(a)
                    rows_R.append(R)
                    rows_ram.append(ram)
                    rows_rate.append(rate)
                    step_pre.append(grid.prefill_s_per_token * scale)
                    step_dec.append(grid.decode_step_s * scale)

    arch_c = np.asarray(rows_arch, object)
    R_c = np.asarray(rows_R, float)
    ram_c = np.asarray(rows_ram, float)
    rate_c = np.asarray(rows_rate, float)
    pre_c = np.asarray(step_pre)[:, None]      # (N, 1)
    dec_c = np.asarray(step_dec)[:, None]

    s_samples = pp[None, :] * pre_c + np.maximum(dd - 1.0, 0.0)[None, :] \
        * dec_c                                # (N, M)
    es = s_samples.mean(axis=1)
    var = s_samples.var(axis=1)
    cv2 = np.divide(var, es ** 2, out=np.zeros_like(var),
                    where=es > 0)

    c = R_c * grid.batch_size
    a_load = rate_c * es
    rho = a_load / c
    stable = rho < 1.0

    pw = np.zeros_like(rho)
    wq = np.zeros_like(rho)
    if stable.any():
        i = np.flatnonzero(stable)
        pw_i = _erlang_c(c[i], a_load[i])
        mu = 1.0 / es[i]
        wq_i = pw_i / (c[i] * mu - rate_c[i]) * (1.0 + cv2[i]) / 2.0
        pw[i], wq[i] = pw_i, wq_i
    theta = np.divide(pw, wq, out=np.full_like(pw, np.inf),
                      where=wq > 0)            # tail rate: E[W] = Wq

    p50 = _latency_percentile(0.50, s_samples, pw, theta, stable)
    p95 = _latency_percentile(0.95, s_samples, pw, theta, stable)
    p99 = _latency_percentile(0.99, s_samples, pw, theta, stable)
    mean_lat = np.where(stable, wq + es, np.inf)

    # steady-state billing: serve grid.n_requests requests at rate λ ->
    # horizon T = n/λ, every replica up for all of it (the event path's
    # fleet_cost with R equal wall clocks)
    horizon = grid.n_requests / rate_c
    cost = np.empty_like(rate_c)
    for j in range(len(cost)):
        spec = get_arch(arch_c[j])
        cost[j] = spec.fleet_cost([horizon[j]] * int(R_c[j]), ram_c[j],
                                  horizon[j], n_instances=int(R_c[j]))
    usd_per_1k = cost / grid.n_requests * 1000.0

    return ServingSweep(
        grid=grid, arch=arch_c, replicas=R_c.astype(int),
        ram_gb=ram_c, rate_rps=rate_c, servers=c.astype(int), rho=rho,
        stable=stable, service_mean_s=es, wait_mean_s=wq,
        mean_latency_s=mean_lat, latency_p50_s=p50, latency_p95_s=p95,
        latency_p99_s=p99, total_cost=cost,
        usd_per_1k_requests=usd_per_1k)


def analytic_point(sim: FleetSim, workload: Workload,
                   rate_rps: Optional[float] = None) -> dict:
    """One FleetSim configuration through the analytic path — the
    agreement tests' bridge between the two engines."""
    grid = ServingGrid(
        archs=(sim.arch,), replicas=(sim.replicas,),
        ram_gb=(sim.ram_gb,),
        rate_rps=(rate_rps if rate_rps is not None
                  else workload.mean_rate_rps(),),
        batch_size=sim.batch_size, workload=workload,
        prefill_s_per_token=sim.prefill_s_per_token,
        decode_step_s=sim.decode_step_s, ref_ram_gb=sim.ref_ram_gb,
        gpu_speedup=sim.gpu_speedup)
    sw = serving_sweep_analytic(grid)
    return {f.name: getattr(sw, f.name)[0]
            for f in dataclasses.fields(sw) if f.name != "grid"}
