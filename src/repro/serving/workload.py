"""Seeded open-loop request workloads for the serving fleet simulator.

A :class:`Workload` describes *traffic*: an open-loop arrival process
(requests arrive on their own clock — a slow fleet does not slow the
arrivals, it grows the queue) plus per-request prompt/decode token
counts.  Two sources, mirroring the fault side's Poisson-vs-trace
split (``repro.serverless.faults.FaultPlan.random`` vs
``FaultPlan.from_trace``):

  * **Poisson** — exponential inter-arrival gaps at ``rate_rps`` with
    fixed token counts; the memoryless baseline every queueing formula
    assumes.
  * **Trace-driven** — gaps and token counts resampled from a
    :class:`repro.serverless.traces.RequestTrace` by inverse CDF (the
    bundled default digitizes the Splitwise / Azure LLM-inference
    distributions, arXiv 2311.18677), optionally rescaled to a target
    rate with the burstiness shape preserved.

Seeding discipline is the fault stack's: every random field draws from
its own disjoint ``SeedSequence`` sub-stream with a FIXED number of
uniforms per request, so a :class:`RequestPlan` is a pure function of
``(workload, seed)``, request ``i``'s draws never shift request
``j``'s, and growing ``n_requests`` extends a plan without disturbing
its prefix (tested in ``tests/test_workload.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from repro.serverless.traces import RequestTrace

# per-field sub-stream keys; appending is fine, reordering breaks replay
(_STREAM_ARRIVAL, _STREAM_PROMPT, _STREAM_DECODE) = range(3)


def _stream_rng(seed: int, stream: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(stream,)))


@dataclasses.dataclass(frozen=True)
class RequestPlan:
    """A fully-resolved request stream: one row per request, sorted by
    arrival.  Immutable plain tuples so plans hash/compare/pickle like
    :class:`~repro.serverless.faults.FaultPlan`."""
    arrival_s: Tuple[float, ...]
    prompt_tokens: Tuple[int, ...]
    decode_tokens: Tuple[int, ...]
    seed: int = 0

    def __post_init__(self):
        n = len(self.arrival_s)
        if not (len(self.prompt_tokens) == len(self.decode_tokens) == n):
            raise ValueError(
                f"ragged plan: {n} arrivals vs "
                f"{len(self.prompt_tokens)} prompts / "
                f"{len(self.decode_tokens)} decode counts")
        if any(b < a for a, b in zip(self.arrival_s,
                                     self.arrival_s[1:])):
            raise ValueError("arrival_s must be sorted")

    def __len__(self) -> int:
        return len(self.arrival_s)

    @property
    def total_tokens(self) -> int:
        """Tokens the stream asks the fleet to produce."""
        return int(sum(self.decode_tokens))

    @property
    def span_s(self) -> float:
        return self.arrival_s[-1] if self.arrival_s else 0.0


@dataclasses.dataclass(frozen=True)
class Workload:
    """Open-loop arrival process + token-count model.

    With a ``trace``, gaps (and token counts, where the trace has
    samples) come from its empirical distributions; without one, gaps
    are exponential at ``rate_rps`` and token counts are the fixed
    ``prompt_tokens`` / ``decode_tokens``.  ``rate_rps`` on a traced
    workload *rescales* the measured gaps to the target mean rate —
    burstiness (the gap distribution's shape) is preserved, only the
    clock speed changes.
    """
    n_requests: int = 256
    rate_rps: Optional[float] = None     # None + trace => native rate
    trace: Optional[RequestTrace] = None
    prompt_tokens: int = 512             # fixed counts (trace-less case)
    decode_tokens: int = 128

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got "
                             f"{self.n_requests}")
        if self.rate_rps is None and self.trace is None:
            raise ValueError("a Workload needs an arrival process: set "
                             "rate_rps (Poisson) and/or trace "
                             "(empirical)")
        if self.rate_rps is not None and not (
                math.isfinite(self.rate_rps) and self.rate_rps > 0):
            raise ValueError(f"rate_rps must be finite and > 0, got "
                             f"{self.rate_rps}")
        for f in ("prompt_tokens", "decode_tokens"):
            if getattr(self, f) < 1:
                raise ValueError(f"{f} must be >= 1, got "
                                 f"{getattr(self, f)}")

    # ------------------------------------------------------------ helpers
    def with_rate(self, rate_rps: float) -> "Workload":
        """This workload rescaled to a target mean arrival rate (the
        sweep grids' arrival-rate axis)."""
        return dataclasses.replace(self, rate_rps=rate_rps)

    def mean_rate_rps(self) -> float:
        if self.rate_rps is not None:
            return self.rate_rps
        return self.trace.mean_rate_rps()

    def mean_service_tokens(self) -> Tuple[float, float]:
        """(mean prompt, mean decode) token counts — the analytic
        steady-state path's workload moments."""
        if self.trace is not None and self.trace.prompt_tokens:
            p = float(np.mean(self.trace.prompt_tokens))
        else:
            p = float(self.prompt_tokens)
        if self.trace is not None and self.trace.decode_tokens:
            d = float(np.mean(self.trace.decode_tokens))
        else:
            d = float(self.decode_tokens)
        return p, d

    # ---------------------------------------------------------- generate
    def generate(self, seed: int = 0) -> RequestPlan:
        """Resolve the workload into a :class:`RequestPlan` — a pure
        function of ``(self, seed)``."""
        n = self.n_requests
        u_gap = _stream_rng(seed, _STREAM_ARRIVAL).random(n)
        if self.trace is not None:
            gaps = self.trace.sample("inter_arrival_s", u_gap)
            if self.rate_rps is not None:
                # rescale measured gaps to the target mean rate; the
                # scale uses the trace's POPULATION mean, not this
                # draw's, so two same-rate plans differ only by seed
                native = float(np.mean(self.trace.inter_arrival_s))
                gaps = gaps * (1.0 / (self.rate_rps * native))
        else:
            # inverse-CDF exponential: -ln(1-u)/rate (u in [0,1))
            gaps = -np.log1p(-u_gap) / self.rate_rps
        arrivals = np.cumsum(gaps)

        u_prompt = _stream_rng(seed, _STREAM_PROMPT).random(n)
        u_decode = _stream_rng(seed, _STREAM_DECODE).random(n)
        if self.trace is not None and self.trace.prompt_tokens:
            prompts = self.trace.sample("prompt_tokens", u_prompt)
        else:
            prompts = np.full(n, self.prompt_tokens, float)
        if self.trace is not None and self.trace.decode_tokens:
            decodes = self.trace.sample("decode_tokens", u_decode)
        else:
            decodes = np.full(n, self.decode_tokens, float)
        return RequestPlan(
            arrival_s=tuple(float(a) for a in arrivals),
            prompt_tokens=tuple(int(p) for p in prompts),
            decode_tokens=tuple(int(d) for d in decodes),
            seed=seed)
