"""Continuous-batching serving engine.

Decode-side request scheduler over ``Model.decode_step`` with per-slot
positions: new requests are prefilled individually (batch 1) and their
caches scattered into a fixed-size batched decode cache; every engine
step decodes ONE token for every active slot; finished slots free
immediately for the next queued request (no head-of-line blocking).

This is the serving-framework layer the inference shapes
(decode_32k / long_500k) exercise; batched-request serving per
deliverable (b).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)


def _batch_dim(path) -> int:
    """Cache leaves under blocks/ are stacked: batch lives at dim 1."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    return 1 if any(k in ("blocks", "enc_kv") for k in keys) else 0


def _scatter_request(full_cache, one_cache, slot: int):
    """Insert a batch-1 cache into slot ``slot`` of the batched cache."""
    def one(path, full, single):
        b = _batch_dim(path)
        idx = [slice(None)] * full.ndim
        idx[b] = slot
        return full.at[tuple(idx)].set(jnp.squeeze(single, axis=b))
    return jax.tree_util.tree_map_with_path(one, full_cache, one_cache)


class ServingEngine:
    def __init__(self, model, params, *, batch_size: int, cache_len: int,
                 swa_variant: bool = False):
        self.model = model
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.swa_variant = swa_variant
        self.cache = model.init_cache(batch_size, cache_len,
                                      swa_variant=swa_variant)
        self.positions = np.zeros(batch_size, np.int64)
        self.tokens = np.zeros((batch_size, 1), np.int32)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: deque = deque()
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0

        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(
                p, t, c, pos, swa_variant=swa_variant))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len,
                                       swa_variant=swa_variant))

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, eos_id=None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, eos_id))
        return rid

    def _admit(self):
        for slot in range(self.B):
            # a request can finish AT prefill (max_new_tokens=1, or the
            # first token is eos): it never occupies the slot, which
            # stays free for the next queued request
            while self.slots[slot] is None and self.queue:
                req = self.queue.popleft()
                logits, cache1 = self._prefill(
                    self.params,
                    {"tokens": jnp.asarray(req.prompt[None, :])})
                tok = int(np.argmax(np.asarray(
                    logits[0, -1, :self.model.cfg.vocab_size])))
                req.generated.append(tok)
                if len(req.generated) >= req.max_new_tokens or \
                        (req.eos_id is not None and tok == req.eos_id):
                    self.finished[req.rid] = req
                    continue
                self.cache = _scatter_request(self.cache, cache1, slot)
                self.tokens[slot, 0] = tok
                self.positions[slot] = len(req.prompt)
                self.slots[slot] = req

    def _retire(self, slot: int):
        req = self.slots[slot]
        self.finished[req.rid] = req
        self.slots[slot] = None

    def step(self) -> int:
        """Admit + decode one token for every active slot.  Returns the
        number of active requests after the step."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return 0
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.positions, jnp.int32))
        logits = np.asarray(logits[:, 0, :self.model.cfg.vocab_size])
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(np.argmax(logits[slot]))
            req.generated.append(tok)
            self.tokens[slot, 0] = tok
            self.positions[slot] += 1
            done = len(req.generated) >= req.max_new_tokens or \
                (req.eos_id is not None and tok == req.eos_id)
            if done:
                self._retire(slot)
        return sum(s is not None for s in self.slots)

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drain the queue; returns {rid: generated tokens}."""
        for _ in range(max_steps):
            active = self.step()
            if active == 0 and not self.queue:
                break
        return {rid: r.generated for rid, r in self.finished.items()}
