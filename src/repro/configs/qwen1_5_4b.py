"""Qwen1.5 4B — dense with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family card, scaled per the assignment]:
40 layers, d_model 2560, 20 heads / 20 KV heads, d_ff 6912, vocab 151936.
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    layer_pattern=(GLOBAL,),
    qkv_bias=True,
    window=4096,
    long_context="swa",
    citation="hf:Qwen/Qwen1.5-0.5B",
))
