"""Gemma-3 4B — dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family card, scaled per the assignment]:
34 layers, d_model 2560, 8 heads / 4 KV heads, d_ff 10240, vocab 262144.
Pattern: 5 sliding-window layers then 1 global layer.
"""
from repro.configs.base import GLOBAL, LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    layer_pattern=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL),
    window=1024,
    rope_theta=1_000_000.0,
    mlp="gelu",
    # local:global mix — global layers are linear at decode (1 query vs
    # cached K), local layers keep a window cache, so long_500k is native.
    long_context="native",
    citation="hf:google/gemma-3-1b-pt",
))
