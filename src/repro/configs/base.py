"""Architecture configuration system.

Every assigned architecture is a ``ModelConfig`` instance registered under
its public id (``--arch <id>``).  Configs are pure data: model code in
``repro.models`` interprets them; ``repro.launch.dryrun`` lowers them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kinds used in ``layer_pattern`` (repeating pattern over depth).
GLOBAL = "global"   # full (causal) attention
LOCAL = "local"     # sliding-window attention
RGLRU = "rglru"     # RG-LRU recurrent block (RecurrentGemma / Griffin)
RWKV = "rwkv"       # RWKV6 time-mix block (attention-free)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    # --- attention ---
    head_dim: Optional[int] = None       # default: d_model // n_heads
    window: int = 4096                   # sliding-window size for LOCAL layers
    layer_pattern: Tuple[str, ...] = (GLOBAL,)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mlp: str = "swiglu"                  # swiglu | gelu

    # --- mixture of experts ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- encoder-decoder (audio) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500              # stub conv-frontend frame count

    # --- vlm ---
    n_patches: int = 0                   # stub ViT-frontend patch count

    # --- rwkv ---
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64

    # --- rglru ---
    rglru_width: int = 0                 # recurrence width (default d_model)
    conv_width: int = 4

    # --- long-context policy (see DESIGN.md §3) ---
    # "native": sub-quadratic by construction (ssm/hybrid/swa archs)
    # "swa":    run long_500k with the sliding-window variant enabled
    # "skip":   long_500k not run (reason documented in DESIGN.md)
    long_context: str = "swa"

    # --- numerics ---
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rglru_width == 0:
            object.__setattr__(self, "rglru_width", self.d_model)
        assert self.n_heads % self.n_kv_heads == 0, (
            f"{self.name}: n_heads {self.n_heads} not divisible by "
            f"n_kv_heads {self.n_kv_heads}")

    # ----- derived quantities -----
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return all(k in (RWKV, RGLRU) for k in self.layer_pattern)

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        n_heads = max(1, min(self.n_heads, d_model // 64))
        ratio = max(1, self.n_heads // self.n_kv_heads)
        n_kv = max(1, n_heads // min(ratio, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        pat = self.layer_pattern[:max(1, n_layers)]
        changes = dict(
            n_layers=n_layers, d_model=d_model, head_dim=None,
            n_heads=n_heads, n_kv_heads=n_kv, d_ff=2 * d_model,
            vocab_size=min(self.vocab_size, vocab),
            window=min(self.window, 64),
            layer_pattern=pat,
            rwkv_head_dim=min(self.rwkv_head_dim, 32),
            rwkv_lora_rank=16,
            rglru_width=0,
            encoder_seq=32, n_patches=min(self.n_patches, 8),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            dtype="float32",
        )
        if self.is_moe:
            changes.update(n_experts=4, experts_per_token=2)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (see the task spec).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all():
    import importlib
    for mod in _ALL_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_arch_names():
    _load_all()
    return sorted(_REGISTRY)


_ALL_MODULES = [
    "mixtral_8x22b", "gemma3_4b", "mixtral_8x7b", "rwkv6_7b", "pixtral_12b",
    "smollm_135m", "whisper_small", "phi3_mini_3_8b", "recurrentgemma_2b",
    "qwen1_5_4b", "mobilenet_cifar", "resnet18_cifar",
]
