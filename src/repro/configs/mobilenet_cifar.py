"""MobileNet-style CNN for CIFAR — the paper's lightweight model (~4.2M params).

Depthwise-separable convolution stack (Howard et al. 2017), adapted to
32x32 inputs as in the paper's CIFAR-10 experiments.
"""
from dataclasses import dataclass, field
from typing import Tuple

from repro.configs import base


@dataclass(frozen=True)
class CNNConfig:
    name: str
    family: str = "cnn"
    kind: str = "mobilenet"            # mobilenet | resnet18
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    width_mult: float = 1.0
    dtype: str = "float32"
    citation: str = ""

    def reduced(self, **_):
        import dataclasses
        return dataclasses.replace(self, width_mult=0.25)


CONFIG = base.register(CNNConfig(
    name="mobilenet-cifar",
    kind="mobilenet",
    citation="paper §3.2 (MobileNet, ~4.2M params, CIFAR-10)",
))
