"""Whisper-small — encoder-decoder audio backbone (conv frontend stubbed).

[arXiv:2212.04356]: 12 encoder + 12 decoder layers, d_model 768,
12 heads (MHA), d_ff 3072, vocab 51865.  The mel-spectrogram + conv
feature extractor is the modality-frontend STUB: ``input_specs`` provides
precomputed frame embeddings (B, encoder_seq, d_model).

long_500k is SKIPPED for this arch (enc-dec decoder trained on short
transcripts; full-attention decoder — see DESIGN.md §3).
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                 # decoder layers
    n_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    layer_pattern=(GLOBAL,),
    encoder_seq=1500,
    qkv_bias=True,
    mlp="gelu",
    long_context="skip",
    citation="arXiv:2212.04356",
))
