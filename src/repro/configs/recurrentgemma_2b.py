"""RecurrentGemma 2B — hybrid: RG-LRU recurrence + local attention, 1:2.

[arXiv:2402.19427] (Griffin): 26 layers, d_model 2560, 10 heads / 1 KV
head (MQA), d_ff 7680, vocab 256000.  Pattern: 2 recurrent blocks then
1 local-attention block.
"""
from repro.configs.base import LOCAL, RGLRU, ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=(RGLRU, RGLRU, LOCAL),
    window=2048,
    mlp="gelu",
    long_context="native",    # recurrent state + window cache only
    citation="arXiv:2402.19427",
))
