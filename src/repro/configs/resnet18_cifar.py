"""ResNet-18 for CIFAR — the paper's heavier model (11.7M params)."""
from repro.configs import base
from repro.configs.mobilenet_cifar import CNNConfig

CONFIG = base.register(CNNConfig(
    name="resnet18-cifar",
    kind="resnet18",
    citation="paper §3.2 (ResNet-18, 11.7M params, CIFAR-10)",
))
