"""Mixtral 8x7B — MoE, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088]: 32 layers, d_model 4096, 32 heads / 8 KV heads,
d_ff 14336, vocab 32000.
"""
from repro.configs.base import LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    layer_pattern=(LOCAL,),
    window=4096,
    n_experts=8,
    experts_per_token=2,
    rope_theta=1_000_000.0,
    long_context="native",
    citation="arXiv:2401.04088",
))
