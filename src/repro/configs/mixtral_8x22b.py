"""Mixtral 8x22B — MoE, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088] (Mixtral of Experts; 8x22B per public model card:
56 layers, d_model 6144, 48 heads / 8 KV heads, d_ff 16384, vocab 32768).
"""
from repro.configs.base import LOCAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    layer_pattern=(LOCAL,),          # SWA on every layer
    window=4096,
    n_experts=8,
    experts_per_token=2,
    rope_theta=1_000_000.0,
    long_context="native",           # SWA => sub-quadratic decode cache
    citation="arXiv:2401.04088",
))
