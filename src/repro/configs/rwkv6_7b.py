"""RWKV-6 (Finch) 7B — attention-free SSM with data-dependent decay.

[arXiv:2404.05892]: 32 layers, d_model 4096, d_ff 14336, vocab 65536.
Head size 64 (64 wkv heads).
"""
from repro.configs.base import RWKV, ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern=(RWKV,),
    rwkv_head_dim=64,
    rwkv_lora_rank=64,
    mlp="gelu",              # channel-mix uses squared-relu-ish; gelu stand-in
    long_context="native",   # constant-size recurrent state
    citation="arXiv:2404.05892",
))
