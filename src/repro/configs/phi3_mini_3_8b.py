"""Phi-3-mini 3.8B — dense, RoPE + SwiGLU + GQA (32 KV heads = MHA).

[arXiv:2404.14219]: 32 layers, d_model 3072, 32 heads / 32 KV heads,
d_ff 8192, vocab 32064.
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    layer_pattern=(GLOBAL,),
    window=4096,
    long_context="swa",
    citation="arXiv:2404.14219",
))
