"""SmolLM-135M — small llama-architecture dense model.

[hf:HuggingFaceTB/SmolLM-135M]: 30 layers, d_model 576, 9 heads / 3 KV
heads, d_ff 1536, vocab 49152.
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    layer_pattern=(GLOBAL,),
    window=4096,
    long_context="swa",
    citation="hf:HuggingFaceTB/SmolLM-135M",
))
