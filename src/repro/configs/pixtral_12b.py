"""Pixtral 12B — VLM: Pixtral-ViT frontend (stub) + Mistral-Nemo decoder.

[hf:mistralai/Pixtral-12B-2409]: decoder 40 layers, d_model 5120,
32 heads / 8 KV heads, d_ff 14336, vocab 131072.  The vision encoder +
projector is the modality-frontend STUB: ``input_specs`` provides
precomputed patch embeddings of shape (B, n_patches, d_model).
"""
from repro.configs.base import GLOBAL, ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    layer_pattern=(GLOBAL,),
    n_patches=1024,                 # stub ViT patches prepended to text
    rope_theta=1_000_000.0,
    window=4096,
    long_context="swa",             # full-attn dense: long_500k runs the
                                    # sliding-window variant (DESIGN.md §3)
    citation="hf:mistralai/Pixtral-12B-2409",
))
