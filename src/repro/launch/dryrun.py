import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture × input shape) combination on the
production meshes — 16x16 single-pod and 2x16x16 multi-pod — with 512
placeholder host devices (the two lines above MUST precede any other
import; jax pins the device count at first init).

For each combination, records:
  * ``compiled.memory_analysis()``  (per-device bytes — proves fit)
  * ``compiled.cost_analysis()``    (raw HLO flops/bytes; scan caveat)
  * collective op counts/bytes parsed from the post-SPMD HLO
    (``repro.costmodel.hlo_analysis``) with while-loop multipliers
  * analytic FLOPs / 6ND model FLOPs (``repro.costmodel.flops``)
  * the three roofline terms (``repro.costmodel.roofline``)

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--strategy allreduce]
Results land in ``results/dryrun/<arch>__<shape>__<mesh>[__tag].json``.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import INPUT_SHAPES, ModelConfig, get_config
from repro.core import build_serve_step, build_train_step, get_strategy
from repro.core import sharding as shardlib
from repro.costmodel import flops as flopslib
from repro.costmodel.hlo_analysis import analyze_collectives
from repro.costmodel.roofline import roofline
from repro.launch.mesh import data_axes_of, make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# archs whose fp32 optimizer state cannot fit 16GB HBM without ZeRO
# (params·2B + m,v·8B sharded over the 16-way model axis alone exceeds HBM)
FSDP_REQUIRED = {"mixtral-8x22b", "mixtral-8x7b", "pixtral-12b"}

TRANSFORMER_ARCHS = [
    "mixtral-8x22b", "gemma3-4b", "mixtral-8x7b", "rwkv6-7b", "pixtral-12b",
    "smollm-135m", "whisper-small", "phi3-mini-3.8b", "recurrentgemma-2b",
    "qwen1.5-4b",
]


def _extras_sds(cfg, batch, mesh, dp):
    out = {}
    shard = NamedSharding(mesh, P(dp if batch > 1 else None))
    if cfg.family == "vlm":
        out["patch_emb"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=shard)
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=shard)
    return out


def input_specs(cfg: ModelConfig, shape_name: str, mesh, data_axes=None):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    shp = INPUT_SHAPES[shape_name]
    dp = data_axes or data_axes_of(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    tok_shard = NamedSharding(mesh, P(dp))
    B, S = shp.global_batch, shp.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                            sharding=tok_shard)}
    if shp.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                               sharding=tok_shard)
    batch.update(_extras_sds(cfg, B, mesh, dp))
    return batch


def _mem_dict(ma):
    return {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
        "peak_estimate_gb": (ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes) / 2**30,
    }


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               strategy: str = "allreduce", fsdp=None,
               profile: str = "baseline", tag: str = "",
               save: bool = True, fsdp_rs_dtype="float32",
               remat: bool = True, kv_quant: bool = False) -> dict:
    """``profile`` selects the sharding scheme (hillclimb material):
      baseline  16-way TP (model axis) × data-parallel strategies
      dp        pure data parallelism over every mesh axis, no TP
      zero3     pure DP + parameters/optimizer sharded over all axes
    """
    from repro.models import build_model

    cfg = get_config(arch)
    shp = INPUT_SHAPES[shape_name]
    if shp.name == "long_500k" and cfg.long_context == "skip":
        res = {"arch": arch, "shape": shape_name, "skipped":
               "long_500k skipped for this arch (DESIGN.md §3)"}
        if save:
            _save(res, arch, shape_name, multi_pod, tag)
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    data_axes = data_axes_of(mesh)
    model_axis = "model"
    if profile in ("dp", "zero3"):
        data_axes = tuple(mesh.axis_names)
        model_axis = None
        fsdp = profile == "zero3"
    if fsdp is None:
        fsdp = arch in FSDP_REQUIRED
    swa_variant = (shp.name == "long_500k" and cfg.long_context == "swa")

    model = build_model(cfg, remat=remat, kv_quant=kv_quant)
    t0 = time.time()
    if shp.kind == "train":
        ts = build_train_step(model, optim.adamw(3e-4),
                              get_strategy(strategy), mesh,
                              data_axes=data_axes, fsdp=fsdp,
                              model_axis=model_axis,
                              fsdp_rs_dtype=jnp.dtype(fsdp_rs_dtype))
        args = (ts.state_sds(), input_specs(cfg, shape_name, mesh,
                                            data_axes))
        lowered = ts.step_fn.lower(*args)
    else:
        ss = build_serve_step(model, mesh, data_axes=data_axes,
                              batch_size=shp.global_batch,
                              cache_len=shp.seq_len,
                              swa_variant=swa_variant)
        params_sds = jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                               sharding=sh),
            jax.eval_shape(model.init, jax.random.PRNGKey(0)),
            ss.param_shardings)
        if shp.kind == "prefill":
            batch = input_specs(cfg, shape_name, mesh)
            lowered = ss.prefill_fn.lower(params_sds, batch)
        else:
            token, cache_sds, pos = ss.make_inputs("decode", shp.seq_len)
            lowered = ss.decode_fn.lower(params_sds, token, cache_sds, pos)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    coll = analyze_collectives(hlo)

    # ---- analytic roofline terms ----
    if shp.kind == "train":
        flops_g = flopslib.train_step_flops(cfg, shp.global_batch,
                                            shp.seq_len)
        tokens = shp.global_batch * shp.seq_len
    elif shp.kind == "prefill":
        flops_g = flopslib.forward_flops(cfg, shp.global_batch, shp.seq_len,
                                         "prefill")
        tokens = shp.global_batch * shp.seq_len
    else:
        flops_g = flopslib.forward_flops(cfg, shp.global_batch, shp.seq_len,
                                         "decode")
        tokens = shp.global_batch
    # 6ND for train (fwd+bwd), 2ND for forward-only (prefill/decode)
    nd = flopslib.active_param_count(cfg) * tokens
    model_flops = 6.0 * nd if shp.kind == "train" else 2.0 * nd
    hbm_per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + 2 * ma.temp_size_in_bytes)
    rf = roofline(flops_g, hbm_per_dev, coll.wire_bytes, chips, model_flops)

    if profile != "baseline" and not tag:
        tag = profile
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "strategy": strategy if shp.kind == "train" else None,
        "fsdp": fsdp, "swa_variant": swa_variant, "profile": profile,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_dict(ma),
        "cost_analysis_raw": {k: ca.get(k) for k in
                              ("flops", "bytes accessed") if k in ca},
        "collectives": {
            "counts": coll.counts,
            "bytes_by_kind": coll.bytes_by_kind,
            "total_bytes_per_device": coll.total_bytes,
            "wire_bytes_per_device": coll.wire_bytes,
            "unresolved_loops": coll.unresolved_loops,
        },
        "analytic": {
            "flops_global": flops_g,
            "model_flops_6nd": model_flops,
            "params": flopslib.param_count(cfg),
            "active_params": flopslib.active_param_count(cfg),
        },
        "roofline": rf.as_dict(),
    }
    if save:
        _save(res, arch, shape_name, multi_pod, tag)
    return res


def _save(res, arch, shape_name, multi_pod, tag):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}"
    if tag:
        name += f"__{tag}"
    with open(RESULTS_DIR / f"{name}.json", "w") as f:
        json.dump(res, f, indent=2, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="allreduce")
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = TRANSFORMER_ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = dryrun_one(arch, shape, multi_pod=mp,
                                   strategy=args.strategy, fsdp=args.fsdp,
                                   tag=args.tag)
                    if "skipped" in r:
                        print(f"[skip] {label}: {r['skipped']}")
                        continue
                    rf = r["roofline"]
                    print(f"[ok]   {label}: compile {r['compile_s']}s "
                          f"mem {r['memory']['peak_estimate_gb']:.2f}GB "
                          f"dominant={rf['dominant']} "
                          f"t*={rf['step_time_lower_bound_s']:.4f}s")
                except Exception as e:
                    failures.append((label, repr(e)))
                    print(f"[FAIL] {label}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nAll dry-runs compiled.")


if __name__ == "__main__":
    main()
