"""Shared bootstrap for multi-device subprocess drivers.

``--xla_force_host_platform_device_count`` must be set in the
environment *before* jax initializes, so every driver that simulates a
multi-worker fleet on host devices (``byzantine_train``,
``resilient_train``) runs as ``python -m repro.launch.<driver>`` in a
child process.  This module is the one place that knows how to build
that child's environment and read its answer back.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Any, Dict, Sequence


def src_root() -> str:
    """The ``src/`` directory providing the ``repro`` package."""
    import repro
    # repro is a namespace package (__file__ is None): resolve src/ from
    # its search path
    return os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


def child_env(devices: int) -> Dict[str, str]:
    """A copy of the environment forcing ``devices`` host devices and
    putting this repo's ``src/`` first on the child's PYTHONPATH."""
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = (src_root() + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return env


def run_module(module: str, argv: Sequence[str], *, devices: int,
               timeout: float = 1800.0) -> str:
    """Run ``python -m <module> <argv>`` with ``devices`` forced host
    devices; return its stdout, raising ``RuntimeError`` (with the
    stderr tail) on a non-zero exit."""
    out = subprocess.run(
        [sys.executable, "-m", module, *argv],
        capture_output=True, text=True, timeout=timeout,
        env=child_env(devices))
    if out.returncode != 0:
        raise RuntimeError(
            f"{module} exited {out.returncode}: {out.stderr[-3000:]}")
    return out.stdout


def parse_result_line(stdout: str,
                      numeric_except: Sequence[str] = ()) -> Dict[str, Any]:
    """Parse the last ``RESULT,k=v,...`` line of a driver's stdout.

    Values are floated except the keys in ``numeric_except`` (kept as
    strings).  Raises ``RuntimeError`` when no RESULT line was printed
    — the driver died after jax init but before reporting."""
    lines = [l for l in stdout.splitlines() if l.startswith("RESULT,")]
    if not lines:
        raise RuntimeError(
            f"no RESULT line in driver output: {stdout[-2000:]!r}")
    fields = dict(kv.split("=", 1) for kv in lines[-1].split(",")[1:])
    return {k: (v if k in numeric_except else float(v))
            for k, v in fields.items()}


def read_json_out(path: str) -> Any:
    """Load a driver's ``--json-out`` payload."""
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)
