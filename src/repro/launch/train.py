"""Training entrypoint.

Examples:
  # tiny LM on CPU with the SPIRT strategy
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --strategy spirt --steps 50

  # the paper's CNN x strategy matrix
  PYTHONPATH=src python -m repro.launch.train --arch mobilenet-cifar \
      --reduced --strategy mlless --steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import restore, save
from repro.configs.base import get_config
from repro.core import build_train_step, get_strategy, losses
from repro.data import cifar_like, lm_batches, token_stream
from repro.models import build_cnn, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--strategy", default="allreduce",
                    choices=["allreduce", "scatterreduce",
                             "parameter_server", "spirt", "mlless"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--mesh", default="1x1",
                    help="data x model, e.g. 2x2 (needs host devices)")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--fused-optimizer", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dims = tuple(int(x) for x in args.mesh.split("x"))
    if jax.default_backend() == "tpu":
        from repro.launch.distributed import initialize_distributed
        initialize_distributed()
    axes = ("data", "model") if len(dims) == 2 else \
        ("pod", "data", "model")
    mesh = jax.make_mesh(dims, axes)

    is_cnn = cfg.family == "cnn"
    if is_cnn:
        model = build_cnn(cfg)
        imgs, labels = cifar_like(args.batch * 64, seed=0)

        def loss_fn(params, b):
            logits, _ = model.apply(params, b)
            return losses.classification_loss(logits, b["labels"])

        def batches():
            rs = np.random.RandomState(0)
            while True:
                idx = rs.randint(0, len(imgs), args.batch)
                yield {"images": jnp.asarray(imgs[idx]),
                       "labels": jnp.asarray(labels[idx])}
        loss = loss_fn
    else:
        model = build_model(cfg)
        stream = token_stream(args.batch * args.seq * 64, cfg.vocab_size)
        it = lm_batches(stream, args.batch, args.seq)

        def batches():
            for b in it:
                yield jax.tree.map(jnp.asarray, b)
        loss = None

    opt = optim.adamw(args.lr, use_fused=args.fused_optimizer) \
        if not is_cnn else optim.sgd(args.lr, momentum=0.9)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    ts = build_train_step(model, opt, get_strategy(args.strategy), mesh,
                          data_axes=data_axes, fsdp=args.fsdp,
                          loss_fn=loss)
    state = ts.init_state(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} strategy={args.strategy} params={n_params:,} "
          f"mesh={mesh.shape}")

    t0 = time.time()
    for step, batch in zip(range(args.steps), batches()):
        state, metrics = ts.step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            extra = "".join(
                f" {k}={float(v):.3f}" for k, v in metrics.items()
                if k not in ("loss", "step"))
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}"
                  f"{extra}  ({time.time() - t0:.1f}s)")
    if args.checkpoint:
        save(args.checkpoint, state["params"])
        print(f"saved params to {args.checkpoint}")


if __name__ == "__main__":
    main()
