"""Production mesh construction (function — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 v5e chips) or 2x16x16 two-pod (512) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires enough host devices)."""
    return jax.make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
