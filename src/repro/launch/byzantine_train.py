"""Real-training byzantine-robustness driver (subprocess entry point).

Trains the MobileNet CNN on the synthetic CIFAR set, 4-way
data-parallel, with a chosen byzantine worker set wrapped in
``ByzantineGradients`` under any registered attack model
(``repro.serverless.adversarial``: sign_flip / scale / gaussian_noise /
little_is_enough / zero) and any inner aggregation strategy —
including the robust family (``trimmed_mean``, ``coordinate_median``,
``krum``, ``geometric_median``).  This is the single harness behind
``benchmarks/fault_tolerance.py``, ``benchmarks/adversarial_curves.py``
(the real-JAX rows of the byzantine-fraction curves) and
``tests/test_robust_agg.py`` / ``tests/test_adversarial.py``.

It must run in its own process so ``--xla_force_host_platform_
device_count`` is set before jax initializes; use
:func:`run_in_subprocess` from the parent, or directly:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
    python -m repro.launch.byzantine_train --inner trimmed_mean \\
    --attack sign_flip --steps 150

Prints one machine-readable line:

  RESULT,inner=<name>,attack=<name>,steps=<n>,acc=<f>,final_loss=<f>,\\
max_loss=<f>,head_loss=<f>,tail_loss=<f>

The in-process :func:`run` additionally returns the full per-step loss
trace (``"losses"``), which is bit-identical across same-seed runs —
pinned by a regression test.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional, Tuple

#: robust aggregators constructible by name with their tuning kwarg
ROBUST_INNER = ("trimmed_mean", "coordinate_median", "krum",
                "geometric_median")


def run(inner: str = "trimmed_mean", *, attack: str = "scale",
        steps: int = 150, batch: int = 64, data_size: int = 4096,
        trim: int = 1, krum_f: int = 0, microbatches: int = 4,
        byz_scale: Optional[float] = None,
        byz_workers: Tuple[int, ...] = (0,), lr: float = 0.1,
        eval_size: int = 512, seed: int = 0) -> Dict[str, Any]:
    """One training run under an active byzantine worker set.

    ``byz_scale=None`` keeps PR 1's calibrated -8x magnitude for the
    ``scale`` attack and falls through to the attack model's own
    default for everything else.  ``krum_f=0`` because the 4-way
    harness only satisfies Krum's ``W >= 2f + 3`` at ``f = 0`` (the
    neighbourhood scoring still excludes the attacker).  The returned
    dict includes the full loss trace — a pure function of the
    arguments, so equal seeds replay bit-identically.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import optim
    from repro.configs.base import get_config
    from repro.core import build_train_step, get_strategy, losses
    from repro.data import cifar_like
    from repro.models import build_cnn

    cfg = get_config("mobilenet-cifar").reduced()
    imgs, labels = cifar_like(data_size, seed=0)
    timgs, tlabels = cifar_like(eval_size, seed=99)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    bsh = NamedSharding(mesh, P("data"))
    model = build_cnn(cfg)

    def loss_fn(params, b):
        logits, _ = model.apply(params, b)
        return losses.classification_loss(logits, b["labels"])

    if inner in ROBUST_INNER:
        kw = {"microbatches": microbatches}
        if inner == "trimmed_mean":
            kw["trim"] = trim
        elif inner == "krum":
            kw["f"] = krum_f
        inner_strat = get_strategy(inner, **kw)
    else:
        inner_strat = get_strategy(inner)
    if byz_scale is None and attack == "scale":
        byz_scale = -8.0               # PR 1's calibrated attack
    strat = get_strategy("byzantine", inner=inner_strat,
                         workers=tuple(byz_workers), attack=attack,
                         scale=byz_scale, seed=seed, n_workers=n_dev)
    ts = build_train_step(model, optim.sgd(lr, momentum=0.9), strat, mesh,
                          loss_fn=loss_fn)
    state = ts.init_state(jax.random.PRNGKey(seed))
    rs = np.random.RandomState(seed)
    seen = []
    for _ in range(steps):
        idx = rs.randint(0, len(imgs), batch)
        b = {"images": jax.device_put(jnp.asarray(imgs[idx]), bsh),
             "labels": jax.device_put(jnp.asarray(labels[idx]), bsh)}
        state, m = ts.step_fn(state, b)
        seen.append(float(m["loss"]))
    logits, _ = jax.jit(model.apply)(state["params"],
                                     {"images": jnp.asarray(timgs)})
    acc = float(losses.accuracy(logits, jnp.asarray(tlabels)))
    k = min(10, len(seen))
    return {"acc": acc, "final_loss": seen[-1], "max_loss": max(seen),
            "head_loss": float(np.mean(seen[:k])),
            "tail_loss": float(np.mean(seen[-k:])),
            "losses": tuple(seen)}


def run_in_subprocess(inner: str, *, steps: int, attack: str = "scale",
                      data_size: int = 4096, devices: int = 4,
                      seed: int = 0,
                      timeout: float = 1800.0) -> Dict[str, Any]:
    """Spawn this module with its own XLA device count; parse RESULT."""
    from repro.launch import _subprocess
    stdout = _subprocess.run_module(
        "repro.launch.byzantine_train",
        ["--inner", inner, "--attack", attack, "--steps", str(steps),
         "--data-size", str(data_size), "--seed", str(seed)],
        devices=devices, timeout=timeout)
    return _subprocess.parse_result_line(
        stdout, numeric_except=("inner", "attack"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", default="trimmed_mean")
    ap.add_argument("--attack", default="scale")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--data-size", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    r = run(args.inner, attack=args.attack, steps=args.steps,
            data_size=args.data_size, seed=args.seed)
    print(f"RESULT,inner={args.inner},attack={args.attack},"
          f"steps={args.steps},acc={r['acc']},"
          f"final_loss={r['final_loss']},max_loss={r['max_loss']},"
          f"head_loss={r['head_loss']},tail_loss={r['tail_loss']}")


if __name__ == "__main__":
    main()
