"""Real-training byzantine-robustness driver (subprocess entry point).

Trains the MobileNet CNN on the synthetic CIFAR set, 4-way
data-parallel, with worker 0 wrapped in ``ByzantineGradients`` (scaled
poisoned gradients) for the whole run, under a chosen inner aggregation
strategy.  This is the single harness behind both
``benchmarks/fault_tolerance.py`` (long run: does SPIRT + trimmed mean
converge under attack?) and ``tests/test_robust_agg.py`` (short run:
does plain averaging diverge while trimmed mean trains?).

It must run in its own process so ``--xla_force_host_platform_
device_count`` is set before jax initializes; use
:func:`run_in_subprocess` from the parent, or directly:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
    python -m repro.launch.byzantine_train --inner trimmed_mean --steps 150

Prints one machine-readable line:

  RESULT,inner=<name>,steps=<n>,acc=<f>,final_loss=<f>,max_loss=<f>,\\
head_loss=<f>,tail_loss=<f>
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import Dict


def run(inner: str = "trimmed_mean", *, steps: int = 150, batch: int = 64,
        data_size: int = 4096, trim: int = 1, microbatches: int = 4,
        byz_scale: float = -8.0, lr: float = 0.1,
        eval_size: int = 512) -> Dict[str, float]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import optim
    from repro.configs.base import get_config
    from repro.core import build_train_step, get_strategy, losses
    from repro.data import cifar_like
    from repro.models import build_cnn

    cfg = get_config("mobilenet-cifar").reduced()
    imgs, labels = cifar_like(data_size, seed=0)
    timgs, tlabels = cifar_like(eval_size, seed=99)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    bsh = NamedSharding(mesh, P("data"))
    model = build_cnn(cfg)

    def loss_fn(params, b):
        logits, _ = model.apply(params, b)
        return losses.classification_loss(logits, b["labels"])

    if inner in ("trimmed_mean", "coordinate_median"):
        kw = {"trim": trim} if inner == "trimmed_mean" else {}
        inner_strat = get_strategy(inner, microbatches=microbatches, **kw)
    else:
        inner_strat = get_strategy(inner)
    strat = get_strategy("byzantine", inner=inner_strat, workers=(0,),
                         scale=byz_scale)
    ts = build_train_step(model, optim.sgd(lr, momentum=0.9), strat, mesh,
                          loss_fn=loss_fn)
    state = ts.init_state(jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    seen = []
    for _ in range(steps):
        idx = rs.randint(0, len(imgs), batch)
        b = {"images": jax.device_put(jnp.asarray(imgs[idx]), bsh),
             "labels": jax.device_put(jnp.asarray(labels[idx]), bsh)}
        state, m = ts.step_fn(state, b)
        seen.append(float(m["loss"]))
    logits, _ = jax.jit(model.apply)(state["params"],
                                     {"images": jnp.asarray(timgs)})
    acc = float(losses.accuracy(logits, jnp.asarray(tlabels)))
    k = min(10, len(seen))
    return {"acc": acc, "final_loss": seen[-1], "max_loss": max(seen),
            "head_loss": float(np.mean(seen[:k])),
            "tail_loss": float(np.mean(seen[-k:]))}


def run_in_subprocess(inner: str, *, steps: int, data_size: int = 4096,
                      devices: int = 4,
                      timeout: float = 1800.0) -> Dict[str, float]:
    """Spawn this module with its own XLA device count; parse RESULT."""
    import repro
    # repro is a namespace package (__file__ is None): resolve src/ from
    # its search path
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.byzantine_train",
         "--inner", inner, "--steps", str(steps),
         "--data-size", str(data_size)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-3000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT,")][-1]
    fields = dict(kv.split("=", 1) for kv in line.split(",")[1:])
    return {k: (v if k == "inner" else float(v))
            for k, v in fields.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", default="trimmed_mean")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--data-size", type=int, default=4096)
    args = ap.parse_args()
    r = run(args.inner, steps=args.steps, data_size=args.data_size)
    print(f"RESULT,inner={args.inner},steps={args.steps},"
          f"acc={r['acc']},final_loss={r['final_loss']},"
          f"max_loss={r['max_loss']},head_loss={r['head_loss']},"
          f"tail_loss={r['tail_loss']}")


if __name__ == "__main__":
    main()
