"""Resilient-training driver (subprocess entry point).

Runs one chaos scenario — a sharded transformer config trained
data-parallel with a worker killed mid-step — three times in a single
process: the uninterrupted baseline, recovery by **checkpoint restore**
(roll back + replay) and recovery by **peer takeover** (survivors adopt
the dead peer's in-DB partition, no replay).  One process means one
XLA compile cache, so the three runs differ only in policy.

Must run in its own process so ``--xla_force_host_platform_
device_count`` is set before jax initializes; use
:func:`run_in_subprocess` from the parent, or directly:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \\
    python -m repro.launch.resilient_train --arch smollm-135m \\
    --steps 12 --kill-step 6 --json-out /tmp/resil.json

Prints one machine-readable line:

  RESULT,arch=<id>,sim_arch=<id>,kill_step=<n>,bitexact=<0|1>,\\
restore_wall_s=<f>,takeover_wall_s=<f>,restore_replayed=<n>,\\
takeover_loss_gap=<f>

and (with ``--json-out``) writes the full traces/recovery rows as JSON.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Dict, Optional


def run_experiment(*, arch: str = "smollm-135m", sim_arch: str = "spirt",
                   n_workers: int = 4, steps: int = 12,
                   global_batch: int = 12, seq: int = 16,
                   kill_step: int = 6, kill_worker: int = 1,
                   checkpoint_every: int = 4, lr: float = 1e-2,
                   fsdp: bool = True, restore_reinvoke: bool = True,
                   seed: int = 0,
                   modes: str = "baseline,restore,takeover"
                   ) -> Dict[str, Any]:
    """Baseline + restore + takeover for one kill scenario.

    Returns a JSON-ready dict; ``bitexact`` compares the restored run's
    full loss trace to the uninterrupted baseline (only meaningful with
    ``restore_reinvoke=True`` — see the harness docstring)."""
    from repro.resilience import (FaultSchedule, ResilienceConfig,
                                  ResilientTrainer)
    from repro.serverless.recovery import CheckpointRestore, PeerTakeover

    cfg = ResilienceConfig(
        arch=arch, sim_arch=sim_arch, n_workers=n_workers, steps=steps,
        global_batch=global_batch, seq=seq, lr=lr,
        checkpoint_every=checkpoint_every, fsdp=fsdp,
        restore_reinvoke=restore_reinvoke, seed=seed)
    trainer = ResilientTrainer(cfg)
    schedule = FaultSchedule.single(kill_step, kill_worker)
    want = tuple(m.strip() for m in modes.split(",") if m.strip())

    out: Dict[str, Any] = {
        "config": dataclasses.asdict(cfg),
        "kill": {"step": kill_step, "worker": kill_worker},
        "runs": {},
    }

    def pack(res):
        return {
            "losses": list(res.losses),
            "final_loss": res.final_loss,
            "n_params": res.n_params,
            "state_bytes": res.state_bytes,
            "step_s": res.step_s,
            "n_workers_end": res.n_workers_end,
            "replay_exact": res.replay_exact,
            "recoveries": [dataclasses.asdict(r)
                           for r in res.recoveries],
        }

    baseline = None
    if "baseline" in want:
        baseline = trainer.run()
        out["runs"]["baseline"] = pack(baseline)
    if "restore" in want:
        res = trainer.run(schedule, CheckpointRestore(
            checkpoint_every=checkpoint_every))
        row = pack(res)
        if baseline is not None:
            row["bitexact_vs_baseline"] = (
                res.losses == baseline.losses)
        out["runs"]["restore"] = row
    if "takeover" in want:
        res = trainer.run(schedule, PeerTakeover())
        row = pack(res)
        if baseline is not None:
            row["final_loss_gap"] = abs(
                res.final_loss - baseline.final_loss)
        out["runs"]["takeover"] = row
    return out


def run_in_subprocess(*, arch: str = "smollm-135m",
                      sim_arch: str = "spirt", steps: int = 12,
                      kill_step: int = 6, kill_worker: int = 1,
                      n_workers: int = 4, global_batch: int = 12,
                      seq: int = 16, checkpoint_every: int = 4,
                      restore_reinvoke: bool = True, seed: int = 0,
                      modes: str = "baseline,restore,takeover",
                      devices: Optional[int] = None,
                      timeout: float = 1800.0) -> Dict[str, Any]:
    """Spawn this module with its own XLA device count; return the
    ``--json-out`` payload."""
    import os
    import tempfile

    from repro.launch import _subprocess
    fd, path = tempfile.mkstemp(suffix=".json", prefix="resil_")
    os.close(fd)
    try:
        argv = ["--arch", arch, "--sim-arch", sim_arch,
                "--steps", str(steps), "--kill-step", str(kill_step),
                "--kill-worker", str(kill_worker),
                "--n-workers", str(n_workers),
                "--global-batch", str(global_batch),
                "--seq", str(seq),
                "--checkpoint-every", str(checkpoint_every),
                "--seed", str(seed), "--modes", modes,
                "--json-out", path]
        if not restore_reinvoke:
            argv.append("--no-reinvoke")
        _subprocess.run_module("repro.launch.resilient_train", argv,
                               devices=devices or n_workers,
                               timeout=timeout)
        return _subprocess.read_json_out(path)
    finally:
        os.unlink(path)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="chaos-test one sharded training scenario")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--sim-arch", default="spirt")
    ap.add_argument("--n-workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--global-batch", type=int, default=12)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--kill-step", type=int, default=6)
    ap.add_argument("--kill-worker", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--modes", default="baseline,restore,takeover")
    ap.add_argument("--no-reinvoke", action="store_true",
                    help="restore onto the shrunk survivor mesh instead "
                         "of re-invoking the dead worker")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    out = run_experiment(
        arch=args.arch, sim_arch=args.sim_arch,
        n_workers=args.n_workers, steps=args.steps,
        global_batch=args.global_batch, seq=args.seq,
        kill_step=args.kill_step, kill_worker=args.kill_worker,
        checkpoint_every=args.checkpoint_every, seed=args.seed,
        restore_reinvoke=not args.no_reinvoke, modes=args.modes)

    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(out, fh, indent=1, sort_keys=True)

    runs = out["runs"]
    rw = (runs.get("restore", {}).get("recoveries") or
          [{}])[0].get("wall_s", float("nan"))
    tw = (runs.get("takeover", {}).get("recoveries") or
          [{}])[0].get("wall_s", float("nan"))
    rr = (runs.get("restore", {}).get("recoveries") or
          [{}])[0].get("replayed_steps", 0)
    bx = runs.get("restore", {}).get("bitexact_vs_baseline", False)
    gap = runs.get("takeover", {}).get("final_loss_gap", float("nan"))
    print(f"RESULT,arch={args.arch},sim_arch={args.sim_arch},"
          f"kill_step={args.kill_step},bitexact={int(bool(bx))},"
          f"restore_wall_s={rw},takeover_wall_s={tw},"
          f"restore_replayed={rr},takeover_loss_gap={gap}")


if __name__ == "__main__":
    main()
