"""Multi-host / multi-pod process initialization.

On a real TPU pod slice every host runs the same program;
``jax.distributed.initialize`` wires them into one logical device mesh.
This module is the production entry hook — the CPU dry-run never calls
it (it fakes 512 devices in one process instead).

Environment contract (set by the launch scripts in ``scripts/``):
  REPRO_COORDINATOR   host:port of process 0 (default from TPU metadata)
  REPRO_NUM_PROCESSES total process count (default: auto)
  REPRO_PROCESS_ID    this process's index   (default: auto)
"""
from __future__ import annotations

import os

import jax


def initialize_distributed() -> None:
    """Idempotent jax.distributed bring-up from the env contract."""
    if getattr(initialize_distributed, "_done", False):
        return
    kw = {}
    if os.environ.get("REPRO_COORDINATOR"):
        kw["coordinator_address"] = os.environ["REPRO_COORDINATOR"]
    if os.environ.get("REPRO_NUM_PROCESSES"):
        kw["num_processes"] = int(os.environ["REPRO_NUM_PROCESSES"])
    if os.environ.get("REPRO_PROCESS_ID"):
        kw["process_id"] = int(os.environ["REPRO_PROCESS_ID"])
    # on TPU pods with no explicit env, jax autodetects via metadata
    jax.distributed.initialize(**kw)
    initialize_distributed._done = True


def assert_production_topology(multi_pod: bool) -> None:
    """Fail fast if the fleet does not match the assumed mesh."""
    want = 512 if multi_pod else 256
    have = jax.device_count()
    if have != want:
        raise RuntimeError(
            f"expected {want} chips for the "
            f"{'2x16x16' if multi_pod else '16x16'} mesh, found {have}; "
            "check the slice size / REPRO_* env")


def host_local_batch_slice(global_batch: int):
    """Index range of the global batch this host should feed.

    Data loading is host-sharded: each host materializes only its slice
    and ``jax.make_array_from_process_local_data`` assembles the global
    array (see launch/train.py for the single-host fallback path).
    """
    n = jax.process_count()
    i = jax.process_index()
    per = global_batch // n
    return i * per, (i + 1) * per
