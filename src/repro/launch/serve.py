"""Serving entrypoint: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --reduced --batch 4 --prompt-len 64 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import build_serve_step
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    model = build_model(cfg)
    cache_len = args.prompt_len + args.decode_tokens
    ss = build_serve_step(model, mesh, batch_size=args.batch,
                          cache_len=cache_len)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(jax.device_put, params, ss.param_shardings)

    rs = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rs.randint(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_emb"] = jnp.asarray(
            0.1 * rs.randn(args.batch, cfg.n_patches, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            0.1 * rs.randn(args.batch, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))

    t0 = time.time()
    logits, cache = ss.prefill_fn(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{time.time() - t0:.2f}s")

    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None] \
        .astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.decode_tokens):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = ss.decode_fn(params, tok, cache, pos)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None] \
            .astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decoded {args.decode_tokens} tokens in {dt:.2f}s "
          f"({args.decode_tokens * args.batch / dt:.1f} tok/s)")
    print("sample:", np.asarray(jnp.concatenate(out, axis=1))[0][:16])


if __name__ == "__main__":
    main()
