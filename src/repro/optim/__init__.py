from repro.optim.optimizers import Optimizer, adamw, apply_updates, sgd  # noqa: F401
