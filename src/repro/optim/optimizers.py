"""Optimizers (pytree-functional, optax-style but self-contained).

``adamw`` has a fused-Pallas path (``repro.kernels.fused_adamw``) — the
TPU analogue of SPIRT's in-database model update (state stays resident
next to compute; one fused pass over params instead of separate
m/v/param sweeps).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) -> (updates, state)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   params)}

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        if momentum == 0.0:
            ups = jax.tree.map(lambda g: (-lr * g).astype(g.dtype), grads)
            return ups, {"step": step}
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        ups = jax.tree.map(lambda m, g: (-lr * m).astype(g.dtype), mu, grads)
        return ups, {"step": step, "mu": mu}

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, use_fused: bool = False) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        if use_fused:
            from repro.kernels import ops as kops

            def upd(g, m, v, p):
                return kops.fused_adamw(g, m, v, p, lr=lr, b1=b1, b2=b2,
                                        eps=eps, wd=weight_decay,
                                        c1=c1, c2=c2)
            out = jax.tree.map(upd, grads, state["m"], state["v"], params)
            ups = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda o: isinstance(o, tuple))
            m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda o: isinstance(o, tuple))
            v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda o: isinstance(o, tuple))
            return ups, {"step": step, "m": m, "v": v}

        def moments(g, m, v):
            gf = g.astype(jnp.float32)
            return b1 * m + (1 - b1) * gf, b2 * v + (1 - b2) * gf * gf

        mv = jax.tree.map(moments, grads, state["m"], state["v"])
        m = jax.tree.map(lambda t: t[0], mv,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[1], mv,
                         is_leaf=lambda t: isinstance(t, tuple))

        def upd(m_, v_, p):
            u = -lr * ((m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
                       + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        ups = jax.tree.map(upd, m, v, params)
        return ups, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype),
                        params, updates)
