"""The built-in rules: each encodes a contract this repo already
relies on (golden snapshots, bit-reproducible BENCH sweeps, PR 7's
bit-exact replay) but until now only enforced *after* a violation ran.

Importing this module registers all six; ``repro.analysis.__init__``
does so eagerly, mirroring how ``repro.serverless.archs`` registers the
paper architectures at import.
"""
from __future__ import annotations

import ast
import math
from typing import Iterable, Optional

from repro.analysis.engine import (AnalysisContext, Finding,
                                   is_pure_literal)
from repro.analysis.registry import RuleSpec, register_rule

# ---------------------------------------------------------------------------
# seeded-rng — disjoint seeded streams or nothing
# ---------------------------------------------------------------------------
# directories whose results feed golden snapshots / BENCH payloads:
# every random draw must be replayable from (config, seed)
_STRICT_RNG_DIRS = frozenset({"serverless", "serving", "resilience",
                              "data"})
_RNG_CTORS = frozenset({"numpy.random.RandomState",
                        "numpy.random.default_rng", "random.Random"})


def _seed_arg(call: ast.Call):
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "seed":
            return kw.value
    return None


def check_seeded_rng(ctx: AnalysisContext) -> Iterable[Finding]:
    for mod in ctx.modules.values():
        strict = any(p in _STRICT_RNG_DIRS for p in mod.parts[:-1])
        for call, qual in mod.walk_calls():
            if not qual:
                continue
            at_module_level = mod.enclosing_function(call) is None
            tail = qual.rsplit(".", 1)[-1]
            # global-stream draws: np.random.rand / random.random / …
            is_np_global = (qual.startswith("numpy.random.")
                            and qual.count(".") == 2
                            and tail[:1].islower()
                            and tail != "default_rng")
            is_std_global = (qual.startswith("random.")
                             and qual.count(".") == 1
                             and tail[:1].islower())
            if (is_np_global or is_std_global) and (strict
                                                    or at_module_level):
                where = "at module level" if at_module_level else \
                    "in a determinism-critical package"
                yield Finding(
                    mod.rel, call.lineno, "seeded-rng",
                    f"{qual} draws from the process-global RNG stream "
                    f"{where}; draw from a Generator seeded through "
                    "SeedSequence sub-streams instead")
                continue
            if qual in _RNG_CTORS:
                seed = _seed_arg(call)
                if seed is None or (isinstance(seed, ast.Constant)
                                    and seed.value is None):
                    yield Finding(
                        mod.rel, call.lineno, "seeded-rng",
                        f"{qual}() without a seed is entropy from the "
                        "OS; every stream must be replayable from "
                        "(config, seed)")
                elif strict and is_pure_literal(seed):
                    yield Finding(
                        mod.rel, call.lineno, "seeded-rng",
                        f"{qual} with a hard-coded seed in a "
                        "determinism-critical package; seeds must flow "
                        "from function arguments or SeedSequence "
                        "sub-streams so replicates stay disjoint")


register_rule(RuleSpec(
    rule_id="seeded-rng",
    description="no global/unseeded RNG streams; seeds flow from "
                "arguments or SeedSequence sub-streams",
    contract="sweep_events / FaultPlan / Workload results are pure "
             "functions of (config, seed) with disjoint per-class "
             "sub-streams (PR 3); a global or unseeded draw silently "
             "couples replicates",
    check=check_seeded_rng))


# ---------------------------------------------------------------------------
# no-wallclock — simulated reports never absorb host time
# ---------------------------------------------------------------------------
_WALLCLOCK_QUALS = frozenset({
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
})
# measurement code lives here; everything else is simulation/reporting
_WALLCLOCK_OK_DIRS = ("launch", "benchmarks")


def check_no_wallclock(ctx: AnalysisContext) -> Iterable[Finding]:
    for mod in ctx.modules.values():
        if any(mod.in_dir(d) or mod.parts[0] == d
               for d in _WALLCLOCK_OK_DIRS):
            continue
        for call, qual in mod.walk_calls():
            if qual in _WALLCLOCK_QUALS:
                yield Finding(
                    mod.rel, call.lineno, "no-wallclock",
                    f"{qual}() outside launch/ and benchmarks/; "
                    "simulated timings must come from the cost model, "
                    "never the host clock")


register_rule(RuleSpec(
    rule_id="no-wallclock",
    description="wall-clock reads only in launch/ and benchmarks/",
    contract="BENCH_*.json payloads are content-hashed minus timings "
             "and golden snapshots are bit-exact; a host-clock read in "
             "a report-producing path makes both unreproducible",
    check=check_no_wallclock))


# ---------------------------------------------------------------------------
# frozen-spec-mutation — registry-resolved specs are immutable
# ---------------------------------------------------------------------------
_SPEC_GETTERS = frozenset({"get_arch", "get_attack"})
_SPEC_TYPES = frozenset({"ArchSpec", "AttackSpec"})


def _is_spec_getter(mod, node) -> bool:
    if not isinstance(node, ast.Call):
        return False
    qual = mod.resolve(node.func)
    return bool(qual) and qual.rsplit(".", 1)[-1] in _SPEC_GETTERS


def _scopes(mod):
    """(name, body, owner) per lexical scope; owner is the FunctionInfo
    (None = module level) so walks can stay disjoint per scope."""
    yield "<module>", mod.tree, None
    for fi in mod.functions:
        yield fi.name, fi.node, fi


def _scope_nodes(mod, body, owner):
    """Nodes lexically owned by this scope — nested function bodies
    belong to *their* scope, keeping every node single-checked."""
    for node in ast.walk(body):
        if mod.enclosing_function(node) is owner:
            yield node


def _tainted_names(mod, body_node, owner):
    """Names bound to registry-resolved specs within one scope."""
    names = set()
    if owner is not None:
        args = body_node.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            ann = a.annotation
            if ann is not None:
                q = mod.resolve(ann) or ""
                if q.rsplit(".", 1)[-1] in _SPEC_TYPES:
                    names.add(a.arg)
    for node in _scope_nodes(mod, body_node, owner):
        if isinstance(node, ast.Assign) and _is_spec_getter(mod,
                                                            node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and _is_spec_getter(mod, node.value):
            if isinstance(node.target, ast.Name):
                names.add(node.target.id)
    return names


def check_frozen_spec_mutation(ctx: AnalysisContext) -> Iterable[Finding]:
    for mod in ctx.modules.values():
        if mod.parts[0] == "tests":
            # tests legitimately build modified spec COPIES via
            # dataclasses.replace to exercise registration paths
            continue
        for call, qual in mod.walk_calls():
            if qual == "object.__setattr__":
                encl = mod.enclosing_function(call)
                if encl is None or encl.basename != "__post_init__":
                    yield Finding(
                        mod.rel, call.lineno, "frozen-spec-mutation",
                        "object.__setattr__ outside __post_init__ "
                        "defeats dataclass freezing; build a new object "
                        "instead")
        for scope_name, node, owner in _scopes(mod):
            tainted = _tainted_names(mod, node, owner)
            for sub in _scope_nodes(mod, node, owner):
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    targets = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    for t in targets:
                        if isinstance(t, ast.Attribute) and (
                                (isinstance(t.value, ast.Name)
                                 and t.value.id in tainted)
                                or _is_spec_getter(mod, t.value)):
                            yield Finding(
                                mod.rel, sub.lineno,
                                "frozen-spec-mutation",
                                "attribute assignment on a registry-"
                                "resolved spec; specs are frozen — "
                                "register a new spec instead")
                elif isinstance(sub, ast.Call):
                    q = mod.resolve(sub.func) or ""
                    if q in ("dataclasses.replace", "replace") \
                            and sub.args:
                        a0 = sub.args[0]
                        if ((isinstance(a0, ast.Name)
                             and a0.id in tainted)
                                or _is_spec_getter(mod, a0)):
                            yield Finding(
                                mod.rel, sub.lineno,
                                "frozen-spec-mutation",
                                "dataclasses.replace on a registry-"
                                "resolved spec inside src/; derived "
                                "variants must be registered under "
                                "their own name, not shadow a paper "
                                "spec")


register_rule(RuleSpec(
    rule_id="frozen-spec-mutation",
    description="registry-resolved ArchSpec/AttackSpec values are "
                "never mutated or replace()d in src/",
    contract="tests/golden/ pins the five paper archs bit-exactly and "
             "PR 4's extension rule says new behaviour registers a new "
             "spec; mutating a resolved spec changes every downstream "
             "consumer silently",
    check=check_frozen_spec_mutation))


# ---------------------------------------------------------------------------
# trace-safety — no host syncs on jit/shard_map paths
# ---------------------------------------------------------------------------
_NP_MATERIALIZE = frozenset({"numpy.asarray", "numpy.array", "numpy.copy",
                             "numpy.ascontiguousarray"})
_PY_CASTS = frozenset({"float", "int", "bool"})
_TRACED_TEST_METHODS = frozenset({"any", "all", "item"})


def _own_nodes(mod, fi):
    """Nodes belonging to ``fi`` itself (nested defs excluded — they
    are their own graph nodes)."""
    for node in ast.walk(fi.node):
        if mod.enclosing_function(node) is fi:
            yield node


def _contains_jax_call(mod, node) -> bool:
    """A subtree that *calls into jax* yields a fresh traced array —
    casting or branching on it is unambiguously a host sync.  Bare
    names/attributes are skipped: ``int(cfg.factor * k * T / E)`` on
    static shapes is normal jit code."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            q = mod.resolve(sub.func) or ""
            if q.startswith("jax."):
                return True
    return False


def _branches_on_traced(mod, test) -> bool:
    for node in ast.walk(test):
        if isinstance(node, ast.Call):
            q = mod.resolve(node.func) or ""
            if q.startswith("jax.numpy.") or q.startswith("jax.lax."):
                return True
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _TRACED_TEST_METHODS
                    and not q.startswith("numpy.")):
                return True
    return False


def check_trace_safety(ctx: AnalysisContext) -> Iterable[Finding]:
    cg = ctx.callgraph
    for rel, fi, root in cg.reachable_functions():
        mod = ctx.modules[rel]
        via = f"(reachable from jitted entry {root[1]!r} in {root[0]})"
        for node in _own_nodes(mod, fi):
            if isinstance(node, ast.Call):
                qual = mod.resolve(node.func)
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item" and not node.args):
                    yield Finding(
                        mod.rel, node.lineno, "trace-safety",
                        f".item() forces a host sync {via}")
                elif qual in _NP_MATERIALIZE:
                    yield Finding(
                        mod.rel, node.lineno, "trace-safety",
                        f"{qual} materialises a traced value on host "
                        f"{via}; use jnp instead")
                elif (qual in _PY_CASTS and len(node.args) == 1
                      and not node.keywords
                      and _contains_jax_call(mod, node.args[0])):
                    yield Finding(
                        mod.rel, node.lineno, "trace-safety",
                        f"{qual}() on a runtime value is a host sync "
                        f"under tracing {via}")
            elif isinstance(node, (ast.If, ast.While)) \
                    and _branches_on_traced(mod, node.test):
                yield Finding(
                    mod.rel, node.lineno, "trace-safety",
                    f"Python branch on a traced array {via}; use "
                    "jnp.where / lax.cond")


register_rule(RuleSpec(
    rule_id="trace-safety",
    description="no host syncs, numpy materialisation, or Python "
                "branches on traced values in functions reachable "
                "from jit/shard_map entry points",
    contract="train/serve/kernel step functions stay jittable and "
             "donate-safe; a host sync inside the traced region either "
             "crashes at trace time or silently bakes one traced value "
             "into every future call",
    check=check_trace_safety))


# ---------------------------------------------------------------------------
# kernel-ref-parity — every public kernel has an oracle and a test
# ---------------------------------------------------------------------------
def _twin(name: str, ref_names) -> Optional[str]:
    if name in ref_names:
        return name
    for r in sorted(ref_names):
        if name.startswith(r + "_") or r.startswith(name + "_"):
            return r
    return None


def _referenced_names(ctx, test_mod, dir_prefix: str, only_ref: bool):
    """Names in ``test_mod`` that resolve into kernels modules under
    ``dir_prefix`` (into ref.py when ``only_ref``)."""
    out = set()
    cg = ctx.callgraph
    for node in ast.walk(test_mod.tree):
        if not isinstance(node, (ast.Attribute, ast.Name)):
            continue
        dotted = test_mod.resolve(node)
        if not dotted or "." not in dotted:
            continue
        mod_path, name = dotted.rsplit(".", 1)
        rel = cg._by_dotted.get(mod_path)
        if rel is None or not rel.startswith(dir_prefix):
            continue
        is_ref = rel.rsplit("/", 1)[-1] == "ref.py"
        if is_ref == only_ref:
            out.add((rel, name))
    return out


def check_kernel_ref_parity(ctx: AnalysisContext) -> Iterable[Finding]:
    cg = ctx.callgraph
    # group kernels modules by their kernels/ directory
    groups = {}
    for rel, mod in ctx.modules.items():
        if "kernels" in mod.parts[:-1]:
            prefix = rel[:rel.index("kernels") + len("kernels")] + "/"
            groups.setdefault(prefix, []).append(mod)
    test_mods = ctx.test_modules()
    for prefix, mods in sorted(groups.items()):
        ref_mod = next((m for m in mods if m.basename == "ref.py"), None)
        kernel_mods = [m for m in mods
                       if m.basename not in ("ref.py", "__init__.py")]
        public = []
        for m in kernel_mods:
            for fi in m.functions:
                if "." not in fi.name and not fi.name.startswith("_"):
                    public.append((m, fi))
        if ref_mod is None:
            for m, fi in public:
                yield Finding(
                    m.rel, fi.node.lineno, "kernel-ref-parity",
                    f"public kernel {fi.name!r} has no oracle: "
                    f"{prefix}ref.py does not exist")
            continue
        ref_names = {fi.name for fi in ref_mod.functions
                     if "." not in fi.name
                     and not fi.name.startswith("_")}
        # what each test module touches, computed once per group
        refs_per_test = [(t, _referenced_names(ctx, t, prefix, False),
                          _referenced_names(ctx, t, prefix, True))
                         for t in test_mods]
        for m, fi in public:
            twin = _twin(fi.name, ref_names)
            if twin is None:
                yield Finding(
                    m.rel, fi.node.lineno, "kernel-ref-parity",
                    f"public kernel {fi.name!r} has no reference twin "
                    f"in {prefix}ref.py (pure-jnp oracle required for "
                    "parity testing)")
                continue
            if not test_mods:
                continue            # src-only run: no tests scanned
            key = (m.rel, fi.name)
            covered = False
            for t, kernel_refs, ref_refs in refs_per_test:
                if not any(name == twin for _, name in ref_refs):
                    continue
                for k_rel, k_name in kernel_refs:
                    k_key = (k_rel, k_name)
                    if k_key == key or key in cg.closure(k_key):
                        covered = True
                        break
                if covered:
                    break
            if not covered:
                yield Finding(
                    m.rel, fi.node.lineno, "kernel-ref-parity",
                    f"no parity test references both kernel "
                    f"{fi.name!r} and its oracle ref.{twin}")


register_rule(RuleSpec(
    rule_id="kernel-ref-parity",
    description="every public kernel in kernels/ has a pure-jnp twin "
                "in kernels/ref.py and a test referencing both",
    contract="Pallas kernels are only trusted through their oracles "
             "(kernels/ref.py + tests/test_kernels.py); an untwinned "
             "kernel is an unverifiable fast path",
    check=check_kernel_ref_parity))


# ---------------------------------------------------------------------------
# kernel-interpret-default — the interpreter is a validation escape
# hatch, never the production default
# ---------------------------------------------------------------------------
def _is_pallas_call(qual: Optional[str]) -> bool:
    return bool(qual) and qual.rsplit(".", 1)[-1] == "pallas_call"


def _calls_pallas(mod, fi) -> bool:
    for node in _own_nodes(mod, fi):
        if isinstance(node, ast.Call) and _is_pallas_call(
                mod.resolve(node.func)):
            return True
    return False


def _interpret_default(fi):
    """Default expression bound to an ``interpret`` parameter of ``fi``
    (None when the parameter is absent or required)."""
    args = fi.node.args
    pos = args.posonlyargs + args.args
    for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        if a.arg == "interpret":
            return d
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if a.arg == "interpret" and d is not None:
            return d
    return None


def check_kernel_interpret_default(
        ctx: AnalysisContext) -> Iterable[Finding]:
    cg = ctx.callgraph
    for rel, mod in sorted(ctx.modules.items()):
        if mod.parts[0] == "tests" or mod.basename.startswith("test_"):
            continue        # parity tests force the interpreter on CPU
        # (1) literal interpret=True at a pallas_call site in src/
        for call, qual in mod.walk_calls():
            if not _is_pallas_call(qual):
                continue
            for kw in call.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    yield Finding(
                        mod.rel, call.lineno, "kernel-interpret-default",
                        "pallas_call(interpret=True) hard-codes the "
                        "interpreter in a production call path; thread "
                        "an interpret= parameter resolved through the "
                        "ops backend auto-detect instead")
        # (2) public kernel entry points defaulting interpret=True
        if not mod.in_dir("kernels"):
            continue
        for fi in mod.functions:
            if "." in fi.name or fi.name.startswith("_"):
                continue
            d = _interpret_default(fi)
            if not (isinstance(d, ast.Constant) and d.value is True):
                continue
            key = (rel, fi.name)
            reaches_pallas = _calls_pallas(mod, fi) or any(
                k in cg._defs
                and _calls_pallas(ctx.modules[k[0]], cg._defs[k])
                for k in cg.closure(key))
            if reaches_pallas:
                yield Finding(
                    mod.rel, fi.node.lineno, "kernel-interpret-default",
                    f"public Pallas entry point {fi.name!r} defaults "
                    "interpret=True; default to None and resolve via "
                    "the ops backend auto-detect (the interpreter is a "
                    "validation escape hatch, not a production path)")


register_rule(RuleSpec(
    rule_id="kernel-interpret-default",
    description="no public Pallas entry point defaults or hard-codes "
                "interpret=True outside tests",
    contract="interpret= is the escape hatch: None auto-detects the "
             "backend (ops.default_interpret), True is the CPU "
             "validation mode parity tests opt into; a hard-coded True "
             "ships the ~40x-slower interpreter as the production path "
             "and masks Mosaic lowering breakage",
    check=check_kernel_interpret_default))


# ---------------------------------------------------------------------------
# staleness-spec — async ArchSpecs must declare a bounded staleness tax
# ---------------------------------------------------------------------------
def _literal_number(node):
    """Numeric value of a (possibly negated) literal, else None."""
    neg = False
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        neg, node = True, node.operand
    if (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and not isinstance(node.value, bool)):
        return -node.value if neg else node.value
    return None


_STALENESS_FIELDS = ("staleness_bound", "staleness_penalty")


def check_staleness_spec(ctx: AnalysisContext) -> Iterable[Finding]:
    for rel, mod in sorted(ctx.modules.items()):
        if mod.parts[0] == "tests" or mod.basename.startswith("test_"):
            continue        # tests probe the runtime validation itself
        for call, qual in mod.walk_calls():
            if not qual or qual.rsplit(".", 1)[-1] != "ArchSpec":
                continue
            kws = {kw.arg: kw.value for kw in call.keywords if kw.arg}
            bs = kws.get("barrier_sync")
            if not (isinstance(bs, ast.Constant) and bs.value is False):
                continue    # barrier-synchronous: no staleness model
            for field in _STALENESS_FIELDS:
                node = kws.get(field)
                if node is None:
                    yield Finding(
                        mod.rel, call.lineno, "staleness-spec",
                        f"barrier-free ArchSpec declares no {field}: "
                        "an async architecture without a bounded "
                        "staleness penalty simulates free asynchrony "
                        "(stragglers stop hurting but convergence "
                        "never pays)")
                    continue
                val = _literal_number(node)
                if val is None:
                    continue    # computed: __post_init__ decides at runtime
                if not (val > 0 and math.isfinite(val)):
                    yield Finding(
                        mod.rel, node.lineno, "staleness-spec",
                        f"barrier-free ArchSpec sets {field}={val!r}; "
                        "it must be a finite positive value — zero or "
                        "infinite staleness terms disable the "
                        "convergence tax entirely")


register_rule(RuleSpec(
    rule_id="staleness-spec",
    description="barrier-free (async) ArchSpecs declare a finite "
                "positive staleness_bound and staleness_penalty",
    contract="the async round-term model prices asynchrony: stragglers "
             "stop stalling the fleet ONLY because convergence pays "
             "(1 + penalty * min(staleness, bound)) extra work; a "
             "registration with barrier_sync=False and no bounded "
             "penalty would sweep as a free lunch and dominate every "
             "Pareto front for the wrong reason (archs.ArchSpec."
             "__post_init__ is the runtime twin of this check)",
    check=check_staleness_spec))
