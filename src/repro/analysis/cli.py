"""``python -m repro.analysis`` — the repo's own lint gate.

Exit codes are stable so CI can gate on them:

  0  clean (possibly with reasoned suppressions)
  1  at least one non-suppressed finding
  2  usage error (argparse)

``--plugin`` executes a Python file before the run; anything it
registers through :func:`repro.analysis.register_rule` participates
exactly like the built-ins (see ``examples/custom_rule.py``).
"""
from __future__ import annotations

import argparse
import runpy
import sys
from typing import Optional, Sequence

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: determinism & trace-safety static "
                    "analyzer enforcing this repo's correctness "
                    "contracts")
    p.add_argument("paths", nargs="*", default=None,
                   help="files/directories to scan (default: "
                        + " ".join(DEFAULT_PATHS) + ", where present)")
    p.add_argument("--root", default=".",
                   help="directory findings are reported relative to "
                        "(rule path scopes follow it; default: cwd)")
    p.add_argument("--format", choices=("human", "json"),
                   default="human", help="report format")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule ids to run "
                        "(default: all registered)")
    p.add_argument("--plugin", action="append", default=[],
                   metavar="FILE.py",
                   help="execute FILE before the run so third-party "
                        "rules can register_rule() themselves "
                        "(repeatable)")
    p.add_argument("--list-rules", action="store_true",
                   help="print registered rules and their contracts, "
                        "then exit 0")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    # import inside main so --plugin files resolve repro.analysis from
    # an already-initialised registry (built-ins registered first)
    from repro.analysis import engine, report

    args = build_parser().parse_args(argv)
    for plugin in args.plugin:
        runpy.run_path(plugin)
    if args.list_rules:
        print(report.render_rules())
        return 0
    import os
    paths = args.paths or [p for p in DEFAULT_PATHS
                           if os.path.isdir(os.path.join(args.root, p))]
    rules = None
    if args.rules is not None:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        result = engine.analyze_paths(paths, root=args.root, rules=rules)
    except ValueError as e:              # unknown rule id → usage error
        print(f"error: {e}", file=sys.stderr)
        return 2
    render = report.render_json if args.format == "json" \
        else report.render_human
    print(render(result))
    return result.exit_code


if __name__ == "__main__":
    sys.exit(main())
