"""Human and JSON reporters.

Both are pure functions of an :class:`AnalysisResult` — no timestamps,
no environment, no ordering dependence — so the same tree always
produces byte-identical reports (the property
``tests/test_analysis.py`` pins; it is the lint-level twin of the
BENCH content-hash rule).
"""
from __future__ import annotations

import json

from repro.analysis import registry
from repro.analysis.engine import AnalysisResult


def render_human(result: AnalysisResult) -> str:
    out = [f.render() for f in result.findings]
    n_paths = len({f.path for f in result.findings})
    out.append(
        f"{len(result.findings)} finding"
        f"{'' if len(result.findings) == 1 else 's'} in {n_paths} file"
        f"{'' if n_paths == 1 else 's'} "
        f"({result.n_files} scanned, {len(result.suppressed)} "
        f"suppressed)")
    return "\n".join(out)


def render_json(result: AnalysisResult) -> str:
    def row(f):
        return {"path": f.path, "line": f.line, "rule": f.rule_id,
                "message": f.message}

    payload = {
        "version": 1,
        "rules": [
            {"id": r, "description": registry.get_rule(r).description,
             "contract": registry.get_rule(r).contract}
            for r in result.rule_ids if r in registry.list_rules()
        ],
        "n_files": result.n_files,
        "findings": [row(f) for f in result.findings],
        "suppressed": [row(f) for f in result.suppressed],
    }
    return json.dumps(payload, indent=2, sort_keys=True)


def render_rules() -> str:
    """--list-rules: every registered rule with the contract it
    guards."""
    out = []
    for rid in registry.list_rules():
        spec = registry.get_rule(rid)
        out.append(f"{rid}\n    {spec.description}")
        if spec.contract:
            out.append(f"    contract: {spec.contract}")
    return "\n".join(out)
