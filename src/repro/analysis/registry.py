"""Frozen rule registry: one :class:`RuleSpec` per lint rule.

Mirrors ``repro.serverless.archs`` — the registry IS the extension
surface.  A third-party rule is one frozen spec registered through
:func:`register_rule` (see ``examples/custom_rule.py``); the CLI picks
it up via ``--plugin``, with the same actionable unknown-name /
duplicate-name errors as ``get_arch``/``register_arch``.

A rule's ``check`` receives the whole
:class:`~repro.analysis.engine.AnalysisContext` (every parsed module
plus the lazy call graph) and yields
:class:`~repro.analysis.engine.Finding`s — per-file rules iterate
``ctx.modules``; cross-file rules (``kernel-ref-parity``) correlate
across them.  Suppression filtering, ordering, and reporting are the
engine's job, so checks stay pure AST walks.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Tuple

_RULE_ID_RE = re.compile(r"^[a-z][a-z0-9]*(-[a-z0-9]+)*$")

# engine-owned pseudo-rule ids (never registered, never suppressible):
# a suppression without a reason, and a file that does not parse
BAD_SUPPRESSION = "bad-suppression"
SYNTAX_ERROR = "syntax-error"
_RESERVED = (BAD_SUPPRESSION, SYNTAX_ERROR)


@dataclasses.dataclass(frozen=True)
class RuleSpec:
    """Everything the engine needs to know about one lint rule.

    ``check(ctx)`` yields findings; ``contract`` names the repo
    invariant the rule machine-checks (it surfaces in ``--list-rules``
    and the JSON payload so a finding always points back at *why*).
    """
    rule_id: str
    description: str
    check: Callable
    contract: str = ""

    def __post_init__(self):
        if not _RULE_ID_RE.match(self.rule_id):
            raise ValueError(
                f"rule id {self.rule_id!r} must be kebab-case "
                "([a-z0-9] words joined by '-')")
        if self.rule_id in _RESERVED:
            raise ValueError(
                f"rule id {self.rule_id!r} is reserved by the engine")
        if not callable(self.check):
            raise ValueError(f"rule {self.rule_id!r}: check must be "
                             "callable")


_REGISTRY: Dict[str, RuleSpec] = {}


def register_rule(spec: RuleSpec, *, overwrite: bool = False) -> RuleSpec:
    """Add ``spec`` to the registry (returns it, so modules can keep a
    handle).  Re-registering an id is an error unless ``overwrite`` —
    a silently replaced rule is a silently weakened contract."""
    if not overwrite and spec.rule_id in _REGISTRY:
        raise ValueError(f"rule {spec.rule_id!r} is already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[spec.rule_id] = spec
    return spec


def unregister_rule(rule_id: str) -> None:
    """Remove a rule (tests / examples cleaning up after themselves)."""
    _REGISTRY.pop(rule_id, None)


def get_rule(rule_id: str) -> RuleSpec:
    try:
        return _REGISTRY[rule_id]
    except KeyError:
        raise ValueError(
            f"unknown rule {rule_id!r}; registered: "
            f"{', '.join(_REGISTRY)}") from None


def list_rules() -> Tuple[str, ...]:
    """All registered rule ids, in registration order (the repo's
    built-in contracts first)."""
    return tuple(_REGISTRY)
