"""repro-lint: a determinism & trace-safety static analyzer enforcing
the repo's own correctness contracts.

Every claim this reproduction makes — golden-snapshot parity for the
five paper archs, bit-reproducible ``BENCH_*.json`` sweeps, PR 7's
bit-exact checkpoint-restore replay — rests on invariants that were
only enforced by runtime tests, after a violation had already shipped.
This package machine-checks them on every tree:

  seeded-rng            disjoint seeded streams or nothing
  no-wallclock          host clock only in launch/ and benchmarks/
  frozen-spec-mutation  registry-resolved specs are immutable
  trace-safety          no host syncs on jit/shard_map paths
  kernel-ref-parity     every public kernel has an oracle + test

Usage::

    PYTHONPATH=src python -m repro.analysis src tests benchmarks examples

Per-line suppressions carry a mandatory reason::

    t0 = time.perf_counter()  # repro: allow[no-wallclock] -- measures real XLA walls

Third-party rules register through the same frozen-registry pattern as
``repro.serverless.archs`` (see ``examples/custom_rule.py``)::

    from repro.analysis import RuleSpec, register_rule
    register_rule(RuleSpec(rule_id="my-rule", description=..., check=fn))

The engine is stdlib-only; nothing here imports numpy or jax.
"""
from repro.analysis.engine import (AnalysisContext, AnalysisResult,
                                   Finding, FunctionInfo, ModuleInfo,
                                   analyze_modules, analyze_paths,
                                   analyze_sources)
from repro.analysis.registry import (RuleSpec, get_rule, list_rules,
                                     register_rule, unregister_rule)

# importing the built-in rules registers them (same eager-registration
# idiom as the paper archs in repro.serverless.archs)
from repro.analysis import rules as _rules          # noqa: F401

__all__ = [
    "AnalysisContext", "AnalysisResult", "Finding", "FunctionInfo",
    "ModuleInfo", "RuleSpec", "analyze_modules", "analyze_paths",
    "analyze_sources", "get_rule", "list_rules", "register_rule",
    "unregister_rule",
]
