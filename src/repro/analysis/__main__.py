import sys

from repro.analysis.cli import main

try:
    sys.exit(main())
except BrokenPipeError:      # report piped into head/less and truncated
    sys.exit(0)
