"""AST lint engine: parse → suppressions → rules → ordered findings.

The engine is deliberately boring and deliberately **pure**: findings
are a function of file contents alone.  No wall-clock, no RNG, no
filesystem state beyond the scanned sources, stable ordering — the
same bit-reproducibility contract the sweeps hold for ``BENCH_*.json``
applies to lint reports (``tests/test_analysis.py`` pins it with a
hypothesis property).  Everything is stdlib-only so the CI lint job
runs before any dependency install.

Suppression syntax (reason mandatory — an unexplained exemption is a
contract erosion nobody reviews)::

    something_flagged()  # repro: allow[rule-id] -- why this is safe
    other()              # repro: allow[rule-a, rule-b] -- shared reason

A suppression missing its reason (or its rule list) is itself reported
as ``bad-suppression`` and cannot be suppressed.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from functools import cached_property
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis import registry
from repro.analysis.registry import BAD_SUPPRESSION, SYNTAX_ERROR

# directories never walked into: caches, VCS state, and the
# deliberately-violating lint fixture corpus (scanned only when a test
# roots the engine *inside* it)
SKIP_DIRS = frozenset({"__pycache__", ".git", ".hypothesis",
                       "analysis_fixtures", ".pytest_cache"})

_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*allow\[([^\]]*)\]\s*(?:--\s*(.*\S))?\s*$")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source line (ordering = report order)."""
    path: str          # posix path relative to the analysis root
    line: int          # 1-based
    rule_id: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule_id}] {self.message}"


@dataclasses.dataclass(frozen=True)
class FunctionInfo:
    """One function/method definition inside a module."""
    name: str                     # dotted within the module: Cls.meth, f.g
    node: ast.AST                 # the FunctionDef / AsyncFunctionDef
    cls: Optional[str] = None     # immediately enclosing class name

    @property
    def basename(self) -> str:
        return self.name.rsplit(".", 1)[-1]


class ModuleInfo:
    """One parsed source file plus everything rules keep re-deriving:
    import resolution, function index, per-line suppressions."""

    def __init__(self, rel: str, source: str):
        self.rel = rel.replace(os.sep, "/")
        self.parts: Tuple[str, ...] = tuple(self.rel.split("/"))
        self.source = source
        self.lines = source.splitlines()
        self.suppressions: Dict[int, Tuple[str, ...]] = {}
        self.bad_suppressions: List[int] = []
        self.syntax_error: Optional[int] = None
        self.functions: Tuple[FunctionInfo, ...] = ()
        self.name_map: Dict[str, str] = {}
        self._enclosing: Dict[int, Optional[FunctionInfo]] = {}
        self._parse_suppressions()
        try:
            self.tree: Optional[ast.Module] = ast.parse(source)
        except SyntaxError as e:
            self.tree = None
            self.syntax_error = e.lineno or 1
            return
        self.name_map = _build_name_map(self.tree, self.dotted_package)
        self.functions = tuple(self._index_functions())

    # -- path helpers -----------------------------------------------------
    @property
    def basename(self) -> str:
        return self.parts[-1]

    @property
    def dotted_name(self) -> str:
        """Importable dotted module path (``src/`` is a sys.path root)."""
        parts = self.parts[1:] if self.parts[0] == "src" else self.parts
        parts = list(parts)
        parts[-1] = parts[-1][:-3] if parts[-1].endswith(".py") \
            else parts[-1]
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    @property
    def dotted_package(self) -> str:
        return self.dotted_name.rsplit(".", 1)[0] \
            if "." in self.dotted_name else ""

    def in_dir(self, name: str) -> bool:
        """True when ``name`` is one of this file's parent directories."""
        return name in self.parts[:-1]

    # -- suppressions -----------------------------------------------------
    def _parse_suppressions(self):
        for lineno, text in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(text)
            if m is None:
                # half-written suppression markers are themselves
                # findings (the marker split keeps this line from
                # matching its own heuristic)
                if ("repro:" + " allow") in text and "#" in text:
                    self.bad_suppressions.append(lineno)
                continue
            ids = tuple(s.strip() for s in m.group(1).split(",")
                        if s.strip())
            reason = (m.group(2) or "").strip()
            if not ids or not reason:
                self.bad_suppressions.append(lineno)
            else:
                self.suppressions[lineno] = ids

    def suppresses(self, finding: Finding) -> bool:
        ids = self.suppressions.get(finding.line, ())
        return finding.rule_id in ids or "*" in ids

    # -- AST indexes ------------------------------------------------------
    def _index_functions(self):
        funcs: List[FunctionInfo] = []

        def visit(node, qual, cls, cur):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{qual}.{child.name}" if qual else child.name
                    fi = FunctionInfo(q, child, cls)
                    funcs.append(fi)
                    self._enclosing[id(child)] = cur
                    visit(child, q, None, fi)
                elif isinstance(child, ast.ClassDef):
                    q = f"{qual}.{child.name}" if qual else child.name
                    self._enclosing[id(child)] = cur
                    visit(child, q, child.name, cur)
                else:
                    self._enclosing[id(child)] = cur
                    visit(child, qual, cls, cur)

        visit(self.tree, "", None, None)
        return funcs

    def enclosing_function(self, node) -> Optional[FunctionInfo]:
        """Innermost function containing ``node`` (None = module level)."""
        return self._enclosing.get(id(node))

    def resolve(self, node) -> Optional[str]:
        """Best-effort dotted qualname for a Name/Attribute chain, with
        imports resolved (``np.random.rand`` → ``numpy.random.rand``)."""
        if isinstance(node, ast.Name):
            return self.name_map.get(node.id, node.id)
        if isinstance(node, ast.Attribute):
            base = self.resolve(node.value)
            return f"{base}.{node.attr}" if base else None
        return None

    def walk_calls(self):
        """Every ``ast.Call`` with its resolved callee qualname."""
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                yield node, self.resolve(node.func)


def _build_name_map(tree: ast.Module, package: str) -> Dict[str, str]:
    """local name → dotted origin, merged over every import statement in
    the file (function-level lazy imports included — a lint heuristic,
    not a scope-exact resolver)."""
    nm: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    nm[a.asname] = a.name
                else:
                    root = a.name.split(".", 1)[0]
                    nm[root] = root
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:                       # relative import
                pkg_parts = package.split(".") if package else []
                up = node.level - 1
                pkg_parts = pkg_parts[:len(pkg_parts) - up] if up else \
                    pkg_parts
                base = ".".join(pkg_parts + ([base] if base else []))
            for a in node.names:
                if a.name == "*":
                    continue
                nm[a.asname or a.name] = f"{base}.{a.name}" if base \
                    else a.name
    return nm


def is_pure_literal(node) -> bool:
    """True when an expression contains no Name/Attribute/Call — i.e. a
    constant the author baked in rather than a value that flows."""
    return not any(isinstance(n, (ast.Name, ast.Attribute, ast.Call))
                   for n in ast.walk(node))


# ---------------------------------------------------------------------------
# Context + runner
# ---------------------------------------------------------------------------
class AnalysisContext:
    """Everything a rule can see: the parsed modules (sorted by path,
    so iteration order never depends on filesystem enumeration) and the
    lazily-built intra-repo call graph."""

    def __init__(self, modules: Dict[str, ModuleInfo]):
        self.modules: Dict[str, ModuleInfo] = dict(
            sorted(modules.items()))

    @cached_property
    def callgraph(self):
        from repro.analysis.callgraph import CallGraph
        return CallGraph(self.modules)

    def test_modules(self) -> List[ModuleInfo]:
        return [m for m in self.modules.values()
                if m.basename.startswith("test_")]


@dataclasses.dataclass(frozen=True)
class AnalysisResult:
    """One engine run: active findings, what was suppressed, coverage."""
    findings: Tuple[Finding, ...]
    suppressed: Tuple[Finding, ...]
    n_files: int
    rule_ids: Tuple[str, ...]

    @property
    def exit_code(self) -> int:
        return 1 if self.findings else 0


def iter_python_files(paths: Sequence[str], root: str) -> List[str]:
    """Resolve CLI path arguments to a sorted list of .py files.  A
    directory passed explicitly is walked even if its *name* is in
    SKIP_DIRS (that is how the fixture corpus gets scanned on purpose);
    nested skip-dirs are always pruned."""
    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            if full.endswith(".py"):
                out.append(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in SKIP_DIRS)
            for f in sorted(filenames):
                if f.endswith(".py"):
                    out.append(os.path.join(dirpath, f))
    return sorted(set(out))


def _resolve_rules(rules) -> List[registry.RuleSpec]:
    if rules is None:
        return [registry.get_rule(r) for r in registry.list_rules()]
    return [r if isinstance(r, registry.RuleSpec)
            else registry.get_rule(r) for r in rules]


def analyze_modules(modules: Dict[str, ModuleInfo],
                    rules=None) -> AnalysisResult:
    """Run ``rules`` (default: every registered rule) over already-
    parsed modules; the deterministic core shared by the file and
    in-memory entry points."""
    specs = _resolve_rules(rules)
    ctx = AnalysisContext(modules)
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for mod in ctx.modules.values():
        if mod.syntax_error is not None:
            active.append(Finding(mod.rel, mod.syntax_error, SYNTAX_ERROR,
                                  "file does not parse; nothing on it "
                                  "can be checked"))
        for line in mod.bad_suppressions:
            active.append(Finding(
                mod.rel, line, BAD_SUPPRESSION,
                "suppression needs a rule list and a reason: "
                "# repro: allow[rule-id] -- <why this is safe>"))
    checkable = {rel: m for rel, m in ctx.modules.items()
                 if m.tree is not None}
    ctx_checkable = AnalysisContext(checkable)
    for spec in specs:
        for f in spec.check(ctx_checkable):
            mod = ctx.modules.get(f.path)
            if mod is not None and mod.suppresses(f):
                suppressed.append(f)
            else:
                active.append(f)
    return AnalysisResult(findings=tuple(sorted(active)),
                          suppressed=tuple(sorted(suppressed)),
                          n_files=len(ctx.modules),
                          rule_ids=tuple(s.rule_id for s in specs))


def analyze_sources(sources: Dict[str, str], rules=None) -> AnalysisResult:
    """Analyze in-memory ``{relative/path.py: source}`` mappings —
    the pure-function entry point tests and examples drive."""
    return analyze_modules(
        {rel: ModuleInfo(rel, text) for rel, text in sources.items()},
        rules=rules)


def analyze_paths(paths: Sequence[str], root: str = ".",
                  rules=None) -> AnalysisResult:
    """Analyze files/directories on disk, reporting paths relative to
    ``root``."""
    root = os.path.abspath(root)
    modules = {}
    for f in iter_python_files(paths, root):
        rel = os.path.relpath(f, root).replace(os.sep, "/")
        with open(f, encoding="utf-8") as fh:
            modules[rel] = ModuleInfo(rel, fh.read())
    return analyze_modules(modules, rules=rules)
