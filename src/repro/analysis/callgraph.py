"""Lightweight intra-repo call graph rooted at jit/shard_map entry
points.

Purpose-built for the ``trace-safety`` and ``kernel-ref-parity``
rules, not a general points-to analysis.  Nodes are function
definitions keyed ``(module_rel, dotted_name_in_module)``; edges are
added for

  * direct calls — local names, imported names, ``module.attr`` chains
    resolved through each module's import map;
  * ``self.method()`` — preferring the enclosing class, falling back to
    duck dispatch;
  * duck dispatch — ``obj.method()`` on an unresolvable receiver links
    to every class method of that name in the scanned tree (a CHA-style
    over-approximation: for a *safety* rule, reaching too much beats
    reaching too little);
  * function references passed as arguments (``jax.lax.scan(step, …)``,
    ``defvjp(fwd, bwd)``, ``functools.partial(f, …)``) — how trace-side
    bodies usually enter jax.

Roots are functions passed to (or decorated with) ``jax.jit`` /
``pjit`` / any ``*.shard_map`` — the boundary past which host syncs,
``np.asarray`` materialisation, and Python branching on traced values
stop being slow and start being wrong.
"""
from __future__ import annotations

import ast
from collections import deque
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import FunctionInfo, ModuleInfo

Key = Tuple[str, str]                       # (module rel path, func name)

_JIT_QUALS = frozenset({"jax.jit", "jax.pjit",
                        "jax.experimental.pjit.pjit"})
_PARTIAL_QUALS = frozenset({"functools.partial", "partial"})

# duck dispatch gives up on method names defined in more places than
# this — linking e.g. every `.get` in the tree would drown the graph
_MAX_DUCK_TARGETS = 12


def _is_jit_qual(qual: Optional[str]) -> bool:
    if not qual:
        return False
    return qual in _JIT_QUALS or qual.rsplit(".", 1)[-1] == "shard_map"


class CallGraph:
    def __init__(self, modules: Dict[str, ModuleInfo]):
        self.modules = modules
        # indexes
        self._defs: Dict[Key, FunctionInfo] = {}
        self._by_module: Dict[str, Dict[str, Key]] = {}     # top-level fns
        self._by_dotted: Dict[str, str] = {}                # dotted -> rel
        self._methods: Dict[str, List[Key]] = {}            # duck index
        for rel, mod in modules.items():
            if mod.tree is None:
                continue
            self._by_dotted[mod.dotted_name] = rel
            local: Dict[str, Key] = {}
            for fi in mod.functions:
                key = (rel, fi.name)
                self._defs[key] = fi
                if "." not in fi.name:
                    local[fi.name] = key
                if fi.cls is not None:
                    self._methods.setdefault(fi.basename, []).append(key)
            self._by_module[rel] = local
        self.edges: Dict[Key, Set[Key]] = {}
        self.roots: Set[Key] = set()
        self._parent: Dict[Key, Key] = {}       # BFS provenance
        for rel, mod in modules.items():
            if mod.tree is not None:
                self._scan_module(rel, mod)
        self._reachable = self._bfs()

    # -- resolution --------------------------------------------------------
    def _module_func(self, dotted: str) -> Optional[Key]:
        """Resolve ``pkg.module.func`` (longest module prefix wins)."""
        if "." not in dotted:
            return None
        mod_path, func = dotted.rsplit(".", 1)
        rel = self._by_dotted.get(mod_path)
        if rel is None:
            return None
        return self._by_module.get(rel, {}).get(func)

    def _resolve_ref(self, mod: ModuleInfo, encl: Optional[FunctionInfo],
                     node) -> List[Key]:
        """Function-definition keys a Name/Attribute may refer to."""
        rel = mod.rel
        if isinstance(node, ast.Name):
            # nested def of the enclosing function chain
            if encl is not None:
                key = (rel, f"{encl.name}.{node.id}")
                if key in self._defs:
                    return [key]
            key = self._by_module.get(rel, {}).get(node.id)
            if key is not None:
                return [key]
            dotted = mod.name_map.get(node.id)
            if dotted:
                hit = self._module_func(dotted)
                if hit is not None:
                    return [hit]
            return []
        if isinstance(node, ast.Attribute):
            dotted = mod.resolve(node)
            if dotted:
                hit = self._module_func(dotted)
                if hit is not None:
                    return [hit]
                # ClassName.method in this module
                key = (rel, dotted)
                if key in self._defs:
                    return [key]
            # self.method() → own class first
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "self" and encl is not None
                    and encl.cls is not None):
                own = (rel, f"{encl.name.rsplit('.', 1)[0]}.{node.attr}")
                if own in self._defs:
                    return [own]
            ducks = self._methods.get(node.attr, [])
            if 0 < len(ducks) <= _MAX_DUCK_TARGETS:
                return list(ducks)
        return []

    # -- construction ------------------------------------------------------
    def _add_edge(self, src: Optional[Key], dst: Key):
        if src is None or src == dst:
            return
        self.edges.setdefault(src, set()).add(dst)

    def _add_root(self, keys: List[Key]):
        self.roots.update(keys)

    def _scan_module(self, rel: str, mod: ModuleInfo):
        # decorator roots: @jax.jit / @partial(jax.jit, …)
        for fi in mod.functions:
            for dec in fi.node.decorator_list:
                qual = mod.resolve(dec)
                target = dec.func if isinstance(dec, ast.Call) else None
                if target is not None:
                    tq = mod.resolve(target)
                    if tq in _PARTIAL_QUALS and dec.args:
                        qual = mod.resolve(dec.args[0])
                    elif _is_jit_qual(tq):
                        qual = tq
                if _is_jit_qual(qual):
                    self.roots.add((rel, fi.name))
        for call, qual in mod.walk_calls():
            encl = mod.enclosing_function(call)
            src = (rel, encl.name) if encl is not None else None
            # jit/shard_map call sites: first argument is an entry point
            if _is_jit_qual(qual) and call.args:
                self._add_root(self._targets(mod, encl, call.args[0]))
            # custom_vjp wiring: fn.defvjp(fwd, bwd) puts fwd/bwd on the
            # trace path of fn
            if (isinstance(call.func, ast.Attribute)
                    and call.func.attr == "defvjp"):
                owners = self._resolve_ref(mod, encl, call.func.value)
                for owner in owners:
                    for arg in call.args:
                        for t in self._resolve_ref(mod, encl, arg):
                            self._add_edge(owner, t)
            # direct call edge
            for t in self._resolve_ref(mod, encl, call.func):
                self._add_edge(src, t)
            # function references passed as arguments
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                if isinstance(arg, (ast.Name, ast.Attribute)):
                    for t in self._resolve_ref(mod, encl, arg):
                        self._add_edge(src, t)

    def _targets(self, mod, encl, node) -> List[Key]:
        """Entry-point targets of a jit/shard_map argument, looking
        through functools.partial."""
        if isinstance(node, ast.Call):
            q = mod.resolve(node.func)
            if q in _PARTIAL_QUALS and node.args:
                return self._targets(mod, encl, node.args[0])
            return []
        return self._resolve_ref(mod, encl, node)

    # -- reachability ------------------------------------------------------
    def _bfs(self) -> Set[Key]:
        seen = set(self.roots)
        q = deque(sorted(self.roots))
        while q:
            cur = q.popleft()
            for nxt in sorted(self.edges.get(cur, ())):
                if nxt not in seen:
                    seen.add(nxt)
                    self._parent[nxt] = cur
                    q.append(nxt)
        return seen

    def closure(self, start: Key) -> Set[Key]:
        """Everything callable from ``start`` (start included)."""
        seen = {start}
        q = deque([start])
        while q:
            for nxt in self.edges.get(q.popleft(), ()):
                if nxt not in seen:
                    seen.add(nxt)
                    q.append(nxt)
        return seen

    def is_reachable(self, key: Key) -> bool:
        return key in self._reachable

    def reachable_functions(self):
        """(rel, FunctionInfo, root_key) for every function on a trace
        path, in deterministic order."""
        for key in sorted(self._reachable):
            yield key[0], self._defs[key], self.root_of(key)

    def root_of(self, key: Key) -> Key:
        while key in self._parent:
            key = self._parent[key]
        return key
