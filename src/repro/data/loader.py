"""Sharding-aware loaders reproducing the paper's partitioning.

The paper's setup (§4.1/§4.3): 4 workers, batch 512 per worker-step,
24 minibatches per worker per epoch, global batch 2048.  ``WorkerShards``
pre-partitions an epoch into per-worker minibatch queues exactly as
SPIRT/MLLess schedule them; AllReduce/ScatterReduce workers act as
streaming dataloaders over an even split.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np


@dataclasses.dataclass
class WorkerShards:
    """Per-worker minibatch schedule for one epoch."""
    images: np.ndarray
    labels: np.ndarray
    n_workers: int
    batch_size: int

    def epoch(self, epoch_idx: int) -> List[List[Dict[str, np.ndarray]]]:
        n = len(self.images)
        rng = np.random.RandomState(1234 + epoch_idx)
        order = rng.permutation(n)
        per_worker = n // self.n_workers
        out = []
        for w in range(self.n_workers):
            sel = order[w * per_worker:(w + 1) * per_worker]
            batches = []
            for s in range(0, per_worker - self.batch_size + 1,
                           self.batch_size):
                idx = sel[s:s + self.batch_size]
                batches.append({"images": self.images[idx],
                                "labels": self.labels[idx]})
            out.append(batches)
        return out

    @property
    def batches_per_worker(self) -> int:
        return (len(self.images) // self.n_workers) // self.batch_size


def global_batch_iter(shards: WorkerShards, epoch_idx: int
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Zip per-worker queues into global steps (data-parallel view)."""
    per_worker = shards.epoch(epoch_idx)
    for step in range(shards.batches_per_worker):
        imgs = np.concatenate([per_worker[w][step]["images"]
                               for w in range(shards.n_workers)])
        labs = np.concatenate([per_worker[w][step]["labels"]
                               for w in range(shards.n_workers)])
        yield {"images": imgs, "labels": labs}
