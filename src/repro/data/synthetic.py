"""Deterministic synthetic datasets (the container is offline).

``cifar_like``: 10-class 32x32x3 image set whose classes are genuinely
learnable (class-conditional frequency/orientation patterns + noise), a
stand-in for CIFAR-10 with the same shapes and cardinality knobs.

``token_stream``: synthetic LM corpus from a class of order-2 Markov
chains — next-token structure exists, so LM losses decrease under
training and convergence comparisons between sync strategies are
meaningful.
"""
from __future__ import annotations

import numpy as np


def cifar_like(n: int, *, seed: int = 0, num_classes: int = 10,
               image_size: int = 32, channels: int = 3):
    """Returns (images [n,H,W,C] float32 in [-1,1], labels [n] int32)."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, num_classes, size=n).astype(np.int32)
    yy, xx = np.meshgrid(np.arange(image_size), np.arange(image_size),
                         indexing="ij")
    imgs = np.empty((n, image_size, image_size, channels), np.float32)
    # class templates: oriented gratings at class-specific frequency/phase
    thetas = np.linspace(0, np.pi, num_classes, endpoint=False)
    freqs = 2 + np.arange(num_classes) % 5
    for c in range(num_classes):
        proj = np.cos(thetas[c]) * xx + np.sin(thetas[c]) * yy
        tmpl = np.sin(2 * np.pi * freqs[c] * proj / image_size)
        idx = labels == c
        k = int(idx.sum())
        base = np.repeat(tmpl[None, :, :, None], channels, axis=3)
        # per-channel class colour cast
        cast = np.sin(np.arange(channels) + c)[None, None, None, :]
        imgs[idx] = 0.6 * base + 0.25 * cast
    imgs += rng.randn(n, image_size, image_size, channels).astype(
        np.float32) * 0.35
    return np.clip(imgs, -1, 1), labels


def token_stream(n_tokens: int, vocab: int, *, seed: int = 0):
    """Order-1 Markov chain with a sparse, banded transition structure."""
    rng = np.random.RandomState(seed)
    # each token strongly prefers a small set of successors
    n_succ = 8
    succ = (np.arange(vocab)[:, None] * 7 + rng.randint(
        0, vocab, size=(vocab, n_succ))) % vocab
    out = np.empty(n_tokens, np.int32)
    t = rng.randint(vocab)
    noise = rng.random(n_tokens)
    choices = rng.randint(0, n_succ, size=n_tokens)
    uniform = rng.randint(0, vocab, size=n_tokens)
    for i in range(n_tokens):
        out[i] = t
        if noise[i] < 0.85:
            t = succ[t, choices[i]]
        else:
            t = uniform[i]
    return out


def lm_batches(tokens: np.ndarray, batch: int, seq: int, *, seed: int = 0):
    """Yield dicts of {"tokens","labels"} forever (deterministic order)."""
    n_seq = (len(tokens) - 1) // seq
    rng = np.random.RandomState(seed)
    starts = rng.permutation(n_seq)
    i = 0
    while True:
        idx = [starts[(i + j) % n_seq] for j in range(batch)]
        i += batch
        toks = np.stack([tokens[s * seq:(s + 1) * seq] for s in idx])
        labs = np.stack([tokens[s * seq + 1:(s + 1) * seq + 1] for s in idx])
        yield {"tokens": toks, "labels": labs}
