from repro.data.synthetic import cifar_like, lm_batches, token_stream  # noqa: F401
from repro.data.loader import WorkerShards, global_batch_iter  # noqa: F401
