"""Cheap runtime backstops for the contracts ``repro.analysis`` checks
statically.

The report dataclasses (``serving.FleetReport``,
``serverless.RuntimeReport``) are the boundary where simulated numbers
become *claims* — golden snapshots, BENCH_*.json hashes, Pareto fronts.
A jax tracer leaking into one of those fields means a jitted function
is building reports mid-trace, which silently turns a pure host-side
measurement into an abstract value (and usually a ConcretizationError
three calls later, far from the cause).  ``no_tracer_fields`` is the
runtime twin of the static ``trace-safety`` rule: O(fields) type
checks, no jax import, so analytic-only users never pay accelerator
import costs.
"""
from __future__ import annotations

import dataclasses


def _is_tracer(value) -> bool:
    t = type(value)
    if t.__module__.partition(".")[0] != "jax":     # fast path: host types
        return False
    return any(c.__name__ == "Tracer" for c in t.__mro__)


def _scan(value, depth: int = 2):
    """Yield tracer-typed values in ``value`` (containers one level of
    tuple/list/dict deep per ``depth`` — report fields are flat or
    shallowly nested)."""
    if _is_tracer(value):
        yield value
    elif depth and isinstance(value, (tuple, list)):
        for v in value:
            yield from _scan(v, depth - 1)
    elif depth and isinstance(value, dict):
        for v in value.values():
            yield from _scan(v, depth - 1)


def no_tracer_fields(obj) -> None:
    """Raise ``TypeError`` if any dataclass field of ``obj`` holds a jax
    tracer (directly or inside a shallow tuple/list/dict)."""
    for f in dataclasses.fields(obj):
        for bad in _scan(getattr(obj, f.name)):
            raise TypeError(
                f"{type(obj).__name__}.{f.name} holds a jax tracer "
                f"({type(bad).__name__}); reports must be built from "
                "concrete host values, never inside a traced function")
