"""Byzantine-robust aggregation reductions — Pallas TPU kernels.

The PR 5 robust aggregators (``repro.serverless.recovery``) are the hot
numeric path of every converges-under-attack row and real-JAX recovery
run: per sync step the fleet's ``[W, D]`` gradient stack (W workers,
D = flat model size) is reduced with a byzantine-robust statistic.
SPIRT's argument (arXiv 2309.14148) — keep state adjacent to compute
instead of bouncing it through a master — is exactly the roofline
argument for fusing these reductions: every statistic below is
bandwidth-bound (touch W*D floats, emit D), so the kernel's job is to
touch HBM once per operand, with the worker axis resident in VMEM.

Four kernels, tiled over the D axis with the full W axis per tile:

  ``trimmed_mean``       trim == 1: one fused pass masking the per-
                         coordinate min and max entries and summing the
                         interior (the cancellation-safe form — NOT
                         (sum-min-max)/(W-2); see recovery.trimmed_mean).
                         trim >= 2: a Batcher odd-even compare-exchange
                         network sorts the W lane-vectors inside the
                         tile (O(W log^2 W) min/max ops, no gathers —
                         the "masked partial-sort" a D-tiled layout
                         wants) and the interior rows are averaged.
  ``coordinate_median``  the same sorting network; median = middle row
                         (odd W) or mean of the two middle rows (even).
  ``krum_pairwise``      the W x W squared-distance matrix, accumulated
                         across D tiles as ||xi||^2 + ||xj||^2 - 2 Gram
                         (one MXU contraction per tile) instead of
                         materializing the [W, W, D] broadcast in HBM.
  ``weiszfeld_step``     one geometric-median (Weiszfeld) iteration,
                         fused distance + reweight: pass 1 accumulates
                         per-row squared distances to z across D tiles,
                         pass 2 emits the re-weighted combination.

Dispatch contract (shared with ``repro.kernels.ops``): ``interpret=``
is the escape hatch —

  ``None``   auto-detect: Mosaic-compiled Pallas on TPU, otherwise the
             *fused jnp twin* of the kernel body (same tile math on the
             whole array).  Production code therefore never runs the
             Pallas interpreter silently (the ``kernel-interpret-
             default`` lint rule pins this contract).
  ``True``   force the Pallas interpreter — the validation mode parity
             tests use on CPU.
  ``False``  force Mosaic lowering.

Pure-jnp oracles live in ``repro.kernels.ref`` (kernel-ref-parity);
the batched numpy twins driving the adversarial sweep stay in
``repro.serverless.adversarial``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128                 # last-dim tile multiple (TPU lane width)
_DEFAULT_TILE_D = 4096


def _auto_interpret(interpret):
    """Resolve the ``interpret=`` escape hatch; None -> backend
    auto-detect (the shared helper in ops.py)."""
    if interpret is None:
        from repro.kernels.ops import default_interpret
        return default_interpret()
    return bool(interpret)


def _flatten_stack(stacked):
    """[W, ...] -> (W, D) fp32 view + trailing shape for un-flattening."""
    W = stacked.shape[0]
    trailing = stacked.shape[1:]
    return stacked.reshape(W, -1).astype(jnp.float32), trailing


def _pad_tiles(flat, tile_d):
    """Pad the D axis to a tile multiple (zeros; padded columns are
    sliced off / distance-neutral)."""
    D = flat.shape[1]
    tile = min(tile_d, max(_LANE, D))
    tile += (-tile) % _LANE
    pad = (-D) % tile
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, tile, flat.shape[1] // tile


# ---------------------------------------------------------------------------
# shared tile math (the kernel bodies AND the fused jnp twins)
# ---------------------------------------------------------------------------
def _batcher_pairs(n: int):
    """Compare-exchange pairs of a Batcher odd-even/bitonic sorting
    network for ``n`` a power of two; (lo, hi) means "row lo receives
    the minimum".  Static python ints — fully unrolled at trace time."""
    pairs = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            for i in range(n):
                partner = i ^ j
                if partner > i:
                    pairs.append((i, partner) if (i & k) == 0
                                 else (partner, i))
            j //= 2
        k *= 2
    return pairs


def _sorted_rows(x):
    """Sort a (W, d) fp32 block along axis 0 via the compare-exchange
    network (rows held as a python list — no gathers, VPU min/max
    only).  Non-power-of-two W pads with +inf rows that sink to the
    bottom and are dropped."""
    W = x.shape[0]
    P = 1
    while P < W:
        P *= 2
    rows = [x[i] for i in range(W)]
    rows += [jnp.full_like(rows[0], jnp.inf) for _ in range(P - W)]
    for lo, hi in _batcher_pairs(P):
        a, b = rows[lo], rows[hi]
        rows[lo] = jnp.minimum(a, b)
        rows[hi] = jnp.maximum(a, b)
    return rows[:W]


def _tile_trimmed_mean(x, trim: int):
    """(W, d) fp32 -> (d,) trimmed interior mean.  trim == 1 is the
    masked one-pass form (cancellation-safe under a scaled byzantine
    row); trim >= 2 runs the sorting network."""
    W = x.shape[0]
    if trim == 1:
        imin = jnp.argmin(x, axis=0)
        imax = jnp.argmax(x, axis=0)
        idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
        keep = (idx != imin[None, :]) & (idx != imax[None, :])
        mid = jnp.sum(x * keep, axis=0) / (W - 2)
        # argmin == argmax only when the whole column is constant
        return jnp.where(imin == imax, x[0], mid)
    rows = _sorted_rows(x)
    interior = rows[trim:W - trim]
    return functools.reduce(jnp.add, interior) / len(interior)


def _tile_median(x):
    """(W, d) fp32 -> (d,) per-coordinate median via the network."""
    W = x.shape[0]
    rows = _sorted_rows(x)
    if W % 2:
        return rows[W // 2]
    return 0.5 * (rows[W // 2 - 1] + rows[W // 2])


def _tile_sqdist(x):
    """(W, d) fp32 -> (W, W) partial squared distances via the Gram
    matrix: one MXU contraction instead of a [W, W, d] broadcast."""
    n = jnp.sum(x * x, axis=1)
    g = jax.lax.dot_general(x, x, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    d = n[:, None] + n[None, :] - 2.0 * g
    return jnp.maximum(d, 0.0)          # Gram cancellation never < 0


# ---------------------------------------------------------------------------
# trimmed mean / coordinate median
# ---------------------------------------------------------------------------
def _rowstat_kernel(x_ref, o_ref, *, stat, trim):
    x = x_ref[...].astype(jnp.float32)
    out = _tile_trimmed_mean(x, trim) if stat == "trim" else _tile_median(x)
    o_ref[...] = out[None, :]


def _rowstat_pallas(flat, tile_d, interpret, *, stat, trim=0):
    W = flat.shape[0]
    padded, tile, n_tiles = _pad_tiles(flat, tile_d)
    kernel = functools.partial(_rowstat_kernel, stat=stat, trim=trim)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((W, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, padded.shape[1]), jnp.float32),
        interpret=interpret,
    )(padded)
    return out[0]


def trimmed_mean(stacked, trim: int = 1, *, tile_d: int = _DEFAULT_TILE_D,
                 interpret=None):
    """Mean over axis 0 of a ``[W, ...]`` stack after dropping the
    ``trim`` smallest and largest values per coordinate.  Returns fp32
    with the stack's trailing shape; needs ``W > 2*trim``."""
    W = stacked.shape[0]
    if trim < 1:
        raise ValueError(f"trimmed_mean kernel needs trim >= 1, got "
                         f"trim={trim}")
    if W <= 2 * trim:
        raise ValueError(f"trimmed_mean needs W > 2*trim, got W={W}, "
                         f"trim={trim}")
    flat, trailing = _flatten_stack(stacked)
    if interpret is None and _auto_interpret(None):
        red = _tile_trimmed_mean(flat, trim)        # fused jnp twin
    else:
        red = _rowstat_pallas(flat, tile_d, _auto_interpret(interpret),
                              stat="trim", trim=trim)
    return red[:flat.shape[1]].reshape(trailing)


def coordinate_median(stacked, *, tile_d: int = _DEFAULT_TILE_D,
                      interpret=None):
    """Per-coordinate median over axis 0 of a ``[W, ...]`` stack
    (fp32; even W averages the two middle order statistics, matching
    ``jnp.median``)."""
    flat, trailing = _flatten_stack(stacked)
    if interpret is None and _auto_interpret(None):
        red = _tile_median(flat)                    # fused jnp twin
    else:
        red = _rowstat_pallas(flat, tile_d, _auto_interpret(interpret),
                              stat="median")
    return red[:flat.shape[1]].reshape(trailing)


# ---------------------------------------------------------------------------
# Krum pairwise distances
# ---------------------------------------------------------------------------
def _sqdist_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += _tile_sqdist(x)


def krum_pairwise(stacked, *, tile_d: int = _DEFAULT_TILE_D,
                  interpret=None):
    """``[W, ...]`` stack -> (W, W) fp32 matrix of squared Euclidean
    distances between rows (diagonal ~0), accumulated in a single pass
    over D tiles.  The selection/scoring layer on top is cheap (W is
    the fleet size); the O(W^2 D) distance work is the hot part."""
    flat, _ = _flatten_stack(stacked)
    W = flat.shape[0]
    if interpret is None and _auto_interpret(None):
        return _tile_sqdist(flat)                   # fused jnp twin
    padded, tile, n_tiles = _pad_tiles(flat, tile_d)
    return pl.pallas_call(
        _sqdist_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((W, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((W, W), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((W, W), jnp.float32),
        interpret=_auto_interpret(interpret),
    )(padded)


# ---------------------------------------------------------------------------
# Weiszfeld inner step (geometric median)
# ---------------------------------------------------------------------------
def _accum_sqdist_kernel(x_ref, z_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum((x - z) ** 2, axis=1, keepdims=True)


def _wsum_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)              # (W, 1)
    o_ref[...] = jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (1, tile)


def weiszfeld_step(stacked, z, floor, *, row_sqnorms=None,
                   tile_d: int = _DEFAULT_TILE_D, interpret=None):
    """One Weiszfeld iteration on a ``[W, D]`` stack: distances of
    every row to ``z``, inverse-distance weights floored at ``floor``
    (the tolerance guard recovery.geometric_median uses), re-weighted
    combination.  Returns the new fp32 ``(D,)`` estimate.

    ``row_sqnorms`` (the per-row ``||x_i||^2``, constant across
    iterations) lets the fused jnp twin use the cached-Gram form —
    ``d_i^2 = ||x_i||^2 - 2 x_i.z + ||z||^2`` — touching the stack
    twice per step instead of three times; the Pallas path computes
    the numerically-safer ``sum((x - z)^2)`` in-tile and ignores it."""
    flat, _ = _flatten_stack(stacked)
    W, D = flat.shape
    z = z.reshape(-1).astype(jnp.float32)
    if z.shape[0] != D:
        raise ValueError(f"weiszfeld_step needs z of length {D}, got "
                         f"{z.shape[0]}")
    if interpret is None and _auto_interpret(None):
        if row_sqnorms is None:
            sq = jnp.sum((flat - z[None, :]) ** 2, axis=1)
        else:
            sq = jnp.maximum(
                row_sqnorms - 2.0 * (flat @ z) + jnp.dot(z, z), 0.0)
        w = 1.0 / jnp.maximum(jnp.sqrt(sq), floor)
        return (w @ flat) / jnp.sum(w)
    interp = _auto_interpret(interpret)
    padded, tile, n_tiles = _pad_tiles(flat, tile_d)
    zp = jnp.pad(z, (0, padded.shape[1] - D)).reshape(1, -1)
    sq = pl.pallas_call(
        _accum_sqdist_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((W, tile), lambda i: (0, i)),
                  pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((W, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((W, 1), jnp.float32),
        interpret=interp,
    )(padded, zp)
    w = 1.0 / jnp.maximum(jnp.sqrt(sq), floor)       # (W, 1)
    wsum = pl.pallas_call(
        _wsum_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((W, tile), lambda i: (0, i)),
                  pl.BlockSpec((W, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, padded.shape[1]), jnp.float32),
        interpret=interp,
    )(padded, w)
    return wsum[0, :D] / jnp.sum(w)
