"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_attention(q, k, v, *, window=None, causal=True):
    """Naive O(S^2) masked softmax attention. Shapes as the kernel."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qr, kf) / (hd ** 0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def block_norms(blocks):
    return jnp.sum(blocks.astype(jnp.float32) ** 2, axis=1)


def masked_filter(blocks, mask):
    # filter in fp32, emit in the input dtype: a bf16 gradient must
    # come back as bf16 (its wire-byte accounting depends on it)
    bf = blocks.astype(jnp.float32)
    kept = bf * mask[:, None].astype(jnp.float32)
    return kept.astype(blocks.dtype), (bf - kept).astype(blocks.dtype)


def block_significance(blocks, threshold):
    """MLLess significance mask: blocks whose RMS exceeds ``threshold``
    times the fleet-wide RMS (oracle for ``ops.block_significance``)."""
    sq = block_norms(blocks)
    rms = jnp.sqrt(jnp.mean(sq) + 1e-20)
    return jnp.sqrt(sq) > threshold * rms


def significance_filter(blocks, threshold):
    """(kept, residual, mask) in one pass (oracle for
    ``ops.significance_filter``)."""
    mask = block_significance(blocks, threshold)
    kept, resid = masked_filter(blocks, mask)
    return kept, resid, mask


def wkv6(r, k, v, logw, u):
    """Exact step-by-step RWKV6 recurrence (the kernel oracle).

    r,k,v,logw: (B, T, H, N); u: (H, N).  S_t = diag(w_t) S_{t-1} +
    k_t v_t^T;  y_t = r_t (S_{t-1} + diag(u) k_t v_t^T).
    """
    B, T, H, N = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], w[:, t]
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       S + uf[None, :, :, None] * kv)
        return wt[..., None] * S + kv, y

    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    _, ys = jax.lax.scan(step, S0, jnp.arange(T))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype)


# ---------------------------------------------------------------------------
# robust-aggregation reductions (oracles for kernels/robust_agg.py)
# ---------------------------------------------------------------------------
def trimmed_mean(stacked, trim=1):
    """Full-sort interior mean over axis 0 of a [W, ...] stack (the
    O(W log W)-per-coordinate reference the kernel's masked one-pass /
    sorting-network forms must match)."""
    W = stacked.shape[0]
    s = jnp.sort(stacked.astype(jnp.float32), axis=0)
    return jnp.mean(jax.lax.slice_in_dim(s, trim, W - trim, axis=0),
                    axis=0)


def coordinate_median(stacked):
    """Per-coordinate median over axis 0 (jnp.median semantics: even W
    averages the two middle order statistics)."""
    return jnp.median(stacked.astype(jnp.float32), axis=0)


def krum_pairwise(stacked):
    """W x W squared Euclidean distances via the explicit [W, W, D]
    broadcast (exactly what recovery.krum materializes today — the HBM
    blowup the kernel's Gram-accumulation form exists to avoid)."""
    W = stacked.shape[0]
    flat = stacked.reshape(W, -1).astype(jnp.float32)
    return jnp.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)


def weiszfeld_step(stacked, z, floor):
    """One naive Weiszfeld iteration: materialize the [W, D] residual,
    take row norms, re-weight (oracle for the fused kernel step)."""
    W = stacked.shape[0]
    flat = stacked.reshape(W, -1).astype(jnp.float32)
    z = z.reshape(-1).astype(jnp.float32)
    dist = jnp.linalg.norm(flat - z[None, :], axis=-1)
    w = 1.0 / jnp.maximum(dist, floor)
    return jnp.sum(w[:, None] * flat, axis=0) / jnp.sum(w)


def fused_adamw_flat(g, m, v, p, c1, c2, *, lr, b1, b2, eps, wd):
    gf = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * gf
    v_new = b2 * v + (1 - b2) * gf * gf
    u = -lr * ((m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + wd * pf)
    return u, m_new, v_new
