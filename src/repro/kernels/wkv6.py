"""RWKV6 chunked WKV recurrence — Pallas TPU kernel.

The wkv state update is the compute hot-spot of the attention-free SSM
architecture (rwkv6-7b).  Tiling: grid (batch, head); each program keeps
the (N, N) state resident in VMEM and walks the sequence in chunks of
``chunk`` steps — intra-chunk pairwise-decay attention (MXU matmuls) +
inter-chunk state propagation, exactly the GLA-style parallel form of
``repro.models.rwkv6`` (whose scan carries the state through HBM every
chunk; here it never leaves VMEM).

Shapes: r, k, v, logw: (B, T, H, N); u: (H, N); returns y: (B, T, H, N).
T must be a multiple of ``chunk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, *, chunk,
                seq_len):
    c = chunk
    n_chunks = seq_len // c
    N = r_ref.shape[-1]
    u = u_ref[...].astype(jnp.float32)                   # (N,)
    tidx = jax.lax.iota(jnp.int32, c)
    mask = (tidx[:, None] > tidx[None, :]).astype(jnp.float32)  # strict LT

    def chunk_body(ci, S):
        sl = pl.ds(ci * c, c)
        r = r_ref[sl, :].astype(jnp.float32)             # (c, N)
        k = k_ref[sl, :].astype(jnp.float32)
        v = v_ref[sl, :].astype(jnp.float32)
        lw = lw_ref[sl, :].astype(jnp.float32)

        L = jnp.cumsum(lw, axis=0)                       # inclusive
        Lprev = L - lw
        # inter-chunk: y_inter = (r * exp(Lprev)) @ S
        q_dec = r * jnp.exp(Lprev)
        y_inter = jax.lax.dot_general(
            q_dec, S, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (c, N)
        # intra-chunk: a[t,s] = sum_n r_t k_s exp(Lprev_t - L_s), s < t
        diff = Lprev[:, None, :] - L[None, :, :]         # (c, c, N) <= 0
        a = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(diff),
                    axis=-1) * mask                      # (c, c)
        y_intra = jax.lax.dot_general(
            a, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # bonus: (r_t . (u * k_t)) v_t
        bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
        y = y_inter + y_intra + bonus * v
        y_ref[sl, :] = y.astype(y_ref.dtype)

        # state: S_new = exp(L_last) * S + sum_s exp(L_last - L_s) k_s v_s^T
        L_last = L[-1]
        k_dec = k * jnp.exp(L_last[None, :] - L)
        S_new = jnp.exp(L_last)[:, None] * S + jax.lax.dot_general(
            k_dec, v, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (N, N)
        return S_new

    S0 = jnp.zeros((N, N), jnp.float32)
    jax.lax.fori_loop(0, n_chunks, chunk_body, S0)


def wkv6_chunked(r, k, v, logw, u, *, chunk=64, interpret=None):
    """r,k,v,logw: (B, T, H, N) with T % chunk == 0; u: (H, N).
    ``interpret=None`` auto-detects the backend (Mosaic on TPU, the
    interpreter elsewhere) via ``ops.resolve_interpret``."""
    from repro.kernels import ops as _ops
    interpret = _ops.resolve_interpret(interpret)
    B, T, H, N = r.shape
    assert T % chunk == 0, (T, chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk, seq_len=T)
    spec = pl.BlockSpec((None, T, None, N), lambda b, h: (b, 0, h, 0))
    return pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((None, N), lambda b, h: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, N), r.dtype),
        interpret=interpret,
    )(r, k, v, logw, u)
