"""Fused AdamW update — Pallas TPU kernel.

One VMEM pass reads (grad, m, v, param) tiles and writes
(update, m_new, v_new) — the TPU analogue of SPIRT's in-database model
update (state stays adjacent to compute; no separate m/v/param sweeps
over HBM).  Scalars (lr, betas, bias corrections) arrive via
scalar-prefetch-style operands broadcast into the kernel closure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adamw_kernel(g_ref, m_ref, v_ref, p_ref, c_ref,
                  u_ref, mo_ref, vo_ref, *, lr, b1, b2, eps, wd):
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    p = p_ref[...].astype(jnp.float32)
    c1 = c_ref[0, 0]
    c2 = c_ref[0, 1]
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    u = -lr * ((m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + wd * p)
    u_ref[...] = u.astype(u_ref.dtype)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def fused_adamw_flat(g, m, v, p, c1, c2, *, lr, b1, b2, eps, wd,
                     tile=(256, 256), interpret=None):
    """All operands 1-D of equal length; returns (update, m_new, v_new).
    ``interpret=None`` auto-detects the backend (Mosaic on TPU, the
    interpreter elsewhere) via ``ops.resolve_interpret``."""
    from repro.kernels import ops as _ops
    interpret = _ops.resolve_interpret(interpret)
    n = g.shape[0]
    rows, cols = tile
    per = rows * cols
    pad = (-n) % per
    def prep(x, dt):
        x = x.astype(dt)
        if pad:
            x = jnp.pad(x, (0, pad))
        return x.reshape(-1, cols)
    g2 = prep(g, jnp.float32)
    m2 = prep(m, jnp.float32)
    v2 = prep(v, jnp.float32)
    p2 = prep(p, jnp.float32)
    R = g2.shape[0]
    cvec = jnp.stack([c1, c2]).astype(jnp.float32).reshape(1, 2)
    kernel = functools.partial(_adamw_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                               wd=wd)
    u2, mo2, vo2 = pl.pallas_call(
        kernel,
        grid=(R // rows,),
        in_specs=[pl.BlockSpec((rows, cols), lambda i: (i, 0))] * 4 +
                 [pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((rows, cols), lambda i: (i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((R, cols), jnp.float32)] * 3,
        interpret=interpret,
    )(g2, m2, v2, p2, cvec)
    unflat = lambda x: x.reshape(-1)[:n]
    return unflat(u2), unflat(mo2), unflat(vo2)
