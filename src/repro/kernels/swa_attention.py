"""Sliding-window flash-attention forward — Pallas TPU kernel.

Tiling: grid (batch, kv_head, q_blocks).  Each program holds one
(Bq, hd) query tile in VMEM plus the full per-(b, kv-head) K/V strips
(the window bounds how much is ever *read*: the kv loop runs only over
blocks intersecting [q_start - window + 1, q_end], with a traced-bound
``fori_loop`` so out-of-window blocks cost nothing).  Online softmax in
fp32 accumulators, GQA folded into the tile's head-group dim.

MXU alignment: Bq and Ck are multiples of 128 where shapes allow;
``ops.swa_attention`` pads the head_dim/seq to legal tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, window, causal, q_block,
                 kv_block, seq_len):
    # q_ref: (q_block, G, hd); k_ref/v_ref: (seq, hd); o_ref like q_ref
    qi = pl.program_id(2)
    q_start = qi * q_block
    q = q_ref[...].astype(jnp.float32)                 # (Bq, G, hd)
    G = q.shape[1]
    hd = q.shape[2]
    scale = 1.0 / (hd ** 0.5)

    n_kv = seq_len // kv_block
    # kv block range intersecting the union of windows of this q tile
    if window is None:
        lo = 0
    else:
        lo = jnp.maximum((q_start - window + 1) // kv_block, 0)
    hi = jnp.minimum((q_start + q_block - 1) // kv_block + 1, n_kv) \
        if causal else n_kv

    q_pos = q_start + jax.lax.iota(jnp.int32, q_block)

    def body(ki, carry):
        m, l, acc = carry
        k_start = ki * kv_block
        k = k_ref[pl.ds(k_start, kv_block), :].astype(jnp.float32)
        v = v_ref[pl.ds(k_start, kv_block), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q.reshape(q_block * G, hd), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (Bq*G, Ck)
        s = s.reshape(q_block, G, kv_block)
        kv_pos = k_start + jax.lax.iota(jnp.int32, kv_block)
        mask = jnp.ones((q_block, kv_block), jnp.bool_)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(q_block * G, kv_block), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(q_block, G, hd)
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((q_block, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_block, G), jnp.float32)
    a0 = jnp.zeros((q_block, G, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(
        o_ref.dtype)


def swa_attention_fwd(q, k, v, *, window=None, causal=True,
                      q_block=256, kv_block=256, interpret=None):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd).  Returns (B, S, H, hd).
    ``interpret=None`` auto-detects the backend (Mosaic on TPU, the
    interpreter elsewhere) via ``ops.resolve_interpret``."""
    from repro.kernels import ops as _ops
    interpret = _ops.resolve_interpret(interpret)
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    assert S % q_block == 0 and S % kv_block == 0, (S, q_block, kv_block)

    # (B, S, KV, G, hd) so the grid can map (batch, kv_head, q_tile)
    qr = q.reshape(B, S, KV, G, hd)

    kernel = functools.partial(
        _attn_kernel, window=window, causal=causal, q_block=q_block,
        kv_block=kv_block, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, S // q_block),
        in_specs=[
            pl.BlockSpec((None, q_block, None, G, hd),
                         lambda b, h, qi: (b, qi, h, 0, 0)),
            pl.BlockSpec((None, S, None, hd), lambda b, h, qi: (b, 0, h, 0)),
            pl.BlockSpec((None, S, None, hd), lambda b, h, qi: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, None, G, hd),
                               lambda b, h, qi: (b, qi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, KV, G, hd), q.dtype),
        interpret=interpret,
    )(qr, k, v)
    return out.reshape(B, S, KV * G, hd)
