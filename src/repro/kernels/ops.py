"""Jit'd public wrappers for the Pallas kernels.

``swa_attention`` carries a custom VJP whose backward recomputes
attention with the pure-jnp reference (flash-style recompute — no
O(S^2) residuals saved), so the kernel is usable inside ``jax.grad``.

``default_interpret()`` is the shared backend auto-detect every kernel
module resolves its ``interpret=None`` default through: Mosaic lowering
on TPU, the Pallas interpreter everywhere else (the validation mode for
this container).  Production call paths must never hard-code
``interpret=True`` — the ``kernel-interpret-default`` lint rule pins
this; pass ``interpret=`` explicitly only in parity tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_significance as _bs
from repro.kernels import fused_adamw as _fa
from repro.kernels import ref as _ref
from repro.kernels import swa_attention as _swa


def default_interpret() -> bool:  # repro: allow[kernel-ref-parity] -- backend helper, not a kernel
    """True off-TPU: only Mosaic can lower these kernels natively."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:  # repro: allow[kernel-ref-parity] -- backend helper, not a kernel
    """Resolve an ``interpret=`` escape hatch: None -> auto-detect."""
    return default_interpret() if interpret is None else bool(interpret)


INTERPRET = default_interpret()


# ---------------------------------------------------------------------------
# sliding-window flash attention
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _swa_core(q, k, v, window, causal):
    S = q.shape[1]
    qb = 256 if S % 256 == 0 else (128 if S % 128 == 0 else S)
    kb = qb
    return _swa.swa_attention_fwd(q, k, v, window=window, causal=causal,
                                  q_block=qb, kv_block=kb,
                                  interpret=INTERPRET)


def _swa_fwd(q, k, v, window, causal):
    return _swa_core(q, k, v, window, causal), (q, k, v)


def _swa_bwd(window, causal, res, g):
    # memory-light backward: the chunked flash bwd from the model library
    from repro.models import attention as _att
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _att.chunked_attention(q_, k_, v_, window=window,
                                                  causal=causal), q, k, v)
    return vjp(g)


_swa_core.defvjp(_swa_fwd, _swa_bwd)


def swa_attention(q, k, v, *, window=None, causal=True):
    return _swa_core(q, k, v, window, causal)


# ---------------------------------------------------------------------------
# MLLess block significance
# ---------------------------------------------------------------------------
def block_significance(blocks, threshold):
    """blocks: (n, b) -> bool mask of significant blocks."""
    sq = _bs.block_norms(blocks, interpret=INTERPRET)
    rms = jnp.sqrt(jnp.mean(sq) + 1e-20)
    return jnp.sqrt(sq) > threshold * rms


def significance_filter(blocks, threshold):
    """Returns (kept, residual, mask) in one fused pass."""
    mask = block_significance(blocks, threshold)
    kept, resid = _bs.masked_filter(blocks, mask, interpret=INTERPRET)
    return kept, resid, mask


# ---------------------------------------------------------------------------
# RWKV6 chunked WKV
# ---------------------------------------------------------------------------
def wkv6(r, k, v, logw, u, *, chunk=64):
    """Chunked WKV recurrence (state VMEM-resident). Shapes as ref.wkv6."""
    from repro.kernels import wkv6 as _w
    T = r.shape[1]
    c = chunk
    while T % c:
        c //= 2
    return _w.wkv6_chunked(r, k, v, logw, u, chunk=max(c, 1),
                           interpret=INTERPRET)


# ---------------------------------------------------------------------------
# fused AdamW
# ---------------------------------------------------------------------------
def fused_adamw(g, m, v, p, *, lr, b1, b2, eps, wd, c1, c2):
    """Pytree-leaf update: any-shape operands, flattened internally."""
    shape = g.shape
    out = _fa.fused_adamw_flat(
        g.reshape(-1), m.reshape(-1), v.reshape(-1), p.reshape(-1),
        jnp.asarray(c1), jnp.asarray(c2), lr=lr, b1=b1, b2=b2, eps=eps,
        wd=wd, interpret=INTERPRET)
    u, m_new, v_new = (x.reshape(shape) for x in out)
    return u.astype(p.dtype), m_new, v_new
