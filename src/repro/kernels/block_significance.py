"""MLLess significance filter — Pallas TPU kernels.

Kernel 1 (``block_norms``): per-block squared-L2 norms of a
(n_blocks, block) gradient view, one VMEM pass.

Kernel 2 (``masked_filter``): given the significance mask, emits the
filtered gradient and the error-feedback residual in a single fused
elementwise pass (the operation MLLess performs per update round).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norm_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x * x, axis=1, keepdims=True)


def block_norms(blocks, *, tile_rows=256, interpret=None):
    """blocks: (n_blocks, block) -> squared L2 norm per block (n_blocks,).
    ``interpret=None`` auto-detects the backend via
    ``ops.resolve_interpret``."""
    from repro.kernels import ops as _ops
    interpret = _ops.resolve_interpret(interpret)
    n, b = blocks.shape
    tile_rows = min(tile_rows, n)
    pad = (-n) % tile_rows
    if pad:
        blocks = jnp.pad(blocks, ((0, pad), (0, 0)))
    np_ = blocks.shape[0]
    out = pl.pallas_call(
        _norm_kernel,
        grid=(np_ // tile_rows,),
        in_specs=[pl.BlockSpec((tile_rows, b), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(blocks)
    return out[:n, 0]


def _filter_kernel(x_ref, m_ref, keep_ref, resid_ref):
    x = x_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)          # (rows, 1) 0/1
    kept = x * m
    keep_ref[...] = kept.astype(keep_ref.dtype)
    resid_ref[...] = (x - kept).astype(resid_ref.dtype)


def masked_filter(blocks, mask, *, tile_rows=256, interpret=None):
    """blocks: (n, b); mask: (n,) bool -> (kept (n,b), residual (n,b)).
    ``interpret=None`` auto-detects the backend via
    ``ops.resolve_interpret``."""
    from repro.kernels import ops as _ops
    interpret = _ops.resolve_interpret(interpret)
    n, b = blocks.shape
    tile_rows = min(tile_rows, n)
    pad = (-n) % tile_rows
    m2 = mask.astype(jnp.float32)[:, None]
    if pad:
        blocks = jnp.pad(blocks, ((0, pad), (0, 0)))
        m2 = jnp.pad(m2, ((0, pad), (0, 0)))
    np_ = blocks.shape[0]
    kept, resid = pl.pallas_call(
        _filter_kernel,
        grid=(np_ // tile_rows,),
        in_specs=[pl.BlockSpec((tile_rows, b), lambda i: (i, 0)),
                  pl.BlockSpec((tile_rows, 1), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_rows, b), lambda i: (i, 0)),
                   pl.BlockSpec((tile_rows, b), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((np_, b), blocks.dtype),
                   jax.ShapeDtypeStruct((np_, b), blocks.dtype)],
        interpret=interpret,
    )(blocks, m2)
    return kept[:n], resid[:n]
