"""Analytic FLOP / byte / parameter counting per ModelConfig.

Primary source for the roofline compute term: the CPU backend's
``cost_analysis()`` counts ``lax.scan`` bodies once (verified — see
DESIGN.md §6), so scanned layer stacks are undercounted there.  Here we
count every matmul the model performs, exactly, from the config.

Conventions: 1 MAC = 2 FLOPs; causal attention counts the ~1/2 factor
(the chunked implementation skips fully-masked KV blocks via lax.cond);
sliding-window attention costs O(S·W).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import GLOBAL, LOCAL, RGLRU, RWKV, ModelConfig


def param_count(cfg: ModelConfig) -> int:
    d, f, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n = V * d * 2                       # embed + unembed
    per_layer: Dict[str, int] = {}
    per_layer[GLOBAL] = per_layer[LOCAL] = (
        d * H * hd + 2 * d * KV * hd + H * hd * d)
    w = cfg.rglru_width
    per_layer[RGLRU] = 2 * d * w + 2 * w * w + w * d + cfg.conv_width * w
    r = cfg.rwkv_lora_rank
    per_layer[RWKV] = 5 * d * d + 2 * d * r
    mlp = (3 if cfg.mlp == "swiglu" else 2) * d * f
    moe = cfg.n_experts * 3 * d * f + d * cfg.n_experts

    pat = cfg.layer_pattern
    for i in range(cfg.n_layers):
        kind = pat[i % len(pat)]
        n += per_layer[kind]
        if cfg.is_moe and kind in (GLOBAL, LOCAL):
            n += moe
        else:
            n += mlp
    if cfg.is_encoder_decoder:
        n += cfg.n_encoder_layers * (per_layer[GLOBAL] + mlp)
        n += cfg.n_layers * per_layer[GLOBAL]      # cross attention
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k experts only)."""
    if not cfg.is_moe:
        return param_count(cfg)
    full = param_count(cfg)
    d, f = cfg.d_model, cfg.d_ff
    inactive = (cfg.n_experts - cfg.experts_per_token) * 3 * d * f
    n_moe_layers = cfg.n_layers
    return full - inactive * n_moe_layers


def _attn_flops(cfg, tokens: int, kv_len: float) -> float:
    """One attention layer, ``tokens`` queries against kv_len keys avg."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * tokens * d * (H + 2 * KV) * hd + 2 * tokens * H * hd * d
    scores = 2 * tokens * kv_len * H * hd * 2     # QK^T and PV
    return proj + scores


def _mixer_flops(cfg, kind, tokens: int, seq_len: int, decode: bool) -> float:
    d = cfg.d_model
    if kind == GLOBAL:
        kv = seq_len if decode else seq_len / 2    # causal half
        return _attn_flops(cfg, tokens, kv)
    if kind == LOCAL:
        kv = min(cfg.window, seq_len) if decode else \
            min(cfg.window, seq_len / 2)
        return _attn_flops(cfg, tokens, kv)
    if kind == RGLRU:
        w = cfg.rglru_width
        return 2 * tokens * (2 * d * w + 2 * w * w + w * d)
    if kind == RWKV:
        N = cfg.rwkv_head_dim
        r = cfg.rwkv_lora_rank
        proj = 2 * tokens * (5 * d * d + 2 * d * r)
        # chunked wkv: intra ~2*T*c*d*2, inter/state ~2*T*d*N*2
        c = 64
        wkv = 2 * tokens * d * (2 * c + 2 * N)
        return proj + wkv
    raise ValueError(kind)


def _ffn_flops(cfg, kind, tokens: int) -> float:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.is_moe and kind in (GLOBAL, LOCAL):
        router = 2 * tokens * d * cfg.n_experts
        expert_tokens = tokens * cfg.experts_per_token * cfg.capacity_factor
        return router + 2 * expert_tokens * 3 * d * f
    n_mat = 3 if cfg.mlp == "swiglu" else 2
    return 2 * tokens * n_mat * d * f


def forward_flops(cfg: ModelConfig, batch: int, seq_len: int,
                  kind: str = "train") -> float:
    """Exact forward FLOPs for one step.

    kind: "train"/"prefill" (full sequence) or "decode" (1 token vs
    seq_len-long cache).
    """
    decode = kind == "decode"
    tokens = batch * (1 if decode else seq_len)
    pat = cfg.layer_pattern
    total = 0.0
    for i in range(cfg.n_layers):
        k = pat[i % len(pat)]
        total += _mixer_flops(cfg, k, tokens, seq_len, decode)
        total += _ffn_flops(cfg, k, tokens)
        if cfg.is_encoder_decoder:
            total += _attn_flops(cfg, tokens, cfg.encoder_seq)  # cross
    if cfg.is_encoder_decoder:
        enc_tokens = batch * cfg.encoder_seq
        for _ in range(cfg.n_encoder_layers):
            total += _attn_flops(cfg, enc_tokens, cfg.encoder_seq)
            total += _ffn_flops(cfg, GLOBAL, enc_tokens)
    total += 2 * tokens * cfg.d_model * cfg.vocab_size   # unembed
    return total


def train_step_flops(cfg: ModelConfig, batch: int, seq_len: int,
                     remat: bool = True) -> float:
    """fwd + bwd (2x fwd) + remat recompute (1x fwd) = 4x forward."""
    f = forward_flops(cfg, batch, seq_len, "train")
    return f * (4.0 if remat else 3.0)


def model_flops_6nd(cfg: ModelConfig, batch: int, seq_len: int) -> float:
    """The standard 6·N·D estimate (N = active params, D = tokens)."""
    return 6.0 * active_param_count(cfg) * batch * seq_len


def step_bytes_hbm(cfg: ModelConfig, batch: int, seq_len: int,
                   kind: str = "train", dtype_bytes: int = 2) -> float:
    """Lower-bound HBM traffic: params read (+grad/opt write for train)
    + KV-cache read for decode."""
    N = param_count(cfg)
    if kind == "train":
        # params read fwd + bwd, grads written, adam m/v read+write fp32
        return N * dtype_bytes * 3 + N * 4 * 4
    if kind == "prefill":
        return N * dtype_bytes
    # decode: params + full cache read per token
    pat = cfg.layer_pattern
    cache = 0
    for i in range(cfg.n_layers):
        k = pat[i % len(pat)]
        if k == GLOBAL:
            cache += seq_len * cfg.n_kv_heads * cfg.head_dim * 2
        elif k == LOCAL:
            cache += min(cfg.window, seq_len) * cfg.n_kv_heads \
                * cfg.head_dim * 2
        elif k == RGLRU:
            cache += cfg.rglru_width * (cfg.conv_width + 1)
        elif k == RWKV:
            cache += (cfg.d_model // cfg.rwkv_head_dim) \
                * cfg.rwkv_head_dim ** 2
    return N * dtype_bytes + batch * cache * dtype_bytes
