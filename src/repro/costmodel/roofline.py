"""Roofline-term computation from dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute term    = FLOPs_global / (chips × peak_FLOP/s)
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

The compiled SPMD module is the *per-device* program, so HLO-derived
byte counts are already per-device; the global analytic FLOPs are
divided by the chip count.  Sources and caveats in DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.costmodel.pricing import HW


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # 6·N_active·D
    hlo_flops: float            # analytic exact count (scan-corrected)
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * HW.peak_flops_bf16)

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_time_lower_bound_s": self.step_time_s,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_upper_bound": self.mfu_upper_bound, "chips": self.chips,
        }


def roofline(flops_global: float, hbm_bytes_per_dev: float,
             wire_bytes_per_dev: float, chips: int,
             model_flops: float) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_global / (chips * HW.peak_flops_bf16),
        memory_s=hbm_bytes_per_dev / HW.hbm_bandwidth,
        collective_s=wire_bytes_per_dev / HW.ici_bandwidth,
        model_flops=model_flops, hlo_flops=flops_global, chips=chips)
