from repro.costmodel import flops, pricing  # noqa: F401
