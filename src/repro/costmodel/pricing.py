"""Cloud pricing constants and cost formulas (paper §3.1/§4.1).

The Lambda formula is the paper's:  Cost = Time(s) × RAM(GB) × $/GB-s.
TPU v5e pricing extends the comparison beyond-paper (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

# --- AWS (paper's constants) ---
LAMBDA_USD_PER_GB_S = 0.0000166667          # x86, us-east
G4DN_XLARGE_USD_PER_HOUR = 0.526            # 1x NVIDIA T4, on-demand
S3_PUT_USD = 0.005 / 1000                   # per request
S3_GET_USD = 0.0004 / 1000
SQS_USD_PER_MILLION = 0.40
STEP_FUNCTIONS_USD_PER_TRANSITION = 0.000025

# --- TPU (beyond-paper extension) ---
TPU_V5E_USD_PER_CHIP_HOUR = 1.20            # on-demand, us-central


def lambda_cost(seconds: float, ram_gb: float, invocations: int = 1) -> float:
    return seconds * ram_gb * LAMBDA_USD_PER_GB_S * invocations


def gpu_cost(seconds: float, n_instances: int = 1,
             usd_per_hour: float = G4DN_XLARGE_USD_PER_HOUR) -> float:
    return seconds / 3600.0 * usd_per_hour * n_instances


def tpu_cost(seconds: float, n_chips: int,
             usd_per_chip_hour: float = TPU_V5E_USD_PER_CHIP_HOUR) -> float:
    return seconds / 3600.0 * usd_per_chip_hour * n_chips


def storage_ops_cost(puts: int, gets: int) -> float:
    return puts * S3_PUT_USD + gets * S3_GET_USD


@dataclasses.dataclass(frozen=True)
class TPUv5e:
    """Roofline hardware constants (per chip)."""
    peak_flops_bf16: float = 197e12       # FLOP/s
    hbm_bandwidth: float = 819e9          # B/s
    ici_bandwidth: float = 50e9           # B/s per link
    hbm_bytes: float = 16e9


HW = TPUv5e()
