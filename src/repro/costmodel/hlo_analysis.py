"""Post-SPMD HLO analysis: collective bytes with while-loop multipliers.

``cost_analysis()`` has no collective accounting and counts scan bodies
once (DESIGN.md §6), so we parse ``compiled.as_text()``:

  1. split the module into named computations,
  2. find every while op and recover its trip count from the canonical
     ``compare(counter, constant)`` pattern in the condition computation,
  3. propagate multipliers (nested whiles multiply),
  4. sum result-shape bytes of every collective op, scaled by its
     computation's multiplier.

Byte semantics per op (per-device wire-byte estimates for a ring of
size W; W unknown at parse time, so we report *result-shape bytes* and
let the roofline layer apply schedule factors):
  all-reduce: 2x result bytes; all-gather/reduce-scatter: 1x;
  all-to-all / collective-permute: 1x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# per-device wire bytes as a multiple of the op's RESULT bytes, for a
# ring schedule over a group of size g:
#   all-reduce      2(g-1)/g x result      ~ 2x
#   all-gather      (g-1)/g x result       ~ 1x
#   reduce-scatter  (g-1)/g x input = (g-1) x result   <- scales with g!
#   all-to-all      (g-1)/g x result       ~ 1x
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_GROUP_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,512]{1,0}' or a tuple '(f32[2], f32[2])' -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_ENTRY_RE = re.compile(
    r"^ENTRY\s+%?[\w\.\-]+\s*\((.*?)\)\s*->\s*(.+?)\s*\{\s*$",
    re.MULTILINE)


def entry_io_bytes(hlo: str) -> Tuple[int, int]:
    """(parameter_bytes, result_bytes) of the module's ENTRY
    computation — the compiler-confirmed memory floor of one call:
    every input must be read at least once and every output written
    once, so ``param + result`` bytes over the machine's stream
    bandwidth lower-bounds achievable wall-clock (the kernel-bench
    roofline gate).  Returns (0, 0) when no ENTRY header parses."""
    m = _ENTRY_RE.search(hlo)
    if not m:
        return 0, 0
    return _shape_bytes(m.group(1)), _shape_bytes(m.group(2))


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]
    total_bytes: float          # result-shape bytes × multipliers
    wire_bytes: float           # schedule-weighted (2x for all-reduce)
    unresolved_loops: int


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation name -> its instruction lines (body between braces)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if cur is None and stripped.endswith("{") and "=" not in \
                stripped.split("(")[0]:
            m = _HEADER_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def analyze_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)

    # map body-computation -> (parent computation, trip count) using the
    # "known_trip_count" backend_config XLA attaches to scan-style whiles
    body_trips: Dict[str, Tuple[str, int]] = {}
    unresolved = 0
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" not in ln:
                continue
            mb = _BODY_RE.search(ln)
            if not mb:
                continue
            mt = _TRIP_RE.search(ln)
            if mt:
                trips = int(mt.group(1))
            else:
                trips = 1
                unresolved += 1
            body_trips[mb.group(1)] = (cname, trips)

    # multiplier per computation (nested loops multiply)
    def multiplier(cname: str, seen=()) -> float:
        if cname in seen:
            return 1.0
        if cname in body_trips:
            parent, trips = body_trips[cname]
            return trips * multiplier(parent, seen + (cname,))
        return 1.0

    # also attribute computations *called* by loop bodies (fusions etc.):
    # conservative approach — collectives only appear at top computation
    # scope in post-SPMD HLO, inside entry or while bodies.
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    bytes_by: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    total = 0.0
    wire = 0.0
    for cname, lines in comps.items():
        mult = multiplier(cname)
        for ln in lines:
            for kind in _COLLECTIVES:
                # "%x = bf16[..] all-reduce(" / "all-reduce-start("
                if re.search(rf"=\s*[^=]*\b{kind}(-start)?\(", ln):
                    lhs = ln.split("=", 1)[1]
                    shape_part = lhs.split(kind)[0]
                    b = _shape_bytes(shape_part)
                    factor = _WIRE_FACTOR[kind]
                    if kind == "reduce-scatter":
                        gm = _GROUP_RE.search(ln)
                        g = len(gm.group(1).split(",")) if gm else 2
                        factor = max(g - 1, 1)
                    counts[kind] += int(mult)
                    bytes_by[kind] += b * mult
                    total += b * mult
                    wire += b * mult * factor
                    break
    return CollectiveStats(counts=counts, bytes_by_kind=bytes_by,
                           total_bytes=total, wire_bytes=wire,
                           unresolved_loops=unresolved)
