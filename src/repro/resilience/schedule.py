"""Deterministic step-indexed fault schedules for real training runs.

The serverless stack expresses faults in *wall-clock seconds* over an
epoch horizon (``faults.FaultPlan``); a real training loop advances in
*steps*.  :class:`FaultSchedule` is the bridge: an immutable list of
(step, worker) kills, either written directly or derived from a
``FaultPlan`` so the exact scenario the event runtime simulates can be
replayed against real sharded training (``repro.resilience.harness``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.serverless.faults import FaultPlan


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Kills to inject, as ``(step, worker)`` pairs sorted by step.

    ``worker`` indexes the fleet *at the moment of the kill* (after an
    earlier takeover shrank the fleet, the harness reduces it modulo
    the surviving width).  A kill at step ``s`` means steps ``0..s-1``
    completed and step ``s``'s in-flight work is lost — the recovery
    policy decides what happens next.
    """
    kills: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self):
        norm = []
        for entry in self.kills:
            step, worker = entry
            if step < 1:
                raise ValueError(
                    f"kill step must be >= 1 (step {step}: there is "
                    "nothing to recover before the first completed step)")
            if worker < 0:
                raise ValueError(f"worker must be >= 0, got {worker}")
            norm.append((int(step), int(worker)))
        norm.sort()
        steps = [s for s, _ in norm]
        if len(set(steps)) != len(steps):
            raise ValueError(
                f"at most one kill per step, got steps {steps}")
        object.__setattr__(self, "kills", tuple(norm))

    @classmethod
    def single(cls, step: int, worker: int = 0) -> "FaultSchedule":
        return cls(kills=((step, worker),))

    def kill_at(self, step: int) -> Optional[int]:
        """Worker to kill before executing ``step``, or None."""
        for s, w in self.kills:
            if s == step:
                return w
        return None

    @property
    def n_kills(self) -> int:
        return len(self.kills)

    @classmethod
    def from_fault_plan(cls, plan: FaultPlan, *, total_steps: int,
                        horizon_s: float) -> "FaultSchedule":
        """Map a serverless :class:`FaultPlan`'s crash times onto step
        indices: a crash at time ``t`` of an epoch spanning
        ``horizon_s`` kills before step ``round(t / horizon_s *
        total_steps)``, clamped into ``[1, total_steps - 1]`` so the
        kill always lands mid-run.  Crashes mapping to an occupied step
        are dropped (first in time order wins — one kill per step, like
        the runtime's one-crash-per-worker thinning).  Pure function of
        (plan, total_steps, horizon_s)."""
        if total_steps < 2:
            raise ValueError(
                f"total_steps must be >= 2, got {total_steps}")
        if not horizon_s > 0:
            raise ValueError(f"horizon_s must be > 0, got {horizon_s}")
        kills, used = [], set()
        for crash in sorted(plan.crashes, key=lambda c: c.time_s):
            step = int(round(crash.time_s / horizon_s * total_steps))
            step = min(max(step, 1), total_steps - 1)
            if step in used:
                continue
            used.add(step)
            kills.append((step, crash.worker))
        return cls(kills=tuple(kills))
