"""In-memory "in-DB" state store — the SPIRT / RedisAI stand-in.

SPIRT's fault-tolerance story (arXiv 2309.14148) is that per-worker
model/optimizer partitions live in the database, so a dead worker's
state survives it and peers take over without replay.  This module is
that database for the real-training harness: a byte store holding one
serialized partition per worker, with read/write accounting so the
recovery benchmark can report *bytes moved* per policy.

The harness pushes ``checkpoint.dumps(state)`` split into ``W``
contiguous slices (partition ``w`` = the ``w``-th slice of the blob) —
the store is the source of truth at takeover time: survivors reassemble
the full blob from the partitions and re-shard it onto the survivor
mesh, so recovered state genuinely round-trips through the DB's bytes
rather than being copied from surviving device memory.
"""
from __future__ import annotations

from typing import Dict, List, Tuple


class InMemoryStore:
    """Keyed byte store with transfer accounting."""

    def __init__(self):
        self._data: Dict[str, bytes] = {}
        self.bytes_written = 0
        self.bytes_read = 0
        self.puts = 0
        self.gets = 0

    def put(self, key: str, data: bytes) -> None:
        self._data[key] = bytes(data)
        self.bytes_written += len(data)
        self.puts += 1

    def get(self, key: str) -> bytes:
        if key not in self._data:
            raise KeyError(
                f"store has no key {key!r}; present: "
                f"{sorted(self._data)}")
        data = self._data[key]
        self.bytes_read += len(data)
        self.gets += 1
        return data

    def __contains__(self, key: str) -> bool:
        return key in self._data

    def keys(self) -> List[str]:
        return sorted(self._data)

    def reset(self) -> None:
        self._data.clear()
        self.bytes_written = self.bytes_read = 0
        self.puts = self.gets = 0

    # ------------------------------------------------------------------
    # per-worker state partitions (SPIRT's in-DB model shards)
    # ------------------------------------------------------------------
    @staticmethod
    def _part_key(worker: int) -> str:
        return f"shard/{worker}"

    def push_partitions(self, blob: bytes, n_workers: int) -> None:
        """Split ``blob`` into ``n_workers`` contiguous slices and store
        one per worker (overwriting the previous step's partition —
        the DB holds only the current state, like SPIRT's per-round
        in-place updates)."""
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        step = len(blob) // n_workers
        for w in range(n_workers):
            lo = w * step
            hi = (w + 1) * step if w < n_workers - 1 else len(blob)
            self.put(self._part_key(w), blob[lo:hi])

    def fetch_state(self, n_workers: int,
                    dead: int) -> Tuple[bytes, int]:
        """Reassemble the full blob from every worker's partition.

        Returns ``(blob, dead_partition_bytes)`` — the second value is
        the transfer peer takeover actually *buys*: survivors hold their
        own partitions already, so the dead peer's slice is the state
        that had to cross the network.  (Read accounting still counts
        every partition; ``bytes_read`` is the DB-side load.)"""
        parts = [self.get(self._part_key(w)) for w in range(n_workers)]
        if not 0 <= dead < n_workers:
            raise ValueError(
                f"dead worker {dead} out of range for {n_workers}")
        return b"".join(parts), len(parts[dead])
